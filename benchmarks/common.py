"""Shared harness for the paper-figure benchmarks.

Every benchmark module exposes ``run() -> BenchResult``: a list of CSV-able
rows plus a list of paper-claim checks. ``benchmarks/run.py`` drives them all
and writes ``reports/bench_results.json``.

Timing source: the performance analyzer in "model" mode over the calibrated
A10 preset (``A10_CALIBRATED``) — measured-equivalent efficiency factors
calibrated once against the paper's own Fig. 2(b) ratios (see
``core/hardware.py``). The FlexGen baseline keeps using raw peak numbers, as
it does in the paper. On a real GPU/TPU host the same benchmarks run with
``measure='wallclock'``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10_CALIBRATED, HardwareModel
from repro.core.interval import LayerTimes, NO_OFFLOAD


@dataclasses.dataclass
class Claim:
    """One paper claim and what our reproduction yields."""
    name: str
    paper: str                  # the paper's number/statement
    ours: str                   # what we measured/modeled
    ok: bool                    # qualitative claim reproduced?
    note: str = ""

    def row(self) -> str:
        s = "PASS" if self.ok else "DIFF"
        out = f"  [{s}] {self.name}: paper={self.paper} ours={self.ours}"
        if self.note:
            out += f"  ({self.note})"
        return out


@dataclasses.dataclass
class BenchResult:
    name: str
    rows: list[dict]                     # tabular results
    claims: list[Claim]
    notes: list[str] = dataclasses.field(default_factory=list)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "rows": self.rows,
            "claims": [dataclasses.asdict(c) for c in self.claims],
            "notes": self.notes,
        }

    def render(self) -> str:
        lines = [f"=== {self.name} ==="]
        if self.rows:
            cols = list(self.rows[0].keys())
            lines.append(",".join(cols))
            for r in self.rows:
                lines.append(",".join(_fmt(r.get(c)) for c in cols))
        for c in self.claims:
            lines.append(c.row())
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def analyzer_for(cfg: ModelConfig, hw: HardwareModel = A10_CALIBRATED,
                 link_share: float = 1.0) -> PerformanceAnalyzer:
    return PerformanceAnalyzer(cfg, hw, measure="model",
                               link_share=link_share)


def times_for(cfg: ModelConfig, batch: int, seq: int, phase: str,
              hw: HardwareModel = A10_CALIBRATED,
              link_share: float = 1.0) -> LayerTimes:
    return analyzer_for(cfg, hw, link_share).layer_times(batch, seq, phase)


def weight_bytes_total(cfg: ModelConfig) -> int:
    """Whole-model weight bytes (stack + embeddings)."""
    from repro.models import spec as S
    from repro.models.model import build_model
    return S.tree_bytes(build_model(cfg).spec)


def non_stack_bytes(cfg: ModelConfig) -> int:
    """Weight bytes outside the offloadable layer stack (embeddings, head)."""
    from repro.models.transformer import pattern_info
    _, units = pattern_info(cfg)
    return weight_bytes_total(cfg) - units * costs.unit_weight_bytes(cfg)


def kv_bytes_for(cfg: ModelConfig, batch: int, total_seq: int) -> int:
    return costs.kv_cache_bytes(cfg, batch, total_seq)


def interval_str(i: int) -> str:
    return "inf" if i >= NO_OFFLOAD else str(i)


def capture_trace(eng, perfetto_path: str | None = None) -> dict:
    """Audit a finished engine's iteration trace and summarize it for a
    benchmark report. Optionally exports the Perfetto timeline alongside.

    Returns {audit_ok, audit_checks, violations, totals} — benches fold
    audit_ok into a Claim so a conservation regression fails the figure
    that exercised it, not just the unit suite.
    """
    report = eng.trace.audit()
    if perfetto_path is not None:
        eng.trace.write_perfetto(perfetto_path)
    return {
        "audit_ok": report.ok,
        "audit_checks": report.checks,
        "violations": report.violations[:10],
        "totals": eng.trace.totals(),
    }


def throughput_tok_s(batch: int, iter_s: float) -> float:
    return batch / iter_s if iter_s > 0 else 0.0


# ---------------------------------------------------------------------------
# System decisions under joint SLO + device-memory constraints (fig10/12/13)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SystemDecision:
    feasible: bool
    interval: int = NO_OFFLOAD       # Select-N; FlexGen reports fraction
    fraction: float = 0.0            # FlexGen offloaded portion
    host_bytes: float = 0.0
    device_weight_bytes: float = 0.0
    iter_s: float = float("inf")     # actual (calibrated) latency
    why: str = ""


def selectn_decide(times: LayerTimes, slo_s: float, hbm_bytes: float,
                   non_stack_bytes: float, kv_bytes: float) -> SystemDecision:
    """Smallest interval meeting the SLO whose resident set + KV fits HBM
    (= maximal host-memory usage subject to both constraints)."""
    from repro.core.interval import OffloadPlan, iter_time_with_interval
    budget = hbm_bytes - non_stack_bytes - kv_bytes
    for i in list(range(1, times.num_layers + 1)) + [NO_OFFLOAD]:
        plan = OffloadPlan(times.num_layers, i)
        if plan.device_bytes(times.layer_bytes) > budget:
            continue
        t = iter_time_with_interval(times, i)
        if t <= slo_s * (1 + 1e-9):
            return SystemDecision(
                True, interval=i,
                host_bytes=plan.host_bytes(times.layer_bytes),
                device_weight_bytes=plan.device_bytes(times.layer_bytes)
                + non_stack_bytes,
                iter_s=t, fraction=plan.num_offloaded / times.num_layers)
    return SystemDecision(False, why="no interval meets SLO within HBM")


def flexgen_decide(times: LayerTimes, slo_s: float, hbm_bytes: float,
                   non_stack_bytes: float, kv_bytes: float,
                   layer_flops: float, hw: HardwareModel,
                   bw_assumed: float, bw_actual: float = 1.0
                   ) -> SystemDecision:
    """SLO-aware FlexGen (paper §3.3): static offload fraction chosen from the
    peak-FLOPs latency estimate and an *assumed* bandwidth share; the actual
    latency is then whatever the calibrated times + actual share yield.

    bw_assumed: 1/n for the worst-case operator (Obs #3, under-offloads);
    1.0 for the contention-oblivious operator (violates under contention).
    """
    l, tt = times.num_layers, times.t_transfer_s
    tc_est = hw.peak_exec_time(layer_flops)
    # largest f whose ESTIMATED latency (1-layer-lookahead overlap) meets SLO
    per_layer_budget = slo_s / l
    if tc_est > per_layer_budget:
        f_slo = 0.0
    else:
        f_slo = min(1.0, per_layer_budget * bw_assumed / tt)
    # memory floor: must offload at least the HBM excess
    stack = l * times.layer_bytes
    f_mem = max(0.0, (stack + non_stack_bytes + kv_bytes - hbm_bytes) / stack)
    if f_mem > f_slo:
        return SystemDecision(
            False, fraction=f_slo,
            why=f"memory needs f>={f_mem:.3f} but SLO estimate allows "
                f"{f_slo:.3f}")
    f = f_slo
    # actual latency: fraction f of every layer streamed, 1-layer lookahead
    per_layer = max(times.t_compute_s, f * tt / bw_actual)
    iter_s = l * per_layer + times.t_rest_s
    return SystemDecision(
        True, fraction=f, host_bytes=f * stack,
        device_weight_bytes=(1 - f) * stack + 2 * f * times.layer_bytes
        + non_stack_bytes,
        iter_s=iter_s)
