"""Paper §5.3 / Fig. 10 (and Fig. 5): host-memory usage and throughput of
Select-N vs (SLO-aware) FlexGen. Model: OPT-13B, seq 64.

Paper claims: Select-N uses 2.37x (Fig. 10) / 2.1x (Fig. 5) more host memory
and reaches up to 1.85x (Fig. 10) / 1.9x (Fig. 5) the throughput, because
FlexGen's worst-case static estimates (peak-FLOPs compute, 1/n bus share)
under-offload, leaving less GPU memory for KV and thus smaller batches.

Two regimes are reported (the paper's Fig. 5 presumes a runnable naive mode,
which fp16 OPT-13B on a 24 GB A10 does not admit — 25.7 GB of weights):

  * SLO-limited (HBM 32 GB headroom, SLO = naive x factor): isolates the
    decision quality — how much host memory each system dares to use for a
    given slack. Reproduces the memory-ratio claim.
  * capacity-forced (HBM 24 GB, the paper's A10): model must offload to run
    at all; reproduces the max-batch / throughput claim. SLO = 4x modeled
    naive — the transfer-byte floor ((W - HBM)/link_bw) makes tighter SLOs
    arithmetically impossible at 24 GB/s; see fig13.
"""
from __future__ import annotations

from benchmarks.common import (BenchResult, Claim, flexgen_decide,
                               interval_str, kv_bytes_for, non_stack_bytes,
                               selectn_decide, times_for)
from repro.configs.paper_models import OPT_13B
from repro.core import costs
from repro.core.hardware import A10

SEQ, OUT = 64, 64
BATCHES = [4, 8, 16, 32]
SLO_FACTORS_A = [1.1, 1.2, 1.3, 1.5]   # SLO-limited regime (32 GB)
SLO_FACTOR_B = 4.0                     # capacity-forced regime (24 GB)


def run() -> BenchResult:
    cfg = OPT_13B
    ns = non_stack_bytes(cfg)
    total_seq = SEQ + OUT
    rows = []

    # ---- regime A: SLO-limited -------------------------------------------
    mem_ratios = []
    b = 8
    kv = kv_bytes_for(cfg, b, total_seq)
    times = times_for(cfg, b, total_seq, "decode")
    lf = costs.layer_flops(cfg, b, 1, total_seq)
    for fac in SLO_FACTORS_A:
        slo = fac * times.t_iter_no_offload_s
        sn = selectn_decide(times, slo, 32e9, ns, kv)
        fg = flexgen_decide(times, slo, 32e9, ns, kv, lf, A10,
                            bw_assumed=1.0 / A10.devices_per_bus)
        ratio = sn.host_bytes / fg.host_bytes if fg.host_bytes else float("inf")
        mem_ratios.append(ratio)
        rows.append({
            "regime": "slo_limited", "batch": b, "slo_factor": fac,
            "sn_interval": interval_str(sn.interval),
            "sn_host_GiB": sn.host_bytes / 2**30,
            "fg_host_GiB": fg.host_bytes / 2**30,
            "host_ratio": ratio,
            "sn_tpot_ms": sn.iter_s * 1e3, "fg_tpot_ms": fg.iter_s * 1e3,
        })

    # ---- regime B: capacity-forced ---------------------------------------
    best = {"sn": (0, 0.0), "fg": (0, 0.0)}     # batch, tok/s
    for b in BATCHES:
        kv = kv_bytes_for(cfg, b, total_seq)
        times = times_for(cfg, b, total_seq, "decode")
        lf = costs.layer_flops(cfg, b, 1, total_seq)
        slo = SLO_FACTOR_B * times.t_iter_no_offload_s
        sn = selectn_decide(times, slo, A10.hbm_bytes, ns, kv)
        fg = flexgen_decide(times, slo, A10.hbm_bytes, ns, kv, lf, A10,
                            bw_assumed=1.0 / A10.devices_per_bus)
        rows.append({
            "regime": "capacity", "batch": b, "slo_factor": SLO_FACTOR_B,
            "sn_interval": interval_str(sn.interval),
            "sn_host_GiB": sn.host_bytes / 2**30,
            "fg_host_GiB": fg.host_bytes / 2**30,
            "host_ratio": (sn.host_bytes / fg.host_bytes
                           if fg.feasible and fg.host_bytes else float("inf")),
            "sn_tpot_ms": sn.iter_s * 1e3 if sn.feasible else float("inf"),
            "fg_tpot_ms": fg.iter_s * 1e3 if fg.feasible else float("inf"),
        })
        if sn.feasible:
            best["sn"] = (b, b / sn.iter_s)
        if fg.feasible:
            best["fg"] = (b, b / fg.iter_s)

    thr_ratio = best["sn"][1] / best["fg"][1] if best["fg"][1] else float("inf")
    claims = [
        Claim("fig10a host memory Select-N vs FlexGen (SLO-limited)",
              "2.37x (2.1x in fig5)",
              f"{min(mem_ratios):.2f}x..{max(mem_ratios):.2f}x",
              ok=max(mem_ratios) > 1.4,
              note="driver: FlexGen's static 1/n bus-share worst case "
                   "(Obs #3) + one-layer vs group prefetch"),
        Claim("fig10b max supportable batch (capacity-forced)",
              "FlexGen supports smaller batches",
              f"Select-N {best['sn'][0]} vs FlexGen {best['fg'][0]}",
              ok=best["fg"][0] <= best["sn"][0]),
        Claim("fig10b throughput at best batch (capacity-forced)",
              "up to 1.85x (1.9x in fig5)", f"{thr_ratio:.2f}x",
              ok=thr_ratio > 1.0,
              note="smaller than paper: our modeled FlexGen gets the full "
                   "actual bus at runtime; the paper's also pays kernel-level "
                   "overheads we don't model"),
    ]
    return BenchResult("fig10_memory_throughput", rows, claims)


if __name__ == "__main__":
    print(run().render())
