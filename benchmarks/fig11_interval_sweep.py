"""Paper §5.4 / Fig. 11: profiling accuracy — is the record's interval really
optimal? Model: OPT-6.7B, seq 64, prefill batch 16, decode batch 128,
SLO = +50% over naive.

Paper result: optimal interval 3 (prefill) and 8 (decode); any smaller
interval violates the SLO, any larger one wastes GPU memory with no latency
or throughput gain.
"""
from __future__ import annotations

from benchmarks.common import BenchResult, Claim, analyzer_for, interval_str
from repro.configs.paper_models import OPT_6_7B
from repro.core.interval import (NO_OFFLOAD, OffloadPlan,
                                 iter_time_with_interval)

SEQ = 64
PREFILL_BATCH, DECODE_BATCH = 16, 128
SLO_FACTOR = 1.5
PAPER_OPT = {"prefill": 3, "decode": 8}


def run() -> BenchResult:
    an = analyzer_for(OPT_6_7B)
    rows = []
    claims = []
    for phase, batch in (("prefill", PREFILL_BATCH), ("decode", DECODE_BATCH)):
        times = an.layer_times(batch, SEQ, phase)
        slo = SLO_FACTOR * times.t_iter_no_offload_s
        rec = an.generate_record([slo], [batch], [SEQ], phase)
        opt = rec.lookup(slo, batch, SEQ)
        sweep = sorted({max(1, opt - 2), max(1, opt - 1), opt, opt + 1,
                        opt + 2, opt + 4, times.num_layers})
        below_violates, at_or_above_ok, mem_monotone = True, True, True
        prev_mem = -1
        for iv in sweep:
            t = iter_time_with_interval(times, iv)
            mem = OffloadPlan(times.num_layers, iv).device_bytes(
                times.layer_bytes)
            rows.append({
                "phase": phase, "interval": interval_str(iv),
                "latency_over_slo": t / slo,
                "device_weights_GiB": mem / 2**30,
                "is_optimal": iv == opt,
            })
            if iv < opt and t <= slo:
                below_violates = False
            if iv >= opt and t > slo * (1 + 1e-9):
                at_or_above_ok = False
            if mem < prev_mem:
                mem_monotone = False
            prev_mem = mem
        claims += [
            Claim(f"fig11 {phase} optimal interval",
                  str(PAPER_OPT[phase]), interval_str(opt),
                  ok=abs(opt - PAPER_OPT[phase]) <= 2,
                  note="modeled A10; paper is wall-clock"),
            Claim(f"fig11 {phase}: below-optimal violates, >=optimal meets",
                  "SLO violated below optimal only",
                  f"below_violates={below_violates} above_ok={at_or_above_ok}",
                  ok=below_violates and at_or_above_ok),
            Claim(f"fig11 {phase}: memory grows with interval",
                  "proportionate GPU memory consumption",
                  "monotone" if mem_monotone else "non-monotone",
                  ok=mem_monotone),
        ]
    return BenchResult("fig11_interval_sweep", rows, claims)


if __name__ == "__main__":
    print(run().render())
