"""Paper §5.5 / Fig. 12: bandwidth contention. OPT-13B and LLaMA2-13B on two
GPUs sharing one PCIe link, seq 64, batches 8/16/32, fixed TPOT SLO.

Paper claims: Select-N's per-bus coordinator re-picks both intervals each
iteration and keeps TPOT under the SLO at every batch size; FlexGen's static
decision violates the SLO at smaller batches; Select-N reaches 2.9x FlexGen's
throughput on the OPT-13B task.

SLO note: the paper uses 100 ms. With fp16 ~25.7 GB models on 24 GB devices
sharing one 24 GB/s link, memory alone forces each instance to move ~5 GB of
weights per iteration — a two-instance floor of ~420 ms/token at batch 8,
more at larger batches (KV displaces resident layers). The 100 ms point is
below the arithmetic floor of the stated hardware; we set the SLO at 1.2x
the per-batch contention floor and reproduce the relative behaviour
(coordinator meets the SLO, static FlexGen violates it, 2.9x throughput).
"""
from __future__ import annotations

from benchmarks.common import (BenchResult, Claim, flexgen_decide,
                               interval_str, kv_bytes_for, non_stack_bytes,
                               times_for)
from repro.configs.paper_models import LLAMA2_13B, OPT_13B
from repro.core import costs
from repro.core.coordinator import (InstanceState, coordinate,
                                    max_interval_for_memory)
from repro.core.hardware import A10
from repro.core.interval import (NO_OFFLOAD, min_feasible_interval,
                                 iter_time_with_interval)
from repro.core.simulator import (schedule_flexgen, schedule_for_interval,
                                  simulate_shared_bus)

SEQ, OUT = 64, 64
BATCHES = [8, 16, 32]
SLO_HEADROOM = 1.2


def _contention_floor(models, b, total_seq) -> float:
    """Two-instance TPOT floor: each instance must move at least its
    memory-forced offloaded layers (whole-layer granularity) over the shared
    link every iteration."""
    from repro.core.interval import OffloadPlan
    total = 0.0
    for cfg in models:
        unit = costs.unit_weight_bytes(cfg)
        budget = (A10.hbm_bytes - non_stack_bytes(cfg)
                  - kv_bytes_for(cfg, b, total_seq))
        max_i = max_interval_for_memory(cfg.num_layers, unit, budget)
        total += OffloadPlan(cfg.num_layers, max_i).host_bytes(unit)
    return total / A10.host_link_bw


def run() -> BenchResult:
    rows = []
    sn_all_ok = True
    fg_violations = 0
    thr_ratios = []
    total_seq = SEQ + OUT
    models = (OPT_13B, LLAMA2_13B)
    for b in BATCHES:
        slo_s = SLO_HEADROOM * _contention_floor(models, b, total_seq)
        insts, times_by, scheds = [], {}, []
        for cfg in models:
            ns = non_stack_bytes(cfg)
            kv = kv_bytes_for(cfg, b, total_seq)
            # each instance sees the full link when deciding min interval;
            # the coordinator then arbitrates (the paper's two-stage flow)
            t = times_for(cfg, b, total_seq, "decode")
            times_by[cfg.name] = t
            max_i = max_interval_for_memory(
                t.num_layers, t.layer_bytes, A10.hbm_bytes - ns - kv)
            min_i = min_feasible_interval(t, slo_s)
            # admission rate basis: transfers must fit one SLO period
            # (paper Fig. 8 lines 4-13, mdl.iter_time)
            insts.append(InstanceState(
                cfg.name, t.num_layers, t.layer_bytes, slo_s, min_i, max_i))
        res = coordinate(insts, link_bw=A10.host_link_bw)
        if not res.ok:
            rows.append({"batch": b, "sn_intervals": "-",
                         "sn_tpot_opt13b_ms": float("inf"),
                         "sn_tpot_llama13b_ms": float("inf"),
                         "fg_tpot_opt13b_ms": float("inf"),
                         "sn_slo_ok": False, "fg_slo_ok": False,
                         "link_rate_GBs": 0.0})
            sn_all_ok = False
            continue
        # simulate both instances actually sharing the link
        demands = []
        for inst in insts:
            iv = res.intervals[inst.name]
            t = times_by[inst.name]
            scheds.append(schedule_for_interval(
                [t.t_compute_s] * t.num_layers, iv, t.t_transfer_s,
                t.t_rest_s))
            demands.append(inst.link_rate(iv))
        outs = simulate_shared_bus(scheds, total_bw=A10.host_link_bw,
                                   demands=demands)
        sn_tpot = {i.name: o["latency_s"] for i, o in zip(insts, outs)}
        sn_all_ok &= all(v <= slo_s * 1.001 for v in sn_tpot.values())

        # FlexGen on the OPT-13B task: static decision, oblivious to the
        # neighbour's actual traffic (decides with the full link, as its
        # cost model has no runtime feedback), then runs under contention.
        cfg = OPT_13B
        t = times_by[cfg.name]
        fg = flexgen_decide(
            t, slo_s, A10.hbm_bytes, non_stack_bytes(cfg),
            kv_bytes_for(cfg, b, total_seq),
            costs.layer_flops(cfg, b, 1, total_seq), A10, bw_assumed=1.0)
        if fg.feasible:
            # neighbour (LLaMA) keeps its coordinated schedule
            fg_sched = schedule_flexgen([t.t_compute_s] * t.num_layers,
                                        fg.fraction, t.t_transfer_s,
                                        t.t_rest_s)
            fg_demand = (fg.fraction * t.num_layers * t.layer_bytes
                         / max(fg.iter_s, 1e-9))
            fouts = simulate_shared_bus(
                [fg_sched, scheds[1]], total_bw=A10.host_link_bw,
                demands=[fg_demand, demands[1]])
            fg_tpot = fouts[0]["latency_s"]
        else:
            fg_tpot = float("inf")
        fg_violated = fg_tpot > slo_s * 1.001
        fg_violations += int(fg_violated)
        thr_ratios.append((b / sn_tpot[cfg.name]) / (b / fg_tpot)
                          if fg_tpot < float("inf") else float("inf"))
        rows.append({
            "batch": b, "slo_ms": slo_s * 1e3,
            "sn_intervals": "/".join(
                interval_str(res.intervals[i.name]) for i in insts),
            "sn_tpot_opt13b_ms": sn_tpot["opt-13b"] * 1e3,
            "sn_tpot_llama13b_ms": sn_tpot["llama2-13b"] * 1e3,
            "fg_tpot_opt13b_ms": fg_tpot * 1e3,
            "sn_slo_ok": sn_tpot["opt-13b"] <= slo_s * 1.001
            and sn_tpot["llama2-13b"] <= slo_s * 1.001,
            "fg_slo_ok": not fg_violated,
            "link_rate_GBs": res.total_link_rate / 1e9,
        })

    finite = [r for r in thr_ratios if r < float("inf")]
    claims = [
        Claim("fig12 Select-N meets SLO under contention at every batch",
              "TPOT < SLO for batches 8/16/32",
              "all ok" if sn_all_ok else "violation", ok=sn_all_ok),
        Claim("fig12 FlexGen violates SLO under contention",
              "violates at batch 8 and 16",
              f"violates at {fg_violations}/3 batch sizes",
              ok=fg_violations >= 2,
              note="static full-link assumption halves under fair share"),
        Claim("fig12 throughput vs FlexGen (OPT-13B)",
              "2.9x at smaller batches",
              (f"up to {max(finite):.2f}x" if finite
               else "inf (FlexGen infeasible)"),
              ok=(not finite) or max(finite) > 1.5),
    ]
    return BenchResult("fig12_contention", rows, claims)


if __name__ == "__main__":
    print(run().render())
