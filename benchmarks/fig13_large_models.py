"""Paper §5.6 / Fig. 13: supporting models larger than device memory.
OPT-13B and LLaMA2-13B (both ~25.7 GB fp16) on a 24 GB A10, batches 1..32.

Paper claims: both models run via offloading with TPOT below 100 ms at every
batch size. The qualitative claim (larger-than-HBM models are runnable with
bounded, batch-stable TPOT) reproduces; the 100 ms absolute value does not
survive byte arithmetic — the memory-forced offload is >= 5 layers x 26 ms
of transfer per token on the stated 24 GB/s link (see rows) — so we report
our modeled floor alongside it.
"""
from __future__ import annotations

from benchmarks.common import (BenchResult, Claim, interval_str, kv_bytes_for,
                               non_stack_bytes, times_for, weight_bytes_total)
from repro.configs.paper_models import LLAMA2_13B, OPT_13B
from repro.core.coordinator import max_interval_for_memory
from repro.core.interval import iter_time_with_interval

SEQ, OUT = 64, 64
BATCHES = [1, 2, 4, 8, 16, 32]
HBM = 24e9


def run() -> BenchResult:
    rows = []
    runnable = True
    tpots = {}
    for cfg in (OPT_13B, LLAMA2_13B):
        ns = non_stack_bytes(cfg)
        for b in BATCHES:
            kv = kv_bytes_for(cfg, b, SEQ + OUT)
            times = times_for(cfg, b, SEQ + OUT, "decode")
            # min achievable TPOT: offload only the memory-forced layers
            # (largest interval whose resident set + KV fits)
            max_i = max_interval_for_memory(
                times.num_layers, times.layer_bytes, HBM - ns - kv)
            feasible = max_i >= 1
            tpot = iter_time_with_interval(times, max_i) if feasible \
                else float("inf")
            rows.append({
                "model": cfg.name, "batch": b,
                "weights_GiB": weight_bytes_total(cfg) / 2**30,
                "interval": interval_str(max_i),
                "tpot_ms": tpot * 1e3,
                "tok_s": b / tpot if feasible else 0.0,
            })
            runnable &= feasible
            tpots.setdefault(cfg.name, []).append(tpot)

    spread = max(max(v) / min(v) for v in tpots.values())
    worst_ms = max(max(v) for v in tpots.values()) * 1e3
    claims = [
        Claim("fig13 larger-than-HBM models are runnable",
              "both 13B models execute on 24 GB",
              "runnable at every batch" if runnable else "infeasible cells",
              ok=runnable),
        Claim("fig13 TPOT grows sub-linearly with batch",
              "batch 1..32 with modest TPOT growth (efficient batching)",
              f"max/min spread {spread:.2f}x over 32x batch growth",
              ok=spread < 3.0,
              note="transfer-bound: TPOT tracks offloaded bytes (KV "
                   "displaces resident layers), not compute"),
        Claim("fig13 TPOT < 100 ms",
              "below 100 ms at all batches", f"up to {worst_ms:.0f} ms",
              ok=False,
              note="not achievable at 24 GB/s x fp16 by byte arithmetic: "
                   ">= (weights - HBM)/link_bw per token; the paper's "
                   "absolute number implies a faster effective link"),
    ]
    return BenchResult("fig13_large_models", rows, claims)


if __name__ == "__main__":
    print(run().render())
