"""Paper §5.6 / Fig. 14: maximum allocatable length vs offloading interval.
Model: Qwen2-beta-7B (32k max positions), 24 GB A10.

max_length = batch x (seq + output) — the total tokens whose KV fits in the
GPU memory left after the resident weights. Paper claims: smaller intervals
offload more parameters, freeing GPU memory for KV and raising max_length
well above the naive (no-offload) dashed line.
"""
from __future__ import annotations

from benchmarks.common import (BenchResult, Claim, interval_str,
                               non_stack_bytes)
from repro.configs.paper_models import QWEN2_BETA_7B
from repro.core import costs
from repro.core.interval import NO_OFFLOAD, OffloadPlan

HBM = 24e9
# interval = num_layers is excluded: one offloaded layer still needs two
# transfer buffers, so it uses *more* device memory than not offloading.
INTERVALS = [1, 2, 4, 8, 16, NO_OFFLOAD]


def run() -> BenchResult:
    cfg = QWEN2_BETA_7B
    unit = costs.unit_weight_bytes(cfg)
    ns = non_stack_bytes(cfg)
    kv_tok = costs.kv_cache_bytes(cfg, 1, 1)
    rows = []
    lengths = []
    for iv in INTERVALS:
        plan = OffloadPlan(cfg.num_layers, iv)
        dev = plan.device_bytes(unit) + ns
        free = max(HBM - dev, 0.0)
        max_len = int(free // kv_tok)
        rows.append({
            "interval": interval_str(iv),
            "device_weights_GiB": dev / 2**30,
            "host_GiB": plan.host_bytes(unit) / 2**30,
            "max_length_tokens": max_len,
        })
        lengths.append(max_len)

    naive = lengths[-1]
    monotone = all(lengths[i] >= lengths[i + 1]
                   for i in range(len(lengths) - 1))
    claims = [
        Claim("fig14 max length grows as interval shrinks",
              "monotone increase with smaller interval",
              "monotone" if monotone else "non-monotone", ok=monotone),
        Claim("fig14 offloading beats the naive dashed line",
              "all offloaded settings above naive",
              f"interval 1 supports {lengths[0] / max(naive, 1):.1f}x the "
              f"naive max length",
              ok=all(l >= naive for l in lengths)),
    ]
    return BenchResult("fig14_max_length", rows, claims)


if __name__ == "__main__":
    print(run().render())
