"""Fig. 15 (extension): two-tier KV offloading — max allocatable context and
sustained batch vs host-KV pool size, with and without weight offloading
sharing the host link. Model: Qwen2-beta-7B on a 24 GB A10 (as Fig. 14).

Weights-only offloading caps KV at the HBM left over from the resident
weights; the host tier (serving.kv_offload) adds page capacity but charges
the streamed KV to the same link budget as weight prefetch, so sustained
batch under the TPOT SLO trades against weight-offload traffic. Emits
``reports/BENCH_kv_tiering.json``.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import (BenchResult, Claim, interval_str,
                               non_stack_bytes, times_for)
from repro.configs.paper_models import QWEN2_BETA_7B
from repro.core import costs
from repro.core.interval import (NO_OFFLOAD, OffloadPlan,
                                 iter_time_with_interval_kv)

HBM = 24e9
TPOT_SLO_S = 0.100
CONTEXT = 2048
HOST_FRACTIONS = [0.0, 0.5, 1.0, 2.0, 4.0]   # host KV pool / HBM
# resident weights / fully hidden prefetch / partially exposed prefetch —
# all TPOT-feasible at the SLO, so the shared-link tradeoff is visible
WEIGHT_INTERVALS = [NO_OFFLOAD, 16, 8]
PAGE_SIZE = 16
MAX_BATCH = 128


def _sustained_batch(cfg, iv: int, dev_kv_cap: float, host_cap: float,
                     kv_tok: int, charge_stream: bool = True) -> int:
    """Largest batch at CONTEXT tokens whose KV fits the two tiers and whose
    combined weight+KV link traffic keeps the iteration under the TPOT SLO.
    ``charge_stream=False`` is the bookkeeping counterfactual (KV moves for
    free) used to check that the stream is actually charged."""
    best = 0
    for b in range(1, MAX_BATCH + 1):
        total_kv = b * CONTEXT * kv_tok
        host_need = max(total_kv - dev_kv_cap, 0.0)
        if host_need > host_cap:
            break
        times = times_for(cfg, b, CONTEXT, "decode")
        t = iter_time_with_interval_kv(times, iv,
                                       host_need if charge_stream else 0.0)
        if t <= TPOT_SLO_S * (1 + 1e-9):
            best = b
        else:
            break        # latency is monotone in batch: no larger b fits
    return best


def run() -> BenchResult:
    cfg = QWEN2_BETA_7B
    unit = costs.unit_weight_bytes(cfg)
    ns = non_stack_bytes(cfg)
    kv_tok = costs.kv_cache_bytes(cfg, 1, 1)
    page_bytes = PAGE_SIZE * kv_tok

    rows = []
    max_ctx = {}         # (iv, frac) -> tokens
    sustained = {}       # (iv, frac) -> batch
    free_link = {}       # (iv, frac) -> batch if KV moved for free
    for iv in WEIGHT_INTERVALS:
        plan = OffloadPlan(cfg.num_layers, iv)
        dev_kv_cap = max(HBM - plan.device_bytes(unit) - ns, 0.0)
        for frac in HOST_FRACTIONS:
            host_cap = frac * HBM
            dev_pages = int(dev_kv_cap // page_bytes)
            host_pages = int(host_cap // page_bytes)
            ctx = (dev_pages + host_pages) * PAGE_SIZE
            bat = _sustained_batch(cfg, iv, dev_kv_cap, host_cap, kv_tok)
            free = _sustained_batch(cfg, iv, dev_kv_cap, host_cap, kv_tok,
                                    charge_stream=False)
            max_ctx[(iv, frac)] = ctx
            sustained[(iv, frac)] = bat
            free_link[(iv, frac)] = free
            rows.append({
                "weight_interval": interval_str(iv),
                "host_kv_frac": frac,
                "device_kv_GiB": dev_kv_cap / 2**30,
                "host_kv_GiB": host_cap / 2**30,
                "max_context_tokens": ctx,
                "sustained_batch@2k": bat,
                "batch_if_stream_free": free,
            })

    ivs = WEIGHT_INTERVALS
    mono_ctx = all(max_ctx[(iv, HOST_FRACTIONS[k])]
                   <= max_ctx[(iv, HOST_FRACTIONS[k + 1])]
                   for iv in ivs for k in range(len(HOST_FRACTIONS) - 1))
    # weights-only (frac 0) vs tiered at the largest pool
    lift = min(max_ctx[(iv, HOST_FRACTIONS[-1])]
               / max(max_ctx[(iv, 0.0)], 1) for iv in ivs)
    batch_lift = sustained[(NO_OFFLOAD, HOST_FRACTIONS[-1])] \
        >= sustained[(NO_OFFLOAD, 0.0)]
    # combined traffic: charging the KV stream to the shared link can only
    # shrink the sustained batch vs the free-link counterfactual — and must
    # actually bind somewhere, or the stream went unaccounted.
    keys = [(iv, f) for iv in ivs for f in HOST_FRACTIONS]
    shared_link = all(sustained[k] <= free_link[k] for k in keys)
    meaningful = any(sustained[k] < free_link[k] for k in keys)
    claims = [
        Claim("fig15 host tier lifts max context",
              "capacity grows with host pool",
              "monotone" if mono_ctx else "non-monotone",
              ok=mono_ctx and lift > 1.0),
        Claim("fig15 tiering lifts sustained batch under TPOT SLO",
              "host KV serves batches weights-only HBM cannot",
              f"{sustained[(NO_OFFLOAD, 0.0)]} -> "
              f"{sustained[(NO_OFFLOAD, HOST_FRACTIONS[-1])]} at 2k ctx",
              ok=batch_lift),
        Claim("fig15 KV stream is charged to the shared link",
              "streamed KV costs batch vs a free-link counterfactual",
              "charged <= free everywhere, strict somewhere"
              if shared_link and meaningful else "violated",
              ok=shared_link and meaningful),
    ]
    res = BenchResult("fig15_kv_tiering", rows, claims)
    os.makedirs("reports", exist_ok=True)
    with open("reports/BENCH_kv_tiering.json", "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
