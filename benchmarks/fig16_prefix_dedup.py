"""Fig. 16 (extension): cross-request prefix dedup + copy-on-write pages —
peak KV pages and admitted batch vs the shared-prefix fraction of the
workload. Model: Qwen2-beta-7B page geometry on a 24 GB A10 (as Fig. 14/15).

Chat-style traffic repeats the same system prompt across requests; without
dedup every copy claims its own device+host pages — exactly the capacity the
offloading interval is trying to reclaim (Fig. 14). The refcounted allocator
(``serving.kv_offload``) stores each shared prompt page once, so both the
peak page footprint and the batch a fixed page budget admits improve with
the shared fraction. COW reserves (one private frame per sharer that will
decode into the shared partial page) are part of the accounting, so the
numbers here are what the engine actually allocates. Emits
``reports/BENCH_prefix_dedup.json``.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import BenchResult, Claim
from repro.configs.paper_models import QWEN2_BETA_7B
from repro.core import costs
from repro.serving.kv_cache import PageConfig
from repro.serving.kv_offload import TieredKVAllocator

PAGE_SIZE = 16
N_REQUESTS = 16
PROMPT_LEN = 256          # tokens; shared prefix = frac * PROMPT_LEN
NEW_TOKENS = 64
SHARED_FRACTIONS = [0.0, 0.25, 0.5, 0.75, 1.0]
BUDGET_REQUESTS = 6       # device budget for the admitted-batch sweep


def _prompts(frac: float, rng: np.random.Generator) -> list[np.ndarray]:
    n_shared = int(frac * PROMPT_LEN)
    common = rng.integers(0, 32_000, n_shared).astype(np.int64)
    return [np.concatenate([common,
                            rng.integers(0, 32_000, PROMPT_LEN - n_shared
                                         ).astype(np.int64)])
            for _ in range(N_REQUESTS)]


def _mk_kv(dev_pages: int, host_pages: int, page_bytes: int, dedup: bool
           ) -> TieredKVAllocator:
    pcfg = PageConfig(PAGE_SIZE, bytes_per_token=page_bytes // PAGE_SIZE)
    return TieredKVAllocator(dev_pages * pcfg.page_size
                             * pcfg.bytes_per_token,
                             host_pages * pcfg.page_size
                             * pcfg.bytes_per_token,
                             pcfg, scope="fig16", enable_dedup=dedup)


def run() -> BenchResult:
    cfg = QWEN2_BETA_7B
    kv_tok = costs.kv_cache_bytes(cfg, 1, 1)
    page_bytes = PAGE_SIZE * kv_tok
    total = PROMPT_LEN + NEW_TOKENS
    pages_per_req = -(-total // PAGE_SIZE)
    ample = N_REQUESTS * (pages_per_req + 1)
    budget = BUDGET_REQUESTS * pages_per_req

    rows = []
    peak = {}             # (dedup, frac) -> peak pages
    admitted = {}         # (dedup, frac) -> batch admitted under budget
    for frac in SHARED_FRACTIONS:
        prompts = _prompts(frac, np.random.default_rng(42))
        for dedup in (False, True):
            kv = _mk_kv(ample, 0, page_bytes, dedup)
            for rid, prompt in enumerate(prompts):
                assert kv.alloc(rid, total, prompt=prompt) is not None
            kv.check_invariants()
            peak[(dedup, frac)] = kv.device.used_peak

            kvb = _mk_kv(budget, 0, page_bytes, dedup)
            batch = 0
            for rid, prompt in enumerate(prompts):
                if kvb.alloc(rid, total, prompt=prompt) is None:
                    break
                batch += 1
            kvb.check_invariants()
            admitted[(dedup, frac)] = batch
        rows.append({
            "shared_prefix_frac": frac,
            "peak_pages_baseline": peak[(False, frac)],
            "peak_pages_dedup": peak[(True, frac)],
            "peak_GiB_baseline": peak[(False, frac)] * page_bytes / 2**30,
            "peak_GiB_dedup": peak[(True, frac)] * page_bytes / 2**30,
            f"admitted@{BUDGET_REQUESTS}req_budget_baseline":
                admitted[(False, frac)],
            f"admitted@{BUDGET_REQUESTS}req_budget_dedup":
                admitted[(True, frac)],
        })

    base_flat = all(peak[(False, f)] == peak[(False, 0.0)]
                    for f in SHARED_FRACTIONS)
    dd_monotone = all(peak[(True, SHARED_FRACTIONS[k])]
                      >= peak[(True, SHARED_FRACTIONS[k + 1])]
                      for k in range(len(SHARED_FRACTIONS) - 1))
    never_worse = all(peak[(True, f)] <= peak[(False, f)]
                      and admitted[(True, f)] >= admitted[(False, f)]
                      for f in SHARED_FRACTIONS)
    saving_75 = 1 - peak[(True, 0.75)] / peak[(False, 0.75)]
    batch_lift = admitted[(True, 0.75)] > admitted[(False, 0.75)]
    claims = [
        Claim("fig16 dedup peak shrinks with shared fraction",
              "baseline flat, dedup monotone down",
              "as expected" if base_flat and dd_monotone else "violated",
              ok=base_flat and dd_monotone),
        Claim("fig16 dedup never allocates more / admits fewer",
              "dedup <= baseline pages, >= baseline batch at every fraction",
              "holds" if never_worse else "violated", ok=never_worse),
        Claim("fig16 75% shared prefix saves >= 40% peak pages",
              ">= 40% (differential-suite acceptance bar)",
              f"{saving_75:.0%} saved, admitted {admitted[(False, 0.75)]} -> "
              f"{admitted[(True, 0.75)]} under the fixed budget",
              ok=saving_75 >= 0.40 and batch_lift),
    ]
    res = BenchResult("fig16_prefix_dedup", rows, claims)
    os.makedirs("reports", exist_ok=True)
    with open("reports/BENCH_prefix_dedup.json", "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
