"""Fig. 17 (extension): preempt-to-host vs wait-only admission under bursts.

The head-of-line scenario the ROADMAP's "swap-aware preemption" item
targets: a streaming-heavy long request (cold KV prefix spilled to host
rides the weight-prefetch link every iteration) is decoding when a burst of
short, tight-TPOT requests arrives. Wait-only admission (§4.2 + the host
spill extension) must hold the burst back — admitting anyone while the long
request streams would push the shared-link iteration time past the shorts'
TPOT — so slots idle until the long request drains. Preempt-to-host parks
the long request's ENTIRE KV on the host tier (one whole-request migration,
charged to the link), serves the burst at full batch with a quiet link, and
resumes the victim — token-exactly — into the freed device pool.

Sweeps the burst size, runs both policies through the real scheduler-driven
engine (reduced model, modeled clock), and emits
``reports/BENCH_preemption.json``: SLO violations, admitted throughput,
preemption/resume counts, p99 queueing delay, and a bitwise token-equality
check for the preempted requests.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BenchResult, Claim
from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import NO_OFFLOAD, OffloadPlan, \
    iter_time_with_interval_kv
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request
from repro.serving.telemetry import summarize_latency

PAGE = 8
MAX_SEQ = 48
MAX_BATCH = 4
DEVICE_PAGES = 4
HOST_PAGES = 64
BURST_SIZES = [2, 4, 6]


def _mk_engine(name: str, preemption: bool) -> ServingEngine:
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=32, heads=2,
                        layers=8, d_ff=64, vocab=128)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    _, units = pattern_info(cfg)
    hbm = OffloadPlan(units, NO_OFFLOAD).device_bytes(
        costs.unit_weight_bytes(cfg)) + DEVICE_PAGES * PAGE * kv_tok
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, [1, 2, 4], [16, 32, 64], "prefill")
    rec_d = an.generate_record(slos, [1, 2, 4], [16, 32, 64], "decode")
    return ServingEngine(
        name, model, A10, rec_p, rec_d, an.layer_times,
        EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ, page_size=PAGE,
                     hbm_budget_bytes=hbm,
                     host_kv_bytes=HOST_PAGES * PAGE * kv_tok,
                     preemption=preemption))


def _trace(eng: ServingEngine, n_shorts: int):
    """S0 (long-running, device-resident), L (streams its cold prefix from
    host), then a burst of short requests whose TPOT affords one streamed
    page but never two (derived from the analytic model)."""
    pb = eng.kv.page_bytes
    dt_1 = iter_time_with_interval_kv(
        eng.times_fn(MAX_BATCH, MAX_SEQ, "decode"), eng.interval, 1 * pb)
    dt_2 = iter_time_with_interval_kv(
        eng.times_fn(1, MAX_SEQ, "decode"), eng.interval, 2 * pb)
    tpot_short = (dt_1 + dt_2) / 2
    rng = np.random.default_rng(17)

    def req(rid, plen, new, tpot):
        return Request(rid=rid,
                       prompt=rng.integers(0, 100, plen).astype(np.int32),
                       max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=tpot)

    s0 = req(0, 4, 12, 1e-3)
    long_req = req(1, 16, 16, 1e-3)
    shorts = [req(i, 4, 4, tpot_short) for i in range(2, 2 + n_shorts)]
    return s0, long_req, shorts


def _run(preemption: bool, n_shorts: int) -> dict:
    eng = _mk_engine(f"fig17-{preemption}-{n_shorts}", preemption)
    s0, long_req, shorts = _trace(eng, n_shorts)
    eng.submit(s0)
    eng.submit(long_req)
    eng.step()
    eng.step()                      # the long request is decoding (parkable)
    for s in shorts:                # burst arrival
        eng.submit(s)
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 500:
        eng.step()
        it += 1
    eng.kv.check_invariants()
    per = [r.metrics() for r in eng.finished]
    tokens = sum(m["tokens"] for m in per)
    delays = [m["queue_delay_s"] for m in per]
    return {
        "finished": len(eng.finished),
        "tokens": tokens,
        "wall_s": eng.clock_s,
        "throughput_tok_s": tokens / eng.clock_s if eng.clock_s else 0.0,
        "tpot_violations": sum(0 if m["tpot_ok"] else 1 for m in per),
        "ttft_violations": sum(0 if m["ttft_ok"] else 1 for m in per),
        "preemptions": eng.scheduler.stats["preemptions"],
        "resumes": eng.scheduler.stats["resumes"],
        "queue_delay_p99_s": summarize_latency(delays)["p99_s"],
        "gen_tokens": {r.rid: list(r.generated) for r in eng.finished},
        "preempted_rids": sorted(r.rid for r in eng.finished
                                 if r.preempt_count > 0),
    }


def _mk_chunked_engine(name: str, incremental: bool) -> ServingEngine:
    """Chunked-prefill engine for the incremental-kernel comparison: ample
    device pages (no offload pressure), prompts span several chunks."""
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=32, heads=2,
                        layers=8, d_ff=64, vocab=128)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    _, units = pattern_info(cfg)
    hbm = OffloadPlan(units, NO_OFFLOAD).device_bytes(
        costs.unit_weight_bytes(cfg)) + 16 * PAGE * kv_tok
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, [1, 2, 4], [16, 32, 64], "prefill")
    rec_d = an.generate_record(slos, [1, 2, 4], [16, 32, 64], "decode")
    return ServingEngine(
        name, model, A10, rec_p, rec_d, an.layer_times,
        EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ, page_size=PAGE,
                     hbm_budget_bytes=hbm, prefill_chunk_tokens=PAGE,
                     incremental_prefill=incremental))


def _prefill_compute(incremental: bool) -> dict:
    """Three 24-token prompts through 8-token chunks: the recompute path
    re-runs the whole resident prefix every chunk (8+16+24 = 48 tokens per
    prompt); the incremental chunk kernel attends only the new chunk's
    queries against paged KV (24 per prompt). Token counts are the gated
    claim — at reduced scale (interpret-mode Pallas, us-size matmuls) wall
    time measures dispatch overhead, so it is reported, not gated."""
    eng = _mk_chunked_engine(f"fig17-incr-{incremental}", incremental)
    rng = np.random.default_rng(23)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 100, 24).astype(np.int32),
                    max_new_tokens=4, ttft_slo_s=10.0, tpot_slo_s=10.0)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 200:
        eng.step()
        it += 1
    wall = time.perf_counter() - t0
    eng.kv.check_invariants()
    return {
        "prefill_tokens_computed": eng.prefill_tokens_computed,
        "prompt_tokens": sum(len(r.prompt) for r in reqs),
        "finished": len(eng.finished),
        "wall_s": wall,
        "gen_tokens": {r.rid: list(r.generated) for r in eng.finished},
    }


def run() -> BenchResult:
    rows = []
    zero_viol = tput_up = tokens_exact = delay_down = True
    preempted_any = False
    for n in BURST_SIZES:
        wait = _run(preemption=False, n_shorts=n)
        pre = _run(preemption=True, n_shorts=n)
        zero_viol &= (wait["tpot_violations"] + pre["tpot_violations"]
                      + wait["ttft_violations"] + pre["ttft_violations"]) == 0
        tput_up &= pre["throughput_tok_s"] > wait["throughput_tok_s"]
        tokens_exact &= pre["gen_tokens"] == wait["gen_tokens"]
        delay_down &= pre["queue_delay_p99_s"] < wait["queue_delay_p99_s"]
        preempted_any |= bool(pre["preempted_rids"])
        rows.append({
            "burst_size": n,
            "tput_wait_tok_s": wait["throughput_tok_s"],
            "tput_preempt_tok_s": pre["throughput_tok_s"],
            "speedup": pre["throughput_tok_s"] / wait["throughput_tok_s"],
            "wall_wait_s": wait["wall_s"],
            "wall_preempt_s": pre["wall_s"],
            "tpot_violations_wait": wait["tpot_violations"],
            "tpot_violations_preempt": pre["tpot_violations"],
            "preemptions": pre["preemptions"],
            "resumes": pre["resumes"],
            "q_delay_p99_wait_s": wait["queue_delay_p99_s"],
            "q_delay_p99_preempt_s": pre["queue_delay_p99_s"],
        })
    recompute = _prefill_compute(incremental=False)
    incr = _prefill_compute(incremental=True)
    incr_ok = (incr["prefill_tokens_computed"] == incr["prompt_tokens"]
               and recompute["prefill_tokens_computed"]
               > recompute["prompt_tokens"]
               and incr["gen_tokens"] == recompute["gen_tokens"]
               and incr["finished"] == 3)
    claims = [
        Claim("fig17 zero SLO violations under burst, both policies",
              "admission + preemption both SLO-safe",
              "0 TTFT/TPOT violations" if zero_viol else "violated",
              ok=zero_viol),
        Claim("fig17 preemption strictly beats wait-only throughput",
              "parked victim stops streaming; burst serves at full batch",
              "speedups " + ", ".join(f"{r['speedup']:.2f}x" for r in rows),
              ok=tput_up and preempted_any),
        Claim("fig17 preempted requests token-bitwise identical",
              "park/resume invisible in the numbers",
              "identical greedy tokens per request"
              if tokens_exact else "DIVERGED", ok=tokens_exact),
        Claim("fig17 queueing-delay p99 drops with preemption",
              "burst no longer head-of-line blocked",
              "p99 strictly lower at every burst size"
              if delay_down else "violated", ok=delay_down),
        Claim("fig17 incremental prefill ends quadratic chunk recompute",
              "each chunk attends only its own queries against resident "
              "paged KV",
              f"prefill tokens computed "
              f"{recompute['prefill_tokens_computed']} -> "
              f"{incr['prefill_tokens_computed']} "
              f"(= prompt tokens, bitwise-identical outputs)" if incr_ok
              else "NOT linear or outputs diverged", ok=incr_ok),
    ]
    res = BenchResult(
        "fig17_preemption", rows, claims,
        notes=[f"chunked prefill drain wall (3x24-token prompts): "
               f"recompute {recompute['wall_s']:.4f}s, incremental "
               f"{incr['wall_s']:.4f}s (informational: reduced-scale wall "
               f"is dispatch-bound, the gated win is compute volume)"])
    os.makedirs("reports", exist_ok=True)
    out = {**res.to_json()}
    with open("reports/BENCH_preemption.json", "w") as f:
        json.dump(out, f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
