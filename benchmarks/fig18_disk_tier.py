"""Fig. 18 (extension): three-tier (NVMe) KV offload vs host-only under
host pressure.

The ROADMAP's "Disk tier" scenario: the pinned-host pool holds exactly the
streaming long request's spilled cold prefix, so parking it — the move
that unblocks a tight-TPOT burst — needs host frames that do not exist.
Host-only, the scheduler must refuse the park (strict SLO guarantee: the
burst waits until the long request drains). With the NVMe tier, the
victim's own spilled pages retire to disk the moment it parks ("preempt to
host, overflow to disk"), long-parked pages of OTHER requests retire the
same way under later pressure, and resume stages disk->host->device. NVMe
traffic is charged to the disk link's own latency term — never to the
TPOT-critical PCIe budget.

Sweeps the burst size, runs host-only vs host+disk through the real
scheduler-driven engine (reduced model, modeled clock), and emits
``reports/BENCH_disk_tier.json``: SLO violations, parks, NVMe page moves,
p99 queueing delay, wall clock, and a bitwise token-equality check across
the two configurations.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BenchResult, Claim, capture_trace
from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import NO_OFFLOAD, OffloadPlan, \
    iter_time_with_interval_kv
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request
from repro.serving.telemetry import summarize_latency

PAGE = 8
MAX_SEQ = 48
MAX_BATCH = 4
DEVICE_PAGES = 4
HOST_PAGES = 2          # exactly the long request's spill: the pressure
DISK_PAGES = 32
BURST_SIZES = [2, 4, 6]


def _mk_engine(name: str, disk: bool, async_plane: bool = False
               ) -> ServingEngine:
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=32, heads=2,
                        layers=8, d_ff=64, vocab=128)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    _, units = pattern_info(cfg)
    hbm = OffloadPlan(units, NO_OFFLOAD).device_bytes(
        costs.unit_weight_bytes(cfg)) + DEVICE_PAGES * PAGE * kv_tok
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, [1, 2, 4], [16, 32, 64], "prefill")
    rec_d = an.generate_record(slos, [1, 2, 4], [16, 32, 64], "decode")
    return ServingEngine(
        name, model, A10, rec_p, rec_d, an.layer_times,
        EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ, page_size=PAGE,
                     hbm_budget_bytes=hbm,
                     host_kv_bytes=HOST_PAGES * PAGE * kv_tok,
                     disk_kv_bytes=(DISK_PAGES * PAGE * kv_tok) if disk
                     else 0.0,
                     # reduced model iterates in ~us: scale the NVMe issue
                     # latency down with it (the 100us default models a
                     # real device against ms-scale iterations)
                     disk_latency_s=1e-7,
                     preemption=True, async_data_plane=async_plane))


def _trace(eng: ServingEngine, n_shorts: int):
    pb = eng.kv.page_bytes
    dt_1 = iter_time_with_interval_kv(
        eng.times_fn(MAX_BATCH, MAX_SEQ, "decode"), eng.interval, 1 * pb)
    dt_2 = iter_time_with_interval_kv(
        eng.times_fn(1, MAX_SEQ, "decode"), eng.interval, 2 * pb)
    tpot_short = (dt_1 + dt_2) / 2
    rng = np.random.default_rng(18)

    def req(rid, plen, new, tpot):
        return Request(rid=rid,
                       prompt=rng.integers(0, 100, plen).astype(np.int32),
                       max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=tpot)

    s0 = req(9, 4, 12, 1e-3)               # 2 device pages, long-running
    long_req = req(0, 16, 16, 1e-3)        # 2 dev + 2 host: streams
    shorts = [req(i, 4, 4, tpot_short) for i in range(1, 1 + n_shorts)]
    return s0, long_req, shorts


def _run(disk: bool, n_shorts: int,
         perfetto_path: str | None = None) -> dict:
    eng = _mk_engine(f"fig18-{disk}-{n_shorts}", disk)
    s0, long_req, shorts = _trace(eng, n_shorts)
    eng.submit(s0)
    eng.submit(long_req)
    eng.step()
    eng.step()                              # long request decoding (parkable)
    for s in shorts:                        # burst arrival
        eng.submit(s)
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 500:
        eng.step()
        it += 1
    eng.kv.check_invariants()
    per = [r.metrics() for r in eng.finished]
    tokens = sum(m["tokens"] for m in per)
    delays = [m["queue_delay_s"] for m in per]
    return {
        "trace": capture_trace(eng, perfetto_path=perfetto_path),
        "finished": len(eng.finished),
        "tokens": tokens,
        "wall_s": eng.clock_s,
        "tpot_violations": sum(0 if m["tpot_ok"] else 1 for m in per),
        "ttft_violations": sum(0 if m["ttft_ok"] else 1 for m in per),
        "preemptions": eng.scheduler.stats["preemptions"],
        "resumes": eng.scheduler.stats["resumes"],
        "disk_demotions": eng.scheduler.stats["disk_demotions"],
        "disk_stagings": eng.scheduler.stats["disk_stagings"],
        "disk_peak_pages": eng.disk_kv_peak_pages,
        "queue_delay_p99_s": summarize_latency(delays)["p99_s"],
        "gen_tokens": {r.rid: list(r.generated) for r in eng.finished},
    }


def _wall_overhead(async_plane: bool, n_shorts: int = 6,
                   repeats: int = 3) -> dict:
    """Real wall seconds the physical copy path adds on top of the modeled
    clock — the data-plane fidelity gap the async copy-stage engine closes.
    ``blocking_copy_s`` is exactly the time the iteration thread spends
    inside the data plane (sync per-page gather/scatter dispatches, async
    batched drains + hazard waits); the modeled dt assumes that time is
    zero because the copies overlap the previous iteration's compute. The
    first run is a throwaway (jit compiles); min-of-N damps host noise.
    Full-loop wall is reported alongside, but at reduced scale it is
    dominated by jitted decode dispatch, not copies."""
    walls, blocking, background, clock = [], [], [], 0.0
    for rep in range(repeats + 1):
        eng = _mk_engine(f"fig18-wall-{async_plane}-{rep}", disk=True,
                         async_plane=async_plane)
        s0, long_req, shorts = _trace(eng, n_shorts)
        eng.submit(s0)
        eng.submit(long_req)
        eng.step()
        eng.step()
        for s in shorts:
            eng.submit(s)
        t0 = time.perf_counter()
        it = 0
        while (eng.scheduler.has_work() or eng._active_batch() > 0) \
                and it < 500:
            eng.step()
            it += 1
        if eng.data_plane is not None:
            eng.data_plane.sync()
        walls.append(time.perf_counter() - t0)
        blocking.append(eng.data_plane.blocking_copy_s)
        background.append(eng.data_plane.background_copy_s)
        clock = eng.clock_s
    return {"wall_s": min(walls[1:]), "model_clock_s": clock,
            "overhead_s": min(blocking[1:]),
            "background_s": max(background[1:])}


def run() -> BenchResult:
    rows = []
    zero_viol = more_parked = tokens_exact = delay_down = True
    audits_ok = True
    audit_checks = 0
    os.makedirs("reports", exist_ok=True)
    for n in BURST_SIZES:
        host = _run(disk=False, n_shorts=n)
        # the largest disk-enabled burst doubles as the exported Perfetto
        # timeline (ROADMAP observability acceptance artifact)
        disk = _run(disk=True, n_shorts=n,
                    perfetto_path="reports/TRACE_disk_tier_perfetto.json"
                    if n == BURST_SIZES[-1] else None)
        for side in (host, disk):
            audits_ok &= side["trace"]["audit_ok"]
            audit_checks += side["trace"]["audit_checks"]
        zero_viol &= (host["tpot_violations"] + disk["tpot_violations"]
                      + host["ttft_violations"] + disk["ttft_violations"]) == 0
        more_parked &= (disk["preemptions"] > host["preemptions"]
                        and disk["disk_demotions"] > 0
                        and disk["disk_stagings"] > 0)
        tokens_exact &= disk["gen_tokens"] == host["gen_tokens"]
        delay_down &= (disk["queue_delay_p99_s"] < host["queue_delay_p99_s"]
                       and disk["wall_s"] < host["wall_s"])
        rows.append({
            "burst_size": n,
            "finished_host": host["finished"],
            "finished_disk": disk["finished"],
            "parks_host": host["preemptions"],
            "parks_disk": disk["preemptions"],
            "disk_demotions": disk["disk_demotions"],
            "disk_stagings": disk["disk_stagings"],
            "disk_peak_pages": disk["disk_peak_pages"],
            "q_delay_p99_host_s": host["queue_delay_p99_s"],
            "q_delay_p99_disk_s": disk["queue_delay_p99_s"],
            "wall_host_s": host["wall_s"],
            "wall_disk_s": disk["wall_s"],
            "tpot_violations": host["tpot_violations"]
            + disk["tpot_violations"],
        })
    sync_wall = _wall_overhead(async_plane=False)
    async_wall = _wall_overhead(async_plane=True)
    wall_closer = async_wall["overhead_s"] < sync_wall["overhead_s"]
    claims = [
        Claim("fig18 zero SLO violations with and without the NVMe tier",
              "disk traffic modeled on its own link term",
              "0 TTFT/TPOT violations" if zero_viol else "violated",
              ok=zero_viol),
        Claim("fig18 disk tier strictly more admitted/parked than host-only",
              "spilled/long-parked pages retire to NVMe instead of "
              "refusing parks",
              "parks " + ", ".join(f"{r['parks_host']}->{r['parks_disk']}"
                                   for r in rows)
              if more_parked else "no gain", ok=more_parked),
        Claim("fig18 park->disk->resume token-bitwise identical",
              "NVMe round trip invisible in the numbers",
              "identical greedy tokens per request"
              if tokens_exact else "DIVERGED", ok=tokens_exact),
        Claim("fig18 burst queueing-delay p99 and wall clock drop",
              "burst serves at full batch while the victim sits on NVMe",
              "p99 + wall strictly lower with disk at every burst size"
              if delay_down else "violated", ok=delay_down),
        Claim("fig18 every run passes the trace-conservation audit",
              "per-tier bytes charged == allocator moves; dt <= certified",
              f"{audit_checks} checks clean across "
              f"{2 * len(BURST_SIZES)} runs" if audits_ok
              else "AUDIT VIOLATIONS", ok=audits_ok),
        Claim("fig18 async data plane: wall clock strictly closer to the "
              "modeled clock than the synchronous baseline",
              "iteration i+1's page copies overlap iteration i (paper §4 "
              "overlap, now honored by the real clock)",
              f"copy seconds on the critical path "
              f"{sync_wall['overhead_s']:.6f}s -> "
              f"{async_wall['overhead_s']:.6f}s" if wall_closer
              else "async critical-path copy time NOT lower",
              ok=wall_closer),
    ]
    res = BenchResult("fig18_disk_tier", rows, claims,
                      notes=[f"data-plane critical path (burst 6, min of "
                             f"3): sync {sync_wall['overhead_s']:.6f}s "
                             f"blocking, async "
                             f"{async_wall['overhead_s']:.6f}s blocking + "
                             f"{async_wall['background_s']:.6f}s "
                             f"overlapped on the worker; full drain loop "
                             f"sync {sync_wall['wall_s']:.4f}s / async "
                             f"{async_wall['wall_s']:.4f}s vs modeled "
                             f"{sync_wall['model_clock_s']:.6f}s"])
    with open("reports/BENCH_disk_tier.json", "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
