"""Fig. 19 (extension): sustained-load serving with online interval
autotuning vs every fixed interval in the offline range.

The traffic harness replaces burst replay: a diurnal arrival process with
multi-round chat sessions and mixed TTFT/TPOT SLO classes (``repro.data.
workload``), honored on the modeled clock by ``ServingEngine.run`` — a
request is invisible to the scheduler until ``arrival_s``, and queueing
delay is measured from arrival. On top of it, the §5 online stage
(``serving.autotune.IntervalTuner``) re-picks the offloading interval every
iteration inside the offline ``[min, max]`` bracket.

Scenario sizing (reduced model, modeled A10 clock): the offline range is
exactly {1, 2}. Interval 1 hosts the whole layer stack but its weight
transfers (~2.5ms/iter) overrun the 2ms interactive TPOT class — a fixed
interval 1 admits those requests anyway (nothing re-checks the running
interval's weight traffic on the clean admission path) and violates.
Interval 2 meets every class but keeps half the stack resident — less host
memory than the load actually requires. The tuner holds 2 while any
interactive request is live or queued, lifts host-ward through the quiet
diurnal troughs, and retreats (paying the demotion write-back) before the
next interactive admission.

Claims checked:
  * arrivals honored — nothing is admitted before it arrives;
  * zero SLO violations at the autotuned interval, while fixed interval 1
    violates the interactive class;
  * autotuned throughput >= every fixed interval in the range;
  * the autotuned engine time-averages MORE hosted weight bytes than the
    best SLO-clean fixed interval (the paper's objective — the throughput
    tie with fixed-2 is not a wash, it is bought while hosting more);
  * greedy tokens bitwise identical to the best fixed interval, and every
    run passes the trace-conservation audit.

Emits ``reports/BENCH_sustained_load.json``.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import BenchResult, Claim, capture_trace
from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import OffloadPlan
from repro.data.workload import SLOClass, WorkloadConfig, generate_workload
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request
from repro.serving.telemetry import summarize_latency

# Geometry: d256/24-layer reduced model -> ~1.9MB units, ~2.46ms interval-1
# and ~1.23ms interval-2 iterations on the modeled A10 link; HBM fits the
# interval-2 resident set + 24 KV pages (interval 3 does not fit at all,
# so the offline range is {1, 2}); the host tier absorbs spills and the
# tuner's retreat demotions.
D_MODEL, HEADS, LAYERS, D_FF, VOCAB = 256, 4, 24, 1024, 128
MAX_BATCH, MAX_SEQ, PAGE = 4, 64, 16
DEVICE_EXTRA_PAGES, HOST_PAGES = 24, 24
SIZING_INTERVAL = 2                      # HBM anchored at this resident set
FIXED_INTERVALS = [1, 2]                 # the offline range, swept
SEED, N_REQUESTS = 11, 120
# interactive TPOT sits on the performance record's 2ms grid floor — the
# tightest SLO the offline stage can certify at this reduced scale
SLO_CLASSES = (SLOClass("interactive", 0.5, 0.002, weight=0.45),
               SLOClass("standard", 1.0, 0.006, weight=0.35),
               SLOClass("batch", 4.0, 0.02, weight=0.20))


def mk_engine(name: str, autotune: bool = False) -> ServingEngine:
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=D_MODEL,
                        heads=HEADS, layers=LAYERS, d_ff=D_FF, vocab=VOCAB)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    _, units = pattern_info(cfg)
    pb = PAGE * kv_tok
    hbm = OffloadPlan(units, SIZING_INTERVAL).device_bytes(
        costs.unit_weight_bytes(cfg)) + DEVICE_EXTRA_PAGES * pb
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, [1, 2, 4, 8], [16, 32, 64], "prefill")
    rec_d = an.generate_record(slos, [1, 2, 4, 8], [16, 32, 64], "decode")
    return ServingEngine(name, model, A10, rec_p, rec_d, an.layer_times,
                         EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                                      page_size=PAGE, hbm_budget_bytes=hbm,
                                      host_kv_bytes=HOST_PAGES * pb,
                                      autotune=autotune))


def workload(n: int = N_REQUESTS, seed: int = SEED) -> list[Request]:
    # sized so the SLO-clean configurations keep up with the diurnal peaks
    # (transient queueing only) while fixed interval 1 falls behind
    wcfg = WorkloadConfig(
        seed=seed, process="diurnal", rate_per_s=80.0,
        diurnal_amplitude=0.6, diurnal_period_s=0.5,
        mean_rounds=2.0, mean_think_s=0.02,
        system_prompt_len=16, median_turn_len=16, turn_len_sigma=0.0,
        max_prompt_len=48, mean_output_len=10.0, max_output_len=16,
        vocab_size=VOCAB, slo_classes=SLO_CLASSES)
    return generate_workload(wcfg, n)


def clone_requests(reqs: list[Request]) -> list[Request]:
    """Fresh Request objects for each engine run (runs mutate state)."""
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens,
                    ttft_slo_s=r.ttft_slo_s, tpot_slo_s=r.tpot_slo_s,
                    arrival_s=r.arrival_s) for r in reqs]


def hosted_bytes_time_avg(eng: ServingEngine) -> float:
    """Time-averaged weight bytes the engine kept on the host — the
    quantity the paper maximizes subject to the SLOs."""
    num = den = 0.0
    for r in eng.trace.iterations:
        hb = OffloadPlan(eng.num_units, r.interval).host_bytes(
            eng.unit_bytes)
        num += hb * r.dt_s
        den += r.dt_s
    return num / max(den, 1e-12)


def run_engine(reqs: list[Request], name: str,
               fixed_interval: int | None) -> dict:
    eng = mk_engine(name, autotune=fixed_interval is None)
    if fixed_interval is not None:
        assert eng.set_interval(fixed_interval)
    summary = eng.run(clone_requests(reqs), max_iters=200_000)
    per = [r.metrics() for r in eng.finished]
    tokens = sum(m["tokens"] for m in per)
    delays = [m["queue_delay_s"] or 0.0 for m in per]
    ttft_e2e = [m["ttft_e2e_s"] for m in per if m["ttft_e2e_s"] is not None]
    tpots = [t for r in eng.finished for t in r.tpot_s]
    return {
        "name": name,
        "trace": capture_trace(eng),
        "finished": len(eng.finished),
        "rejected": summary["rejected"],
        "tokens": tokens,
        "wall_s": eng.clock_s,
        "throughput_tok_s": tokens / eng.clock_s,
        "tpot_violations": sum(0 if m["tpot_ok"] else 1 for m in per),
        "ttft_violations": sum(0 if m["ttft_ok"] else 1 for m in per),
        "queue_delay_p99_s": summarize_latency(delays)["p99_s"],
        "ttft_e2e": summarize_latency(ttft_e2e),
        "tpot": summarize_latency(tpots),
        "hosted_bytes_avg": hosted_bytes_time_avg(eng),
        "interval_switches": eng.interval_switches,
        "interval_refusals": eng.interval_refusals,
        "tuner": ({"lifts": eng.tuner.lifts, "retreats": eng.tuner.retreats,
                   "refusals": eng.tuner.refusals}
                  if eng.tuner is not None else None),
        "first_arrival_s": summary["first_arrival_s"],
        "first_admit_s": summary["first_admit_s"],
        "idle_wait_s": summary["idle_wait_s"],
        "gen_tokens": {r.rid: list(r.generated) for r in eng.finished},
    }


def run() -> BenchResult:
    reqs = workload()
    auto = run_engine(reqs, "autotuned", None)
    fixed = [run_engine(reqs, f"fixed-{i}", i) for i in FIXED_INTERVALS]
    rows = []
    for side in [auto] + fixed:
        rows.append({
            "engine": side["name"],
            "finished": side["finished"],
            "throughput_tok_s": side["throughput_tok_s"],
            "wall_s": side["wall_s"],
            "tpot_violations": side["tpot_violations"],
            "ttft_violations": side["ttft_violations"],
            "ttft_e2e_p50_s": side["ttft_e2e"]["p50_s"],
            "ttft_e2e_p99_s": side["ttft_e2e"]["p99_s"],
            "tpot_p50_s": side["tpot"]["p50_s"],
            "tpot_p99_s": side["tpot"]["p99_s"],
            "q_delay_p99_s": side["queue_delay_p99_s"],
            "hosted_weight_MB_avg": side["hosted_bytes_avg"] / 1e6,
            "interval_switches": side["interval_switches"],
        })

    honored = (auto["first_admit_s"] is not None
               and auto["first_arrival_s"] > 0
               and auto["first_admit_s"] >= auto["first_arrival_s"]
               and auto["idle_wait_s"] > 0)
    auto_viol = auto["tpot_violations"] + auto["ttft_violations"]
    fixed_viol = {f["name"]: f["tpot_violations"] + f["ttft_violations"]
                  for f in fixed}
    some_fixed_violates = any(v > 0 for v in fixed_viol.values())
    # float-robust >=: the SLO-clean fixed interval is the autotuned
    # engine's own steady-state choice, so exact ties are expected
    tput_ge = all(auto["throughput_tok_s"]
                  >= f["throughput_tok_s"] * (1 - 1e-9) for f in fixed)
    tput_beats_violators = all(
        auto["throughput_tok_s"] > f["throughput_tok_s"]
        for f in fixed if fixed_viol[f["name"]] > 0)
    clean_fixed = [f for f in fixed if fixed_viol[f["name"]] == 0]
    hosts_more = all(auto["hosted_bytes_avg"] > f["hosted_bytes_avg"]
                     for f in clean_fixed)
    best = max(fixed, key=lambda f: f["throughput_tok_s"])
    tokens_exact = auto["gen_tokens"] == best["gen_tokens"]
    all_finished = all(s["finished"] == len(reqs) and s["rejected"] == 0
                       for s in [auto] + fixed)
    audits_ok = all(s["trace"]["audit_ok"] for s in [auto] + fixed)
    audit_checks = sum(s["trace"]["audit_checks"] for s in [auto] + fixed)
    tuner_moved = (auto["tuner"]["lifts"] > 0
                   and auto["tuner"]["retreats"] > 0
                   and auto["interval_switches"] >= 2)

    claims = [
        Claim("fig19 arrival process honored on the modeled clock",
              "requests invisible to the scheduler before arrival_s",
              f"first admit {auto['first_admit_s']:.4f}s >= first arrival "
              f"{auto['first_arrival_s']:.4f}s, idle-wait "
              f"{auto['idle_wait_s']:.3f}s" if honored else "admitted early",
              ok=honored),
        Claim("fig19 zero SLO violations only at the autotuned interval",
              "online stage retreats before the violation a fixed "
              "interval walks into",
              f"autotuned 0; fixed {fixed_viol}" if auto_viol == 0
              and some_fixed_violates else
              f"autotuned {auto_viol}, fixed {fixed_viol}",
              ok=auto_viol == 0 and some_fixed_violates),
        Claim("fig19 autotuned throughput >= every fixed interval in range",
              "adapting inside the offline bracket never costs throughput",
              ", ".join(f"{s['name']}={s['throughput_tok_s']:.0f}tok/s"
                        for s in [auto] + fixed),
              ok=tput_ge and tput_beats_violators),
        Claim("fig19 autotuned hosts more weight bytes than the SLO-clean "
              "fixed choice",
              "paper objective: maximize host memory subject to SLOs",
              ", ".join(f"{s['name']}={s['hosted_bytes_avg']/1e6:.1f}MB"
                        for s in [auto] + fixed)
              + (f"; tuner lifted {auto['tuner']['lifts']}x / retreated "
                 f"{auto['tuner']['retreats']}x" if tuner_moved else
                 "; tuner never moved"),
              ok=hosts_more and tuner_moved),
        Claim("fig19 greedy tokens bitwise identical to best fixed interval",
              "the interval changes timing, never the numbers",
              "identical per-request token streams"
              if tokens_exact else "DIVERGED", ok=tokens_exact),
        Claim("fig19 all requests finish and every audit is clean",
              "sustained load drains with conservation checks intact",
              f"{len(reqs)} requests x {1 + len(fixed)} engines, "
              f"{audit_checks} audit checks" if all_finished and audits_ok
              else "incomplete or audit violations",
              ok=all_finished and audits_ok),
    ]
    res = BenchResult(
        "fig19_sustained_load", rows, claims,
        notes=[f"workload: {N_REQUESTS} requests, diurnal rate 80/s "
               f"amp 0.6 period 0.5s, classes "
               + "/".join(f"{c.name}@{c.tpot_slo_s*1e3:g}ms"
                          for c in SLO_CLASSES),
               "offline range {1,2}: interval 3's resident set does not "
               "fit the HBM budget, NO_OFFLOAD never fits"])
    os.makedirs("reports", exist_ok=True)
    with open("reports/BENCH_sustained_load.json", "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
