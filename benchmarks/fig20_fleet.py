"""Fig. 20 (extension): multi-instance serving fleet with KV-affinity
routing vs round-robin, against a single consolidated instance.

A multi-tenant chat trace (``tenants`` distinct system prompts, multi-round
sessions extending their own history) is served three ways on the modeled
clock: a 2-instance fleet with the KV-affinity router, the same fleet with
round-robin placement, and one "big" instance holding both instances' pooled
capacity (2x batch, 2x device KV pages, 2x host pool). Every engine runs
prefix dedup, the host prefix cache, and preempt-to-host; the fleets keep
cross-instance preemption armed (a parked request's host frames + cursor
serialize into a ``MigrationTicket`` and resume bitwise-exactly on a peer).

The affinity router hashes each arriving prompt ONCE (``prefix_page_keys``)
and places it on the instance already claiming the longest prefix run, so a
tenant's sessions pile onto one instance and their shared pages stay
deduplicated there. Round-robin scatters the same tenant across instances:
each one ends up holding (and spilling, and streaming) its own copy of every
tenant prefix — strictly more KV bytes over the modeled PCIe link for
byte-identical output.

Claims checked:
  * per-request greedy tokens bitwise identical across affinity fleet,
    round-robin fleet, and the consolidated big instance — placement
    composes timing, never numbers;
  * the affinity fleet moves strictly fewer total KV bytes than round-robin
    (PCIe both directions + disk tier + migration payloads);
  * affinity concentrates each tenant on one instance (weighted majority)
    and routes on real prefix hits, not just load;
  * zero TTFT/TPOT violations on the affinity fleet, everything finishes,
    nothing rejected;
  * every per-instance trace audit (I1-I11) passes and the fleet-level
    migration conservation cross-check holds: exported bytes == adopted
    bytes across the fleet.

Emits ``reports/BENCH_fleet.json``.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import BenchResult, Claim, capture_trace
from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import NO_OFFLOAD, OffloadPlan
from repro.data.workload import SLOClass, WorkloadConfig, generate_workload
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.fleet import Fleet
from repro.serving.request import Request

D_MODEL, HEADS, LAYERS, D_FF, VOCAB = 256, 4, 8, 1024, 128
MAX_BATCH, MAX_SEQ, PAGE = 4, 96, 16
# Per-instance device KV: 6 pages ≈ one request's footprint (a 60-80
# token prompt + decode spans 5-6 pages), so concurrent requests spill
# cold pages host-ward and stream them back — the traffic affinity's
# dedup shrinks. Finished prefixes therefore land host-side, where the
# keep-alive cache adopts them (it only adopts HOST frames).
DEVICE_EXTRA_PAGES, HOST_PAGES, CACHE_PAGES = 6, 40, 10
N_INSTANCES = 2
TENANTS = 4
SEED, N_REQUESTS = 20, 48
# generous classes: the claim is byte traffic, not latency headroom
SLO_CLASSES = (SLOClass("standard", 4.0, 0.05, weight=0.7),
               SLOClass("batch", 8.0, 0.2, weight=0.3))


def mk_engine(name: str, scale: int = 1) -> ServingEngine:
    """One fleet instance; ``scale=N_INSTANCES`` builds the consolidated
    big-instance baseline with the pooled capacity of the whole fleet."""
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=D_MODEL,
                        heads=HEADS, layers=LAYERS, d_ff=D_FF, vocab=VOCAB)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    _, units = pattern_info(cfg)
    pb = PAGE * kv_tok
    # weights stay fully resident (NO_OFFLOAD): the link traffic under test
    # is the KV tier's, and totals() counts exactly that
    hbm = OffloadPlan(units, NO_OFFLOAD).device_bytes(
        costs.unit_weight_bytes(cfg)) + scale * DEVICE_EXTRA_PAGES * pb
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, [1, 2, 4, 8], [16, 32, 64], "prefill")
    rec_d = an.generate_record(slos, [1, 2, 4, 8], [16, 32, 64], "decode")
    return ServingEngine(name, model, A10, rec_p, rec_d, an.layer_times,
                         EngineConfig(max_batch=scale * MAX_BATCH,
                                      max_seq=MAX_SEQ, page_size=PAGE,
                                      hbm_budget_bytes=hbm,
                                      host_kv_bytes=scale * HOST_PAGES * pb,
                                      prefix_dedup=True, preemption=True,
                                      host_prefix_cache_pages=scale
                                      * CACHE_PAGES))


def workload(n: int = N_REQUESTS, seed: int = SEED) -> list[Request]:
    wcfg = WorkloadConfig(
        # dense arrivals: per-instance concurrency must exceed the device
        # pool under BOTH routers, or round-robin never spills and there
        # is no traffic for affinity to save
        seed=seed, process="poisson", rate_per_s=3000.0,
        mean_rounds=2.0, mean_think_s=0.0005, tenants=TENANTS,
        # max_prompt_len must cover the longest accumulated history:
        # generate_workload clips prompts to the LAST max_prompt_len
        # tokens, and a clipped history no longer page-aligns with its
        # tenant's system prompt (no shared prefix keys at all)
        system_prompt_len=48, median_turn_len=12, turn_len_sigma=0.3,
        max_prompt_len=80, mean_output_len=8.0, max_output_len=16,
        vocab_size=VOCAB, slo_classes=SLO_CLASSES)
    return generate_workload(wcfg, n)


def clone_requests(reqs: list[Request]) -> list[Request]:
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens,
                    ttft_slo_s=r.ttft_slo_s, tpot_slo_s=r.tpot_slo_s,
                    arrival_s=r.arrival_s, tenant=r.tenant) for r in reqs]


def kv_bytes_moved(link: dict) -> float:
    """Total KV payload over the modeled links: PCIe both directions (which
    subsume streamed + promoted, audited by I1), the NVMe tier, and each
    migration ticket counted once (out == in by fleet conservation)."""
    return (link["pcie_in_bytes"] + link["pcie_out_bytes"]
            + link["disk_in_bytes"] + link["disk_out_bytes"]
            + link["mig_out_bytes"])


def run_fleet(reqs: list[Request], policy: str, prefix: str) -> dict:
    engines = [mk_engine(f"{prefix}{i}") for i in range(N_INSTANCES)]
    fleet = Fleet(engines, policy=policy)
    out = fleet.run(clone_requests(reqs), max_iters=200_000)
    ok, violations = fleet.audit()
    finished = [r for e in engines for r in e.finished]
    return {
        "name": prefix, "fleet": fleet, "summary": out,
        "audit_ok": ok, "violations": violations,
        "audit_checks": sum(capture_trace(e)["audit_checks"]
                            for e in engines),
        "bytes_moved": kv_bytes_moved(out["link_bytes"]),
        "per_rid_instance": {r.rid: e.name for e in engines
                             for r in e.finished},
        "gen_tokens": {r.rid: list(r.generated) for r in finished},
        "viol": sum(0 if m["ttft_ok"] and m["tpot_ok"] else 1
                    for m in out["per_request"]),
    }


def run_big(reqs: list[Request]) -> dict:
    eng = mk_engine("big", scale=N_INSTANCES)
    summary = eng.run(clone_requests(reqs), max_iters=200_000)
    trace = capture_trace(eng)
    per = [r.metrics() for r in eng.finished]
    return {
        "name": "big", "summary": summary, "audit_ok": trace["audit_ok"],
        "violations": trace["violations"],
        "audit_checks": trace["audit_checks"],
        "bytes_moved": kv_bytes_moved(eng.trace.totals()),
        "finished": len(eng.finished), "rejected": len(eng.rejected),
        "tokens": sum(m["tokens"] for m in per),
        "wall_s": eng.clock_s,
        "gen_tokens": {r.rid: list(r.generated) for r in eng.finished},
        "viol": sum(0 if m["ttft_ok"] and m["tpot_ok"] else 1 for m in per),
    }


def tenant_concentration(reqs: list[Request], placed: dict) -> float:
    """Weighted fraction of each tenant's requests landing on that tenant's
    modal instance — 1.0 means perfect per-tenant partitioning."""
    tenant_of = {r.rid: r.tenant for r in reqs}
    per_tenant: dict[int, dict[str, int]] = {}
    for rid, inst in placed.items():
        per_tenant.setdefault(tenant_of[rid], {}).setdefault(inst, 0)
        per_tenant[tenant_of[rid]][inst] += 1
    hit = sum(max(c.values()) for c in per_tenant.values())
    return hit / max(sum(sum(c.values()) for c in per_tenant.values()), 1)


def run() -> BenchResult:
    reqs = workload()
    aff = run_fleet(reqs, "affinity", "aff")
    rr = run_fleet(reqs, "round_robin", "rr")
    big = run_big(reqs)

    rows = []
    for side in (aff, rr):
        s = side["summary"]
        rows.append({
            "config": f"fleet-{s['router']}",
            "instances": s["instances"],
            "finished": s["finished"], "rejected": s["rejected"],
            "wall_s": s["wall_modeled_s"],
            "throughput_tok_s": s["throughput_tok_s"],
            "kv_bytes_moved_MB": side["bytes_moved"] / 1e6,
            "slo_violations": side["viol"],
            "migrations": s["migrations"],
            "preemptions": s["preemptions"],
            "ttft_p99_s": s["ttft"]["p99_s"],
            "tpot_p99_s": s["tpot"]["p99_s"],
        })
    rows.append({
        "config": "big-instance", "instances": 1,
        "finished": big["finished"], "rejected": big["rejected"],
        "wall_s": big["wall_s"],
        "throughput_tok_s": big["tokens"] / big["wall_s"],
        "kv_bytes_moved_MB": big["bytes_moved"] / 1e6,
        "slo_violations": big["viol"],
        "migrations": 0, "preemptions": None,
        "ttft_p99_s": None, "tpot_p99_s": None,
    })

    tokens_exact = (aff["gen_tokens"] == big["gen_tokens"]
                    == rr["gen_tokens"])
    fewer_bytes = aff["bytes_moved"] < rr["bytes_moved"]
    conc = tenant_concentration(reqs, aff["per_rid_instance"])
    conc_rr = tenant_concentration(reqs, rr["per_rid_instance"])
    hits_used = sum(max(d.hits) for d in aff["fleet"].router.decisions)
    all_done = all(s["summary"]["finished"] == len(reqs)
                   and s["summary"]["rejected"] == 0 for s in (aff, rr)) \
        and big["finished"] == len(reqs) and big["rejected"] == 0
    audits_ok = aff["audit_ok"] and rr["audit_ok"] and big["audit_ok"]
    mig_conserved = all(
        not any("fleet:" in v for v in s["violations"]) for s in (aff, rr))

    claims = [
        Claim("fig20 greedy tokens bitwise identical across placements",
              "routing and migration compose timing, never numbers",
              "affinity == round_robin == big instance, per request"
              if tokens_exact else "DIVERGED", ok=tokens_exact),
        Claim("fig20 affinity moves strictly fewer KV bytes than "
              "round-robin",
              "co-locating a tenant's sessions dedups their shared pages "
              "once per fleet, not once per instance",
              f"affinity {aff['bytes_moved']/1e6:.2f}MB < round_robin "
              f"{rr['bytes_moved']/1e6:.2f}MB "
              f"({1 - aff['bytes_moved']/max(rr['bytes_moved'], 1):.0%} "
              "less)", ok=fewer_bytes),
        Claim("fig20 affinity partitions tenants across instances",
              "prefix hits steer same-tenant sessions to one instance",
              f"tenant concentration {conc:.0%} (round_robin {conc_rr:.0%})"
              f", {hits_used} claimed prefix pages across decisions",
              ok=conc >= 0.75 and conc > conc_rr and hits_used > 0),
        Claim("fig20 zero SLO violations on the affinity fleet",
              "affinity admission respects per-class TTFT/TPOT",
              f"{aff['viol']} violations, {len(reqs)} requests finished"
              if all_done else "incomplete", ok=aff["viol"] == 0 and all_done),
        Claim("fig20 every audit passes incl. fleet migration conservation",
              "I1-I11 per instance; exported bytes == adopted bytes "
              "fleet-wide",
              f"{aff['audit_checks'] + rr['audit_checks'] + big['audit_checks']}"
              f" checks, {aff['summary']['migrations']} migrations "
              f"({aff['summary']['migrated_bytes']}B)"
              if audits_ok and mig_conserved else
              str((aff["violations"] + rr["violations"]
                   + big["violations"])[:5]),
              ok=audits_ok and mig_conserved),
    ]
    res = BenchResult(
        "fig20_fleet", rows, claims,
        notes=[f"workload: {N_REQUESTS} requests, {TENANTS} tenants, "
               f"poisson 3000/s, {N_INSTANCES}-instance fleet vs pooled "
               "big instance",
               f"per instance: {DEVICE_EXTRA_PAGES} device KV pages "
               f"(< batch working set), {HOST_PAGES} host, "
               f"{CACHE_PAGES} prefix-cache pages"])
    os.makedirs("reports", exist_ok=True)
    with open("reports/BENCH_fleet.json", "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
