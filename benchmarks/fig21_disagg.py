"""Fig. 21 (extension): disaggregated prefill/decode fleet via the PEER
tier, against the symmetric affinity fleet and one pooled instance.

The same chat trace is served three ways on the modeled clock: a
disaggregated fleet (one prefill-role + one decode-role instance — prompts
route to the prefill side, every completed prefill's KV pages hand off
through the PEER tier to the decode side after its scheduler certifies the
transfer), a 2-instance symmetric affinity fleet, and one pooled instance
with the combined capacity. Shape-bucketed prefill makes KV pages
placement-independent, so disaggregation must compose timing, never
numbers.

Claims checked:
  * per-request greedy tokens bitwise identical across the disaggregated
    fleet, the symmetric fleet, and the pooled instance;
  * the disaggregation is real: every request prefills on the prefill
    instance (TTFT charged there) and, when it has a decode phase, decodes
    to completion on the decode instance (TPOT-plus-transfer charged
    there); single-token requests complete at prefill;
  * handoffs ride the PEER tier's own concurrent link channel — zero
    synchronous migration stalls (``mig_wait``), transfer overlaps the
    exporter's next prefill;
  * zero TTFT/TPOT violations everywhere, everything finishes;
  * every per-instance trace audit (I1-I12) passes and the fleet-level
    handoff conservation cross-check holds: bytes exported == bytes
    imported, per link, over the full trace.

Emits ``reports/BENCH_disagg.json``.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import BenchResult, Claim, capture_trace
from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import NO_OFFLOAD, OffloadPlan
from repro.data.workload import SLOClass, WorkloadConfig, generate_workload
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.fleet import Fleet
from repro.serving.request import Request

D_MODEL, HEADS, LAYERS, D_FF, VOCAB = 256, 4, 8, 1024, 128
MAX_BATCH, MAX_SEQ, PAGE = 4, 96, 16
DEVICE_EXTRA_PAGES, HOST_PAGES = 8, 48
SEED, N_REQUESTS = 31, 32
# generous classes: the claims are placement-composability + conservation
SLO_CLASSES = (SLOClass("standard", 4.0, 0.05, weight=0.7),
               SLOClass("batch", 8.0, 0.2, weight=0.3))


def mk_engine(name: str, role: str = "mixed", scale: int = 1
              ) -> ServingEngine:
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=D_MODEL,
                        heads=HEADS, layers=LAYERS, d_ff=D_FF, vocab=VOCAB)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    _, units = pattern_info(cfg)
    pb = PAGE * kv_tok
    hbm = OffloadPlan(units, NO_OFFLOAD).device_bytes(
        costs.unit_weight_bytes(cfg)) + scale * DEVICE_EXTRA_PAGES * pb
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, [1, 2, 4, 8], [16, 32, 64], "prefill")
    rec_d = an.generate_record(slos, [1, 2, 4, 8], [16, 32, 64], "decode")
    return ServingEngine(name, model, A10, rec_p, rec_d, an.layer_times,
                         EngineConfig(max_batch=scale * MAX_BATCH,
                                      max_seq=MAX_SEQ, page_size=PAGE,
                                      hbm_budget_bytes=hbm,
                                      host_kv_bytes=scale * HOST_PAGES * pb,
                                      preemption=True, role=role))


def workload(n: int = N_REQUESTS, seed: int = SEED) -> list[Request]:
    wcfg = WorkloadConfig(
        seed=seed, process="poisson", rate_per_s=3000.0,
        mean_rounds=1.5, mean_think_s=0.0005, tenants=3,
        system_prompt_len=32, median_turn_len=12, turn_len_sigma=0.3,
        max_prompt_len=72, mean_output_len=8.0, max_output_len=12,
        vocab_size=VOCAB, slo_classes=SLO_CLASSES)
    return generate_workload(wcfg, n)


def clone_requests(reqs: list[Request]) -> list[Request]:
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens,
                    ttft_slo_s=r.ttft_slo_s, tpot_slo_s=r.tpot_slo_s,
                    arrival_s=r.arrival_s, tenant=r.tenant) for r in reqs]


def run_fleet(reqs: list[Request], engines: list[ServingEngine],
              name: str) -> dict:
    fleet = Fleet(engines, policy="affinity")
    out = fleet.run(clone_requests(reqs), max_iters=200_000)
    ok, violations = fleet.audit()
    finished = [r for e in engines for r in e.finished]
    return {
        "name": name, "fleet": fleet, "summary": out,
        "audit_ok": ok, "violations": violations,
        "audit_checks": sum(capture_trace(e)["audit_checks"]
                            for e in engines),
        "gen_tokens": {r.rid: list(r.generated) for r in finished},
        "viol": sum(0 if m["ttft_ok"] and m["tpot_ok"] else 1
                    for m in out["per_request"]),
        "mig_wait_s": sum(e.mig_wait_total_s for e in engines),
    }


def run_pooled(reqs: list[Request]) -> dict:
    eng = mk_engine("pooled", scale=2)
    summary = eng.run(clone_requests(reqs), max_iters=200_000)
    trace = capture_trace(eng)
    per = [r.metrics() for r in eng.finished]
    return {
        "name": "pooled", "summary": summary,
        "audit_ok": trace["audit_ok"], "violations": trace["violations"],
        "audit_checks": trace["audit_checks"],
        "finished": len(eng.finished), "tokens": sum(m["tokens"]
                                                     for m in per),
        "wall_s": eng.clock_s,
        "gen_tokens": {r.rid: list(r.generated) for r in eng.finished},
        "viol": sum(0 if m["ttft_ok"] and m["tpot_ok"] else 1 for m in per),
    }


def run() -> BenchResult:
    reqs = workload()
    # Role-typed sizing: the decode instance carries the big KV pool
    # (scale=2), the prefill instance only the staging it hands off from.
    dis = run_fleet(reqs, [mk_engine("p0", role="prefill"),
                           mk_engine("d0", role="decode", scale=2)],
                    "disagg")
    aff = run_fleet(reqs, [mk_engine("a0"), mk_engine("a1")], "affinity")
    pooled = run_pooled(reqs)

    rows = []
    for side in (dis, aff):
        s = side["summary"]
        rows.append({
            "config": side["name"], "instances": s["instances"],
            "finished": s["finished"], "wall_s": s["wall_modeled_s"],
            "throughput_tok_s": s["throughput_tok_s"],
            "handoffs": s["handoffs"],
            "handoff_MB": s["handoff_bytes"] / 1e6,
            "reroutes": s["reroutes"],
            "slo_violations": side["viol"],
            "ttft_p99_s": s["ttft"]["p99_s"],
            "tpot_p99_s": s["tpot"]["p99_s"],
        })
    rows.append({
        "config": "pooled", "instances": 1,
        "finished": pooled["finished"], "wall_s": pooled["wall_s"],
        "throughput_tok_s": pooled["tokens"] / pooled["wall_s"],
        "handoffs": 0, "handoff_MB": 0.0, "reroutes": 0,
        "slo_violations": pooled["viol"],
        "ttft_p99_s": None, "tpot_p99_s": None,
    })

    tokens_exact = (dis["gen_tokens"] == aff["gen_tokens"]
                    == pooled["gen_tokens"])
    per_inst = dis["summary"]["per_instance"]
    # a single-token request IS its prefill: TTFT is its whole life, there
    # is no decode phase to hand off — it completes on the prefill side
    n_decode = sum(1 for r in reqs if r.max_new_tokens > 1)
    n_prefill_only = len(reqs) - n_decode
    split_real = (per_inst["p0"]["finished"] == n_prefill_only
                  and per_inst["d0"]["finished"] == n_decode
                  and dis["summary"]["handoffs"] == n_decode
                  and per_inst["p0"]["handoffs_out"] == n_decode
                  and per_inst["d0"]["handoffs_in"] == n_decode)
    no_stall = (dis["mig_wait_s"] == 0.0
                and dis["summary"]["migrations"] == 0
                and dis["summary"]["handoff_bytes"] > 0)
    all_done = (dis["summary"]["finished"] == aff["summary"]["finished"]
                == pooled["finished"] == len(reqs))
    no_viol = dis["viol"] == aff["viol"] == pooled["viol"] == 0
    audits_ok = dis["audit_ok"] and aff["audit_ok"] and pooled["audit_ok"]
    conserved = not any("fleet:" in v
                        for s in (dis, aff) for v in s["violations"])

    claims = [
        Claim("fig21 greedy tokens bitwise identical across disagg / "
              "affinity / pooled",
              "role-typed placement and PEER handoff compose timing, "
              "never numbers",
              "disagg == affinity == pooled, per request"
              if tokens_exact else "DIVERGED", ok=tokens_exact),
        Claim("fig21 the split is real: prefill-side TTFT, decode-side "
              "completion",
              "router binds prompts to the prefill role; every request "
              "with decode work hands off peer-ward after decode-side "
              "certification (single-token requests ARE their prefill)",
              f"{dis['summary']['handoffs']} handoffs for {n_decode} "
              f"decode-phase requests ({n_prefill_only} prefill-complete); "
              f"p0 finished {per_inst['p0']['finished']}, d0 finished "
              f"{per_inst['d0']['finished']}", ok=split_real),
        Claim("fig21 handoffs ride the PEER link channel, no synchronous "
              "stalls",
              "transfer overlaps the exporter's next prefill (peer_s "
              "term), unlike emergency migration's mig_wait",
              f"{dis['summary']['handoff_bytes']}B handed off with "
              f"{dis['mig_wait_s']:.3g}s mig_wait and "
              f"{dis['summary']['migrations']} migrations", ok=no_stall),
        Claim("fig21 zero SLO violations everywhere",
              "decode-side certification keeps every adopted TPOT budget",
              f"disagg {dis['viol']} / affinity {aff['viol']} / pooled "
              f"{pooled['viol']} violations, all {len(reqs)} finished"
              if all_done else "incomplete", ok=no_viol and all_done),
        Claim("fig21 handoff conservation clean over the full trace",
              "I1-I12 per instance; bytes exported == bytes imported per "
              "link (Fleet.audit cross-check)",
              f"{dis['audit_checks'] + aff['audit_checks'] + pooled['audit_checks']}"
              f" checks, {dis['summary']['handoff_bytes']}B conserved"
              if audits_ok and conserved else
              str((dis["violations"] + aff["violations"]
                   + pooled["violations"])[:5]),
              ok=audits_ok and conserved),
    ]
    res = BenchResult(
        "fig21_disagg", rows, claims,
        notes=[f"workload: {N_REQUESTS} requests, poisson 3000/s; "
               "1 prefill + 1 decode instance vs 2-instance symmetric "
               "fleet vs pooled instance",
               f"role-typed sizing: prefill {DEVICE_EXTRA_PAGES} device / "
               f"{HOST_PAGES} host KV pages (staging only), decode 2x both "
               "(it owns the resident KV); peer link 16 GB/s"])
    os.makedirs("reports", exist_ok=True)
    with open("reports/BENCH_disagg.json", "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return res


if __name__ == "__main__":
    print(run().render())
