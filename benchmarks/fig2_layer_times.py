"""Paper Fig. 2: (a) serving latency under DeepSpeed (normalized to SLO);
(b) per-layer compute vs transfer time. Model: Qwen2-beta-7B, seq 256,
batch 4 — plus the other three paper models for the (a) panel.

Paper numbers: transfer/compute = 3.5x (prefill) and 13.8x (decode);
DeepSpeed inflates serving latency by up to 9.5x.
"""
from __future__ import annotations

from benchmarks.common import BenchResult, Claim, times_for
from repro.configs.paper_models import (LLAMA2_13B, OPT_6_7B, OPT_13B,
                                        QWEN2_BETA_7B)
from repro.core.simulator import schedule_deepspeed, simulate_iteration

MODELS = [QWEN2_BETA_7B, OPT_6_7B, OPT_13B, LLAMA2_13B]
BATCH, SEQ = 4, 256
SLO_FACTOR = 1.2  # SLO = 1.2x the naive (no-offload) latency


def run() -> BenchResult:
    rows = []
    ratios = {}
    ds_norm = {}
    for cfg in MODELS:
        for phase in ("prefill", "decode"):
            t = times_for(cfg, BATCH, SEQ, phase)
            ratio = t.t_transfer_s / t.t_compute_s
            naive = t.t_iter_no_offload_s
            sched = schedule_deepspeed([t.t_compute_s] * t.num_layers,
                                       t.t_transfer_s, t.t_rest_s)
            ds = simulate_iteration(sched)["latency_s"]
            slo = SLO_FACTOR * naive
            rows.append({
                "model": cfg.name, "phase": phase,
                "t_compute_ms": t.t_compute_s * 1e3,
                "t_transfer_ms": t.t_transfer_s * 1e3,
                "transfer_over_compute": ratio,
                "naive_iter_ms": naive * 1e3,
                "deepspeed_iter_ms": ds * 1e3,
                "deepspeed_over_slo": ds / slo,
            })
            if cfg is QWEN2_BETA_7B:
                ratios[phase] = ratio
            ds_norm[(cfg.name, phase)] = ds / slo

    worst = max(ds_norm.values())
    claims = [
        Claim("fig2b transfer/compute (prefill, qwen2-7b)",
              "3.5x", f"{ratios['prefill']:.2f}x",
              ok=2.0 < ratios["prefill"] < 6.0,
              note="calibration target of A10_CALIBRATED"),
        Claim("fig2b transfer/compute (decode, qwen2-7b)",
              "13.8x", f"{ratios['decode']:.2f}x",
              ok=8.0 < ratios["decode"] < 20.0,
              note="calibration target of A10_CALIBRATED"),
        Claim("fig2a DeepSpeed latency vs SLO",
              "up to 9.5x", f"up to {worst:.2f}x",
              ok=worst > 3.0,
              note="transfer-bound: keeping one layer on device violates "
                   "SLOs for every evaluated model"),
    ]
    return BenchResult("fig2_layer_times", rows, claims)


if __name__ == "__main__":
    print(run().render())
