"""Paper Fig. 4: the serving latency FlexGen *estimates* (peak-GPU-performance
model) vs the actual one. Model: OPT-13B.

Paper claim (Observation #2): the peak-FLOPs estimate is much shorter than
the real latency, so FlexGen under-offloads to stay safe.
"""
from __future__ import annotations

from benchmarks.common import BenchResult, Claim, times_for
from repro.configs.paper_models import OPT_13B
from repro.core import costs
from repro.core.hardware import A10

BATCHES = [1, 2, 4, 8, 16, 32]
SEQ = 256


def run() -> BenchResult:
    rows = []
    factors = []
    for phase in ("prefill", "decode"):
        for b in BATCHES:
            t = times_for(OPT_13B, b, SEQ, phase)           # calibrated model
            actual = t.t_iter_no_offload_s
            # FlexGen's estimator: layer FLOPs / peak FLOP/s, no memory term.
            sq = SEQ if phase == "prefill" else 1
            fl = [costs.layer_flops(OPT_13B, b, sq, SEQ, j)
                  for j in range(OPT_13B.num_layers)]
            est = sum(A10.peak_exec_time(f) for f in fl)
            rows.append({
                "phase": phase, "batch": b,
                "estimated_ms": est * 1e3,
                "actual_ms": actual * 1e3,
                "underestimation": actual / est,
            })
            factors.append(actual / est)

    claims = [
        Claim("fig4 peak-FLOPs estimate vs actual latency",
              "estimate much shorter than actual",
              f"actual is {min(factors):.1f}x..{max(factors):.1f}x the estimate",
              ok=min(factors) > 1.0,
              note="decode is memory-bound: peak-FLOPs misses the HBM term "
                   "entirely; prefill misses achievable-MFU derating"),
    ]
    return BenchResult("fig4_estimation_error", rows, claims)


if __name__ == "__main__":
    print(run().render())
