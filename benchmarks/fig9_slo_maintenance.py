"""Paper §5.2 / Fig. 9 (+ Fig. 3): SLO maintenance of Select-N vs DeepSpeed.

Models: OPT-6.7B and Qwen2-beta-7B, prefill instance batch 32, decode
instance batch 128 (the paper's disaggregated setup). SLOs are normalized to
the naive (no-offload) latency; the sweep sets the target at +10%..+50%.

Paper claims: Select-N keeps TTFT/TPOT at or below every SLO by re-picking
the interval; DeepSpeed exceeds the SLO by ~8.08x and loses 6.8x..8.23x
throughput; Fig. 3: DeepSpeed throughput is up to 8.2x lower across batches.
"""
from __future__ import annotations

from benchmarks.common import BenchResult, Claim, analyzer_for, interval_str
from repro.configs.paper_models import OPT_6_7B, QWEN2_BETA_7B
from repro.core.interval import NO_OFFLOAD, iter_time_with_interval
from repro.core.slo import SLO_GRANULARITY_S

MODELS = [OPT_6_7B, QWEN2_BETA_7B]
PREFILL_BATCH, DECODE_BATCH = 32, 128
SEQ = 256
SLO_PCTS = [0.10, 0.20, 0.30, 0.40, 0.50]
FIG3_BATCHES = [1, 4, 16, 64]


def run() -> BenchResult:
    rows = []
    selectn_ok = True
    ds_ratios, thr_ratios = [], []
    for cfg in MODELS:
        an = analyzer_for(cfg)
        for phase, batch in (("prefill", PREFILL_BATCH),
                             ("decode", DECODE_BATCH)):
            times = an.layer_times(batch, SEQ, phase)
            naive = times.t_iter_no_offload_s
            slos = [(1 + p) * naive for p in SLO_PCTS]
            # two-stage flow: offline record, then O(1) lookup per request
            rec = an.generate_record(slos, [batch], [SEQ], phase)
            for pct, slo in zip(SLO_PCTS, slos):
                iv = rec.lookup(slo, batch, SEQ)
                ach = iter_time_with_interval(times, iv)
                ds = iter_time_with_interval(times, 1)
                rows.append({
                    "model": cfg.name, "phase": phase, "slo_pct": pct,
                    "interval": interval_str(iv),
                    "selectn_over_slo": ach / slo,
                    "deepspeed_over_slo": ds / slo,
                    "thr_gain_vs_deepspeed": ds / ach,
                })
                selectn_ok &= ach <= slo * (1 + 1e-9) + SLO_GRANULARITY_S
                if phase == "decode":
                    ds_ratios.append(ds / slo)
                    thr_ratios.append(ds / ach)

    # Fig. 3: decode throughput vs batch size, Select-N (SLO +30%) vs DeepSpeed
    fig3 = []
    for b in FIG3_BATCHES:
        times = analyzer_for(QWEN2_BETA_7B).layer_times(b, SEQ, "decode")
        slo = 1.3 * times.t_iter_no_offload_s
        rec = analyzer_for(QWEN2_BETA_7B).generate_record(
            [slo], [b], [SEQ], "decode")
        iv = rec.lookup(slo, b, SEQ)
        t_sn = iter_time_with_interval(times, iv)
        t_ds = iter_time_with_interval(times, 1)
        fig3.append(t_sn and b / t_sn / (b / t_ds))
        rows.append({
            "model": "qwen2-beta-7b", "phase": "fig3_decode",
            "slo_pct": 0.30, "interval": interval_str(iv),
            "selectn_over_slo": b / t_sn,          # tok/s (reuse column)
            "deepspeed_over_slo": b / t_ds,        # tok/s
            "thr_gain_vs_deepspeed": t_ds / t_sn,
        })

    claims = [
        Claim("fig9 Select-N meets every SLO",
              "latency/SLO <= 1 for all setups",
              "all <= 1" if selectn_ok else "violations found",
              ok=selectn_ok),
        Claim("fig9 DeepSpeed exceeds decode SLO",
              "8.08x", f"{max(ds_ratios):.2f}x",
              ok=max(ds_ratios) > 4.0),
        Claim("fig9 decode throughput vs DeepSpeed",
              "6.8x..8.23x", f"{min(thr_ratios):.2f}x..{max(thr_ratios):.2f}x",
              ok=max(thr_ratios) > 4.0),
        Claim("fig3 DeepSpeed throughput drop (batch sweep)",
              "up to 8.2x", f"up to {max(fig3):.2f}x",
              ok=max(fig3) > 4.0),
    ]
    return BenchResult("fig9_slo_maintenance", rows, claims)


if __name__ == "__main__":
    print(run().render())
