"""§Roofline: three-term roofline analysis from the compiled dry-run.

For every (arch x shape x mesh) cell of reports/dryrun_*.json:

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective wire bytes / (chips x link_bw)

All three in seconds-per-step, per device (the dry-run records per-device
FLOPs/bytes; collective bytes are per-device wire bytes under ring models).
The dominant term is the bottleneck; roofline_fraction = compute / dominant
(1.0 = compute-bound = as good as the hardware allows for that algorithm);
mfu_bound = MODEL_FLOPS / (chips x peak x dominant) is the model-flops
utilization the step would achieve if it ran exactly at the roofline bound.

  PYTHONPATH=src python -m benchmarks.roofline [--report reports/dryrun_single.json ...]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import BenchResult, Claim
from repro.core.hardware import TPU_V5E

PEAK = TPU_V5E.peak_flops          # 197e12 bf16 / chip
HBM_BW = TPU_V5E.hbm_bw            # 819e9 B/s
ICI_BW = TPU_V5E.ici_bw            # 50e9 B/s per link


def analyze_record(r: dict) -> dict | None:
    if not r.get("ok"):
        return None
    ndev = {"16x16": 256, "2x16x16": 512}[r["mesh"]]
    fl = r["flops_per_device"]
    by = r["bytes_accessed_per_device"]
    coll = r["collectives"]["bytes"].get("total", 0)
    t_comp = fl / PEAK
    t_mem = by / HBM_BW
    t_coll = coll / ICI_BW
    dom_t = max(t_comp, t_mem, t_coll)
    dom = {t_comp: "compute", t_mem: "memory", t_coll: "collective"}[dom_t]
    model_fl = r.get("model_flops_global", 0.0)
    hlo_total = fl * ndev
    out = {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "interval": r.get("interval"),
        "compute_ms": t_comp * 1e3,
        "memory_ms": t_mem * 1e3,
        "collective_ms": t_coll * 1e3,
        "bound_ms": dom_t * 1e3,
        "dominant": dom,
        "roofline_fraction": t_comp / dom_t if dom_t > 0 else 0.0,
        "model_flops_over_hlo": model_fl / hlo_total if hlo_total else 0.0,
        "mfu_bound": (model_fl / (ndev * PEAK * dom_t)) if dom_t > 0 else 0.0,
        "peak_GiB": r["memory"]["peak_bytes"] / 2**30,
    }
    return out


def load(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def suggestion(c: dict) -> str:
    """One sentence: what would move the dominant term down."""
    if c["dominant"] == "collective":
        return ("shrink/overlap collectives: reshard to cut all-gathers, "
                "or overlap them with layer compute")
    if c["dominant"] == "memory":
        if c["shape"].startswith("decode") or c["shape"].startswith("long"):
            return ("decode is weight/KV-bandwidth bound: shard KV further, "
                    "shrink per-device bytes (quantize KV, larger model axis)")
        return "reduce HBM traffic: fuse ops, avoid remat re-reads"
    return "compute-bound: already at the algorithmic roofline; raise MFU"


HILLCLIMB_CELLS = [
    ("grok-1-314b", "decode_32k"), ("dbrx-132b", "decode_32k"),
    ("jamba-1.5-large-398b", "decode_32k"),
    ("seamless-m4t-medium", "train_4k"), ("xlstm-125m", "train_4k"),
    ("deepseek-7b", "decode_32k"),
]


def before_after() -> list[dict]:
    """§Perf summary rows: baseline (paper-faithful rules) vs optimized, for
    the hillclimbed cells, single-pod mesh."""
    base_p = "reports/dryrun_single_baseline.json"
    opt_p = "reports/dryrun_single.json"
    if not (os.path.exists(base_p) and os.path.exists(opt_p)):
        return []
    def index(path):
        out = {}
        for r in load(path):
            c = analyze_record(r)
            if c and c["mesh"] == "16x16" and not c["interval"]:
                out[(c["arch"], c["shape"])] = c
        return out
    base, opt = index(base_p), index(opt_p)
    rows = []
    for key in HILLCLIMB_CELLS:
        b, o = base.get(key), opt.get(key)
        if not (b and o):
            continue
        rows.append({
            "arch": key[0], "shape": key[1],
            "bound_before_ms": b["bound_ms"], "bound_after_ms": o["bound_ms"],
            "speedup": b["bound_ms"] / o["bound_ms"] if o["bound_ms"] else 0,
            "dominant_before": b["dominant"], "dominant_after": o["dominant"],
            "frac_before": b["roofline_fraction"],
            "frac_after": o["roofline_fraction"],
        })
    return rows


def run(paths: list[str] | None = None) -> BenchResult:
    paths = paths or ["reports/dryrun_single.json", "reports/dryrun_multi.json",
                      "reports/dryrun_offload.json"]
    cells = []
    seen = set()
    for p in paths:
        if not os.path.exists(p):
            continue
        for r in load(p):
            c = analyze_record(r)
            if c:
                key = (c["arch"], c["shape"], c["mesh"], c["interval"])
                if key in seen:
                    continue
                seen.add(key)
                c["suggestion"] = suggestion(c)
                cells.append(c)

    single = [c for c in cells if c["mesh"] == "16x16" and not c["interval"]]
    n_bound = {}
    for c in single:
        n_bound[c["dominant"]] = n_bound.get(c["dominant"], 0) + 1
    # decode shapes are inherently bandwidth-bound (roofline fraction ~0 by
    # algorithm, not by implementation); rank the batch-compute shapes
    worst = sorted((c for c in single
                    if c["shape"] in ("train_4k", "prefill_32k")),
                   key=lambda c: c["roofline_fraction"])[:3]
    claims = [
        Claim("roofline coverage (single-pod baseline cells)",
              "all 33 runnable cells analyzed", f"{len(single)} cells",
              ok=len(single) >= 33),
        Claim("bottleneck census",
              "per-cell dominant term identified",
              ", ".join(f"{k}:{v}" for k, v in sorted(n_bound.items())),
              ok=True),
        Claim("worst roofline fractions (hillclimb candidates)",
              "-", "; ".join(f"{c['arch']}/{c['shape']}="
                             f"{c['roofline_fraction']:.3f}" for c in worst),
              ok=True),
    ]
    ba = before_after()
    if ba:
        best = max(ba, key=lambda r: r["speedup"])
        claims.append(Claim(
            "§Perf hillclimb (baseline vs optimized bound)",
            "-", "; ".join(f"{r['arch']}/{r['shape']}: "
                           f"{r['bound_before_ms']:.0f}->"
                           f"{r['bound_after_ms']:.0f}ms "
                           f"({r['speedup']:.1f}x)" for r in ba),
            ok=best["speedup"] > 1.5))
    os.makedirs("reports", exist_ok=True)
    with open("reports/roofline.json", "w") as f:
        json.dump({"cells": cells, "before_after": ba}, f, indent=1)
    return BenchResult("roofline", cells, claims,
                       notes=["written to reports/roofline.json"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", nargs="*", default=None)
    args = ap.parse_args()
    print(run(args.report).render())


if __name__ == "__main__":
    main()
