"""Benchmark driver: one module per paper table/figure + the roofline
analysis. Prints each benchmark's rows (CSV) and paper-claim checks, and
writes reports/bench_results.json plus one reports/BENCH_<name>.json per
module (the fig/table ordinal stripped), so every figure's numbers land
as a standalone artifact whether or not the module writes its own.

  PYTHONPATH=src python -m benchmarks.run [--only fig9 ...]
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import re
import time
import traceback

MODULES = [
    "fig2_layer_times",
    "fig4_estimation_error",
    "fig9_slo_maintenance",
    "fig10_memory_throughput",
    "table1_record",
    "fig11_interval_sweep",
    "fig12_contention",
    "fig13_large_models",
    "fig14_max_length",
    "fig15_kv_tiering",
    "fig16_prefix_dedup",
    "fig17_preemption",
    "fig18_disk_tier",
    "fig19_sustained_load",
    "fig20_fleet",
    "fig21_disagg",
    "roofline",
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="substring filter on module names")
    args = ap.parse_args(argv)

    selected = [m for m in MODULES
                if not args.only or any(o in m for o in args.only)]
    results = []
    n_claims = n_pass = 0
    t00 = time.time()
    os.makedirs("reports", exist_ok=True)
    for name in selected:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            res = mod.run()
        except Exception:  # noqa: BLE001 — report, keep going
            print(f"=== {name} === FAILED\n{traceback.format_exc()[-1500:]}")
            results.append({"name": name,
                            "error": traceback.format_exc()[-1500:]})
            continue
        dt = time.time() - t0
        print(res.render())
        print(f"  ({dt:.1f}s)\n")
        results.append(res.to_json())
        stem = re.sub(r"^(fig|table)\d+_", "", res.name)
        with open(f"reports/BENCH_{stem}.json", "w") as f:
            json.dump(res.to_json(), f, indent=1)
        for c in res.claims:
            n_claims += 1
            n_pass += int(c.ok)

    with open("reports/bench_results.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"benchmarks: {len(results)} modules, {n_pass}/{n_claims} paper "
          f"claims reproduced (DIFFs are documented modeling deviations), "
          f"{time.time() - t00:.0f}s -> reports/bench_results.json")


if __name__ == "__main__":
    main()
