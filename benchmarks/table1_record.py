"""Paper Table 1: the performance record — optimal offloading interval over a
(batch x seq) power-of-two grid for a given SLO. Model: OPT-6.7B (decode).

Paper trend: the interval is non-increasing along both axes (more compute
per layer hides more transfer), reaching 1 once a single layer's compute
exceeds its transfer time; past that the record need not be enumerated.
"""
from __future__ import annotations

from benchmarks.common import BenchResult, Claim, analyzer_for, interval_str
from repro.configs.paper_models import OPT_6_7B
from repro.core.interval import NO_OFFLOAD

BATCHES = [4, 8, 16, 32, 64]
SEQS = [128, 256, 512, 1024]
SLO_FACTOR = 1.3


def run() -> BenchResult:
    an = analyzer_for(OPT_6_7B)
    rows = []
    grid = {}
    for b in BATCHES:
        for s in SEQS:
            times = an.layer_times(b, s, "decode")
            slo = SLO_FACTOR * times.t_iter_no_offload_s
            rec = an.generate_record([slo], [b], [s], "decode")
            iv = rec.lookup(slo, b, s)
            grid[(b, s)] = iv
        rows.append({"batch": b, **{f"seq{s}": interval_str(grid[(b, s)])
                                    for s in SEQS}})

    mono_b = all(grid[(BATCHES[i], s)] >= grid[(BATCHES[i + 1], s)]
                 for s in SEQS for i in range(len(BATCHES) - 1))
    mono_s = all(grid[(b, SEQS[i])] >= grid[(b, SEQS[i + 1])]
                 for b in BATCHES for i in range(len(SEQS) - 1))
    claims = [
        Claim("table1 interval non-increasing in batch",
              "5,4,3,2,1 down the batch column", "monotone" if mono_b
              else "non-monotone", ok=mono_b),
        Claim("table1 interval non-increasing in seq",
              "5,4,3,2 across the seq row", "monotone" if mono_s
              else "non-monotone", ok=mono_s,
              note="absolute values differ from the paper's A10 wall-clock "
                   "record; the trend is the claim"),
    ]
    return BenchResult("table1_record", rows, claims)


if __name__ == "__main__":
    print(run().render())
