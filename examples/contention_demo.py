"""Bandwidth-contention demo (paper §4.5 / Fig. 12).

Two engines share one host link. The per-bus coordinator re-picks offloading
intervals every iteration so the summed transfer rates fit the link while
host-memory usage is maximized; a static worst-case split (FlexGen's
assumption) either violates the SLO or under-offloads.

    PYTHONPATH=src python examples/contention_demo.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core.coordinator import InstanceState, coordinate
from repro.core.hardware import A10
from repro.core.interval import LayerTimes, OffloadPlan, iter_time_with_interval
from repro.core.simulator import schedule_for_interval, simulate_shared_bus


def main():
    # Two OPT-13B-like instances (32 units of 400 MB) sharing a 24 GB/s link.
    times = LayerTimes(t_compute_s=2e-3, t_transfer_s=16e-3, num_layers=32,
                       layer_bytes=400 << 20)
    slo = 1.10 * times.t_iter_no_offload_s
    insts = [
        InstanceState("gpu0", 32, times.layer_bytes,
                      times.t_iter_no_offload_s, min_interval=9,
                      max_interval=10**9),
        InstanceState("gpu1", 32, times.layer_bytes,
                      times.t_iter_no_offload_s, min_interval=9,
                      max_interval=10**9),
    ]
    res = coordinate(insts, link_bw=A10.host_link_bw)
    print("coordinated intervals:", res.intervals,
          f"host={res.total_host_bytes/2**30:.1f}GiB",
          f"rate={res.total_link_rate/1e9:.1f}GB/s (link 24GB/s)")

    for name, iv in res.intervals.items():
        t = iter_time_with_interval(times, iv)
        print(f"  {name}: interval {iv} -> iter {t*1e3:.1f} ms "
              f"(SLO {slo*1e3:.1f} ms) {'OK' if t <= slo else 'VIOLATION'}")

    # Oversubscribed static choice: both pick min interval ignoring the peer.
    sched = schedule_for_interval([times.t_compute_s] * 32, 9,
                                  times.t_transfer_s)
    rate = OffloadPlan(32, 9).link_bytes_per_iter(times.layer_bytes) \
        / times.t_iter_no_offload_s
    shared = simulate_shared_bus([sched, sched], total_bw=A10.host_link_bw,
                                 demands=[rate, rate])
    for i, r in enumerate(shared):
        ok = r["latency_s"] <= slo
        print(f"  static gpu{i}: iter {r['latency_s']*1e3:.1f} ms "
              f"{'OK' if ok else 'VIOLATION (uncoordinated contention)'}")


if __name__ == "__main__":
    main()
