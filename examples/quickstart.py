"""Quickstart: the Select-N core API in five minutes (CPU, reduced model).

1. Measure deterministic layer times (the paper's key premise).
2. Generate a performance record offline (Table 1).
3. Pick the optimal offloading interval for an SLO.
4. Run an offloaded decode step and check it matches the plain one.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import NO_OFFLOAD, OffloadPlan, optimal_interval
from repro.core.memory_manager import (OffloadRuntime, split_model_params,
                                       split_stacked)
from repro.models.model import build_model
from repro.models.transformer import pattern_info


def main():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    model = build_model(cfg)
    print(f"arch: {cfg.name}  layers={cfg.num_layers} d_model={cfg.d_model}")

    # 1. offline analyzer — measures wall-clock layer time (deterministic)
    an = PerformanceAnalyzer(cfg, A10, measure="wallclock")
    times = an.layer_times(batch=2, seq=32, phase="decode")
    print(f"measured t_compute/unit = {times.t_compute_s*1e3:.3f} ms, "
          f"t_transfer/unit = {times.t_transfer_s*1e3:.3f} ms")

    # 2./3. optimal interval for a 25%-slack SLO
    slo = 1.25 * times.t_iter_no_offload_s
    iv = optimal_interval(times, slo)
    plan = OffloadPlan(pattern_info(cfg)[1], iv)
    print(f"SLO {slo*1e3:.2f} ms -> optimal interval {iv} "
          f"({plan.num_offloaded}/{plan.num_units} units in host memory)")

    # 4. offloaded serving step == plain serving step
    params = model.init(jax.random.PRNGKey(0))
    inputs = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    logits, caches, _ = jax.jit(
        lambda p, i: model.prefill(p, i, cache_len=20))(params, inputs)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 16, jnp.int32)
    ref, _ = jax.jit(model.decode_step)(params, tok, pos, caches, None)

    rt = OffloadRuntime(model=model, plan=plan)
    off, _ = jax.jit(rt.decode_step)(
        split_model_params(params, plan), tok, pos,
        split_stacked(caches, plan), None)
    err = float(jnp.max(jnp.abs(off.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    print(f"offloaded vs plain decode max|diff| = {err:.2e}  "
          f"({'OK' if err < 1e-2 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
