"""Serve a small model with batched requests under a latency SLO.

End-to-end driver of the paper's kind (serving): continuous batching,
record-based admission, Select-N offload interval.

    PYTHONPATH=src python examples/serve_slo.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen2.5-3b", "--requests", "10",
          "--tpot-slo-ms", "80", "--ttft-slo-ms", "400",
          "--hbm-gb", "0.04", "--max-batch", "4", "--max-seq", "64"])
