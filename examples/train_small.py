"""Train a ~100M-param model for a few hundred steps on CPU with
checkpointing and resume (end-to-end training driver).

    PYTHONPATH=src python examples/train_small.py [--steps N]
"""
import argparse
import sys

from repro.launch.train import main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    # xlstm-125m at full config is CPU-trainable (125M params);
    # we run its reduced variant by default to keep the demo fast, bump
    # --steps and drop --reduced for the full run.
    main(["--arch", "xlstm-125m", "--reduced", "--steps", str(args.steps),
          "--batch", "4", "--seq", "32", "--lr", "3e-3",
          "--ckpt-dir", "/tmp/repro_train_small", "--ckpt-every", "50",
          "--resume"])
