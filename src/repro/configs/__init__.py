"""Config registry: ``get_config(name)`` / ``ASSIGNED_ARCHS`` / shapes."""
from __future__ import annotations

from repro.configs.base import (
    LM_SHAPES,
    BlockSpec,
    FrontendConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    XLSTMConfig,
    cell_is_runnable,
)
from repro.configs import paper_models as _paper

from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.gemma_2b import CONFIG as _gemma
from repro.configs.qwen2_5_3b import CONFIG as _qwen25
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.xlstm_125m import CONFIG as _xlstm

# The 10 assigned pool architectures, in the assignment's order.
ASSIGNED_ARCHS: tuple[str, ...] = (
    "seamless-m4t-medium",
    "grok-1-314b",
    "dbrx-132b",
    "gemma-2b",
    "qwen2.5-3b",
    "h2o-danube-3-4b",
    "deepseek-7b",
    "paligemma-3b",
    "jamba-1.5-large-398b",
    "xlstm-125m",
)

PAPER_MODELS: tuple[str, ...] = (
    "opt-6.7b", "opt-13b", "qwen2-beta-7b", "llama2-13b",
)

_REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _seamless, _grok, _dbrx, _gemma, _qwen25, _danube, _deepseek,
        _paligemma, _jamba, _xlstm,
        _paper.OPT_6_7B, _paper.OPT_13B, _paper.QWEN2_BETA_7B, _paper.LLAMA2_13B,
    )
}


def get_config(name: str) -> ModelConfig:
    key = name.strip().lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_configs() -> list[str]:
    return sorted(_REGISTRY)


def get_shape(name: str) -> ShapeSpec:
    if name not in LM_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(LM_SHAPES)}")
    return LM_SHAPES[name]


def all_cells(include_paper_models: bool = False):
    """Yield every runnable (config, shape) cell."""
    names = ASSIGNED_ARCHS + (PAPER_MODELS if include_paper_models else ())
    for arch in names:
        cfg = get_config(arch)
        for shape in LM_SHAPES.values():
            ok, _why = cell_is_runnable(cfg, shape)
            if ok:
                yield cfg, shape


__all__ = [
    "ASSIGNED_ARCHS", "PAPER_MODELS", "LM_SHAPES",
    "ModelConfig", "ShapeSpec", "BlockSpec", "MoEConfig", "MambaConfig",
    "XLSTMConfig", "FrontendConfig",
    "get_config", "get_shape", "list_configs", "all_cells", "cell_is_runnable",
]
