"""Model/shape configuration system.

Every architecture in the assigned pool (plus the paper's own eval models) is a
``ModelConfig``. Shapes (``train_4k`` etc.) are ``ShapeSpec``s. A *cell* is a
(ModelConfig, ShapeSpec) pair; ``launch/dryrun.py`` iterates cells.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

# ---------------------------------------------------------------------------
# Block kinds composing a layer stack. A stack is described by a repeating
# *pattern* of BlockSpecs; homogeneous models have a single-entry pattern.
# ---------------------------------------------------------------------------

MixerKind = Literal["attention", "mamba", "slstm", "mlstm"]
MlpKind = Literal["dense", "moe"]
ActKind = Literal["silu", "gelu", "relu"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One decoder block: a sequence mixer + an MLP (possibly MoE)."""

    mixer: MixerKind = "attention"
    mlp: MlpKind = "dense"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    # Dense-dispatch capacity factor (MaxText-style "dropping" MoE).
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2  # inner dim = expand * d_model


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # mLSTM: matrix-memory heads; sLSTM: scalar-memory recurrent heads.
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    conv1d_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub: input_specs() yields precomputed embeddings."""

    kind: Literal["audio", "vision"] = "vision"
    # Number of frontend embedding positions prepended / consumed.
    num_positions: int = 256
    embed_dim: int = 0  # 0 => d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm", "audio"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    act: ActKind = "silu"
    gated_mlp: bool = True  # SwiGLU/GeGLU style (w1, w3 gate, w2 down)
    qkv_bias: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10_000.0
    # Sliding-window attention; 0 = full attention.
    sliding_window: int = 0
    tie_embeddings: bool = False
    # Repeating block pattern; cycled to num_layers. Default: [attention+dense].
    pattern: tuple[BlockSpec, ...] = (BlockSpec(),)
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    # Encoder-decoder (seamless): encoder layer count; 0 = decoder-only.
    encoder_layers: int = 0
    frontend: FrontendConfig | None = None
    # Giant models (>~100B) store params sharded over the data axis too (FSDP).
    param_fsdp: bool = False
    dtype: str = "bfloat16"
    # Reference citation tier, carried for documentation.
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def blocks(self) -> tuple[BlockSpec, ...]:
        reps = math.ceil(self.num_layers / len(self.pattern))
        return tuple((self.pattern * reps)[: self.num_layers])

    @property
    def is_subquadratic(self) -> bool:
        """True if long_500k decode is runnable (SSM/hybrid/SWA)."""
        if self.sliding_window > 0:
            return True
        return any(b.mixer != "attention" for b in self.pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (seamless is enc-dec)

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    def num_params(self) -> int:
        """Analytic parameter count (embedding + stacks), for roofline MODEL_FLOPS."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.padded_vocab() * d  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab() * d  # lm head
        for blk in self.blocks:
            n += self._mixer_params(blk.mixer, d, hd)
            n += self._mlp_params(blk.mlp, d)
            n += 2 * d  # two norms
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                n += self._mixer_params("attention", d, hd)
                n += self._mlp_params("dense", d)
                n += 2 * d
            # cross attention in each decoder block
            n += self.num_layers * self._mixer_params("attention", d, hd)
        return n

    def num_active_params(self) -> int:
        """Params touched per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.num_params()
        d = self.d_model
        full = self.num_params()
        moe_blocks = sum(1 for b in self.blocks if b.mlp == "moe")
        per_expert = self._mlp_params("dense", d)
        inactive = moe_blocks * (self.moe.num_experts - self.moe.top_k) * per_expert
        return full - inactive

    def _mixer_params(self, mixer: MixerKind, d: int, hd: int) -> int:
        if mixer == "attention":
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + b
        if mixer == "mamba":
            mc = self.mamba or MambaConfig()
            di = mc.expand * d
            return (d * 2 * di          # in_proj (x, z)
                    + di * mc.d_conv     # conv1d
                    + di * (mc.d_state * 2 + 1)  # B, C, dt projections (selective)
                    + di * mc.d_state    # A
                    + di                 # D
                    + di * d)            # out_proj
        if mixer in ("slstm", "mlstm"):
            xc = self.xlstm or XLSTMConfig()
            pf = xc.proj_factor_mlstm if mixer == "mlstm" else 1.0
            di = int(pf * d)
            if mixer == "mlstm":
                # up-proj, q/k/v projections, gates, out-proj
                return d * 2 * di + 3 * di * di // max(self.num_heads, 1) + 2 * di + di * d
            # sLSTM: 4 gates recurrent + input
            return 4 * (d * d + d * d // max(self.num_heads, 1)) + 4 * d
        raise ValueError(mixer)

    def _mlp_params(self, mlp: MlpKind, d: int) -> int:
        if self.d_ff == 0:
            return 0
        per = d * self.d_ff * (3 if self.gated_mlp else 2)
        if mlp == "moe":
            assert self.moe is not None
            return self.moe.num_experts * per + d * self.moe.num_experts  # + router
        return per


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

StepKind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: StepKind

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


LM_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell per the assignment rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
