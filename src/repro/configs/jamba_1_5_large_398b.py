"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave (attention at
position 4 of every 8-block period), MoE on every other block.
[arXiv:2403.19887; hf]."""
from repro.configs.base import BlockSpec, MambaConfig, ModelConfig, MoEConfig


def _period8() -> tuple[BlockSpec, ...]:
    blocks = []
    for j in range(8):
        mixer = "attention" if j == 4 else "mamba"
        mlp = "moe" if j % 2 == 1 else "dense"
        blocks.append(BlockSpec(mixer=mixer, mlp=mlp))
    return tuple(blocks)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=_period8(),
    moe=MoEConfig(num_experts=16, top_k=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    param_fsdp=True,
    source="arXiv:2403.19887; hf",
)
