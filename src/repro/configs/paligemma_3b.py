"""paligemma-3b [vlm]: gemma backbone (18L d_model=2048 8H kv=1 d_ff=16384)
vocab=257216 with SigLIP vision frontend (stubbed: input_specs() yields 256
patch embeddings). [arXiv:2407.07726; hf]."""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    frontend=FrontendConfig(kind="vision", num_positions=256),
    source="arXiv:2407.07726; hf",
)
