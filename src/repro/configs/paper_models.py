"""The paper's own evaluation models (§5.1): OPT-6.7B, OPT-13B, Qwen2-beta-7B,
LLaMA2-13B. Used by the benchmark reproductions (fig2..fig14).

Note: OPT uses learned positional embeddings and ReLU; we keep RoPE for
positional encoding (systems behaviour — layer structure, sizes, per-layer
bytes/FLOPs — is what the reproduction depends on; recorded in DESIGN.md §9).
"""
from repro.configs.base import ModelConfig

OPT_6_7B = ModelConfig(
    name="opt-6.7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=16384, vocab_size=50272,
    act="relu", gated_mlp=False, norm="layernorm",
    source="arXiv:2205.01068; hf",
)

OPT_13B = ModelConfig(
    name="opt-13b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, d_ff=20480, vocab_size=50272,
    act="relu", gated_mlp=False, norm="layernorm",
    source="arXiv:2205.01068; hf",
)

QWEN2_BETA_7B = ModelConfig(
    name="qwen2-beta-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=151936,
    qkv_bias=True, source="hf:Qwen/Qwen1.5-7B; hf",
)

LLAMA2_13B = ModelConfig(
    name="llama2-13b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, d_ff=13824, vocab_size=32000,
    source="arXiv:2307.09288; hf",
)
