"""Reduced (smoke-test) variants of every architecture.

Same family/pattern/features, tiny dims: used by CPU smoke tests and
examples. The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FrontendConfig, MambaConfig, ModelConfig, MoEConfig


def reduce_config(cfg: ModelConfig, *, d_model: int = 64, heads: int = 4,
                  layers: int | None = None, d_ff: int = 128,
                  vocab: int = 512) -> ModelConfig:
    p = len(cfg.pattern)
    if layers is None:
        layers = max(p, 2 * p if p <= 2 else p)
    layers = ((layers + p - 1) // p) * p
    kv = max(1, heads * cfg.num_kv_heads // cfg.num_heads)
    while heads % kv != 0:
        kv -= 1
    changes: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=d_ff if cfg.d_ff > 0 else 0,
        vocab_size=vocab,
        head_dim=32 if cfg.head_dim else 0,
        sliding_window=8 if cfg.sliding_window else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        param_fsdp=cfg.param_fsdp,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(num_experts=4, top_k=min(2, cfg.moe.top_k),
                                   capacity_factor=cfg.moe.capacity_factor)
    if cfg.mamba is not None:
        changes["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2)
    if cfg.frontend is not None:
        changes["frontend"] = FrontendConfig(kind=cfg.frontend.kind,
                                             num_positions=4)
    return dataclasses.replace(cfg, **changes)
