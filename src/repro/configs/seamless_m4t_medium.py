"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

12L encoder + 12L decoder, d_model=1024, 16H (kv=16), d_ff=4096, vocab=256206.
[arXiv:2308.11596; hf]. The speech frontend (w2v-BERT conformer) is a stub:
input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    act="relu",
    gated_mlp=False,
    norm="layernorm",
    frontend=FrontendConfig(kind="audio", num_positions=1024),
    source="arXiv:2308.11596; hf",
)
