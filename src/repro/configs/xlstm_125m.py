"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (blocks contain their own projections; no separate MLP).
[arXiv:2405.04517; unverified]."""
from repro.configs.base import BlockSpec, ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=(BlockSpec(mixer="mlstm", mlp="dense"),
             BlockSpec(mixer="slstm", mlp="dense")),
    xlstm=XLSTMConfig(),
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
