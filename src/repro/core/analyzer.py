"""Offline performance analyzer (§4.4).

Runs on a dedicated machine with no bus contention. For every (batch, seq)
bucket it obtains per-layer compute time — *measured*, never estimated from
peak FLOPs (Observation #2) — plus the layer transfer time, and tabulates the
optimal offloading interval for every SLO on the 2 ms grid.

Two measurement modes:
  * "wallclock": time the jitted layer on the current backend (what runs on a
    real TPU host; also what the determinism tests exercise on CPU);
  * "model":     analytic roofline estimate from the hardware preset (used by
    the paper-figure benchmarks to reproduce the A10 numbers without an A10;
    recorded in the record's provenance field).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core.hardware import HardwareModel
from repro.core.interval import LayerTimes, NO_OFFLOAD, optimal_interval
from repro.core.record import PerformanceRecord
from repro.models import spec as S
from repro.models import transformer as T
from repro.models.model import build_model


def _time_fn(fn: Callable, *args, repeats: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclasses.dataclass
class MeasuredTimes:
    t_compute_s: float       # one scan unit (pattern period)
    t_transfer_s: float      # one scan unit's weights over the host link
    t_rest_s: float
    unit_bytes: int
    num_units: int


class PerformanceAnalyzer:
    def __init__(self, cfg: ModelConfig, hw: HardwareModel,
                 measure: str = "wallclock", link_share: float = 1.0):
        self.cfg = cfg
        self.hw = hw
        self.measure = measure
        self.link_share = link_share
        self.model = build_model(cfg)
        self._params_one = None  # single-unit params, lazily built

    # ---- per-(batch, seq) measurement ---------------------------------------
    def _single_unit_params(self):
        if self._params_one is None:
            import dataclasses as dc
            cfg1 = dc.replace(self.cfg, num_layers=len(self.cfg.pattern))
            m1 = build_model(cfg1)
            self._params_one = (m1, m1.init(jax.random.PRNGKey(0)))
        return self._params_one

    def measure_times(self, batch: int, seq: int, phase: str) -> MeasuredTimes:
        cfg = self.cfg
        p, r = T.pattern_info(cfg)
        unit_bytes = costs.unit_weight_bytes(cfg)
        # Per-device transferred bytes scale with the TP shard; the analyzer
        # works in whole-instance terms (every host moves its shard in
        # parallel), so full unit bytes over one link is the faithful unit.
        t_transfer = self.hw.transfer_time(unit_bytes, self.link_share)

        if self.measure == "model":
            if phase == "prefill":
                fl = sum(costs.layer_flops(cfg, batch, seq, seq, j)
                         for j in range(p))
                by = sum(costs.layer_act_bytes(cfg, batch, seq, seq, j)
                         for j in range(p))
            else:
                fl = sum(costs.layer_flops(cfg, batch, 1, seq, j)
                         for j in range(p))
                by = sum(costs.layer_act_bytes(cfg, batch, 1, seq, j)
                         for j in range(p))
            t_compute = self.hw.exec_time(fl, by)
            rest = self.hw.exec_time(
                2 * batch * (seq if phase == "prefill" else 1)
                * cfg.d_model * cfg.padded_vocab(),
                cfg.padded_vocab() * cfg.d_model * 2)
            return MeasuredTimes(t_compute, t_transfer, rest, unit_bytes, r)

        # wallclock: run one scan unit for real
        m1, params1 = self._single_unit_params()
        if phase == "prefill":
            tokens = jnp.zeros((batch, seq), jnp.int32)
            pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                   (batch, seq))

            def unit_fn(params, tokens):
                x = T.embed_tokens(m1.cfg, params, tokens)
                ctx = T.SeqCtx(positions=pos, virtual_kv=m1.virtual_kv)
                x, _, _ = T.apply_stack_seq(m1.cfg, params["blocks"], x, ctx)
                return x

            t_unit = _time_fn(jax.jit(unit_fn), params1, tokens)
        else:
            caches = m1.init_cache(jax.random.PRNGKey(1), batch, seq)
            tok = jnp.zeros((batch,), jnp.int32)
            posv = jnp.full((batch,), seq - 1, jnp.int32)

            def unit_fn(params, tok, caches):
                x = T.embed_tokens(m1.cfg, params, tok[:, None])
                x, nc = T.apply_stack_decode(m1.cfg, params["blocks"], x,
                                             posv, caches, m1.virtual_kv)
                return x, nc

            t_unit = _time_fn(jax.jit(unit_fn), params1, tok, caches)
        return MeasuredTimes(t_unit, t_transfer, 0.1 * t_unit, unit_bytes, r)

    def layer_times(self, batch: int, seq: int, phase: str) -> LayerTimes:
        mt = self.measure_times(batch, seq, phase)
        return LayerTimes(
            t_compute_s=mt.t_compute_s, t_transfer_s=mt.t_transfer_s,
            num_layers=mt.num_units, layer_bytes=mt.unit_bytes,
            t_rest_s=mt.t_rest_s)

    # ---- record generation ----------------------------------------------------
    def generate_record(self, slos_s: Sequence[float], batches: Sequence[int],
                        seqs: Sequence[int], phase: str) -> PerformanceRecord:
        rec = PerformanceRecord(
            model_name=self.cfg.name, hardware=self.hw.name, phase=phase,
            batches=sorted(batches), seqs=sorted(seqs), measure=self.measure)
        for b in rec.batches:
            for s in rec.seqs:
                times = self.layer_times(b, s, phase)
                for slo in slos_s:
                    rec.set(slo, b, s, optimal_interval(times, slo))
        return rec


def determinism_check(cfg: ModelConfig, batch: int, seq: int,
                      iters: int = 5) -> dict:
    """Empirically verify the paper's premise: per-iteration layer compute
    time is deterministic (CV below a few percent)."""
    an = PerformanceAnalyzer(cfg, hw=_dummy_hw(), measure="wallclock")
    ts = [an.measure_times(batch, seq, "decode").t_compute_s
          for _ in range(iters)]
    ts = np.asarray(ts)
    return {"mean_s": float(ts.mean()), "std_s": float(ts.std()),
            "cv": float(ts.std() / ts.mean())}


def _dummy_hw() -> HardwareModel:
    from repro.core.hardware import A10
    return A10
