"""Baselines the paper compares against (§3): DeepSpeed ZeRO-Inference,
(SLO-aware) FlexGen, and the naive no-offload mode. Used by the simulator
benchmarks and exposed as executable plans for the JAX path.
"""
from __future__ import annotations

import dataclasses

from repro.core.hardware import HardwareModel
from repro.core.interval import LayerTimes, NO_OFFLOAD, OffloadPlan


def naive_plan(num_units: int) -> OffloadPlan:
    return OffloadPlan(num_units, NO_OFFLOAD)


def deepspeed_plan(num_units: int) -> OffloadPlan:
    """Keep only the current layer on device — interval 1 (§3.2)."""
    return OffloadPlan(num_units, 1)


@dataclasses.dataclass(frozen=True)
class FlexGenDecision:
    fraction: float          # of every layer's weights offloaded to host
    est_iter_s: float        # its own (peak-FLOPs) latency estimate
    bw_fraction_assumed: float


def flexgen_decision(times: LayerTimes, hw: HardwareModel, slo_s: float,
                     layer_flops: float, n_bus_sharers: int = 1
                     ) -> FlexGenDecision:
    """The paper's SLO-aware FlexGen modification (§3.3): statically choose
    the largest offload fraction whose *estimated* latency meets the SLO.

    Two deliberate flaws reproduced from the paper's analysis:
      * compute time estimated from peak FLOPs (underestimates => conservative
        offloading, Observation #2);
      * bandwidth assumed to be 1/n of the link under contention
        (Observation #3).
    """
    bw_frac = 1.0 / max(1, n_bus_sharers)
    tc_est = hw.peak_exec_time(layer_flops)
    l = times.num_layers
    # One-layer-lookahead prefetch: per-layer latency = max(tc, f*tt/bw).
    # Feasibility: L * max(tc_est, f * tt / bw_frac) <= slo.
    per_layer_budget = slo_s / l
    if tc_est > per_layer_budget:
        frac = 0.0
    else:
        tt_eff = times.t_transfer_s / bw_frac
        frac = min(1.0, per_layer_budget / tt_eff) if tt_eff > 0 else 1.0
    est = l * max(tc_est, frac * times.t_transfer_s / bw_frac)
    return FlexGenDecision(fraction=frac, est_iter_s=est,
                           bw_fraction_assumed=bw_frac)


def flexgen_host_bytes(times: LayerTimes, decision: FlexGenDecision) -> float:
    return decision.fraction * times.num_layers * times.layer_bytes


def flexgen_equivalent_interval(times: LayerTimes,
                                decision: FlexGenDecision) -> int:
    """Interval with the same offloaded byte volume (for the JAX path)."""
    if decision.fraction <= 0:
        return NO_OFFLOAD
    n_off = max(1, int(round(decision.fraction * times.num_layers)))
    return max(1, times.num_layers // n_off)
