"""Per-bus bandwidth coordinator (§4.5, Fig. 8).

Multiple serving instances share a host link. Each has, for its current
request, a minimum interval (from the performance record — below it the SLO
breaks) and a maximum interval (from device memory — above it the resident
weights don't fit). The coordinator picks one interval per instance so that
the summed link rates stay under the link bandwidth while total host-memory
usage is maximal.

The paper presents the 2-instance enumeration; we generalize: exact product
search up to a size bound, greedy relaxation beyond (monotone: raising an
interval only lowers both link rate and host usage, so greedy-lift converges).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

from repro.core.interval import NO_OFFLOAD, OffloadPlan


@dataclasses.dataclass
class InstanceState:
    name: str
    num_units: int
    unit_bytes: int
    t_iter_s: float          # current iteration latency (deterministic)
    min_interval: int        # from the performance record (SLO bound)
    max_interval: int        # from device memory (capacity bound)
    idle: bool = False       # idle instances consume no bandwidth
    # Per-iteration KV-page traffic (streamed host-resident KV + migrations,
    # both directions) of this instance's two-tier KV cache. It rides the
    # same host link as weight prefetch, so the coordinator must arbitrate
    # the combined rate (weights + KV) against the link bandwidth.
    kv_bytes_per_iter: float = 0.0
    # Pending peer-link handoff traffic (PEER tier, both directions). The
    # transfer itself has its own modeled link, but every handoff payload
    # crosses this instance's host memory system, so its rate is arbitrated
    # against the shared budget alongside weight prefetch and KV streams.
    peer_bytes_per_iter: float = 0.0

    def valid_intervals(self) -> list[int]:
        if self.idle:
            return [NO_OFFLOAD]
        top = min(self.max_interval, self.num_units)
        vals = [i for i in range(max(1, self.min_interval), top + 1)]
        if self.max_interval >= NO_OFFLOAD:
            vals.append(NO_OFFLOAD)
        # An empty range means no interval satisfies both the SLO bound
        # (min_interval) and the memory bound (max_interval). There is no
        # fallback: NO_OFFLOAD is only valid when the fully-resident weights
        # actually fit (max_interval >= NO_OFFLOAD, appended above).
        return vals

    def admissible(self) -> bool:
        """Paper Fig. 8 lines 34-35: SLO is meetable at all — some interval
        satisfies both the record's floor and the memory ceiling."""
        return self.idle or bool(self.valid_intervals())

    def link_rate(self, interval: int) -> float:
        if self.idle:
            return 0.0
        plan = OffloadPlan(self.num_units, interval)
        kv_rate = (self.kv_bytes_per_iter + self.peer_bytes_per_iter) \
            / self.t_iter_s if self.t_iter_s > 0 else 0.0
        return plan.link_rate(self.unit_bytes, self.t_iter_s) + kv_rate

    def host_bytes(self, interval: int) -> int:
        return OffloadPlan(self.num_units, interval).host_bytes(self.unit_bytes)


@dataclasses.dataclass
class CoordinationResult:
    ok: bool
    intervals: dict[str, int]
    total_host_bytes: int
    total_link_rate: float
    reason: str = ""


EXACT_SEARCH_LIMIT = 200_000


def coordinate(instances: Sequence[InstanceState], link_bw: float
               ) -> CoordinationResult:
    for inst in instances:
        if not inst.admissible():
            return CoordinationResult(
                False, {}, 0, 0.0,
                f"{inst.name}: min interval {inst.min_interval} exceeds max "
                f"{inst.max_interval}; return request to upper-level scheduler")

    choices = [inst.valid_intervals() for inst in instances]
    space = math.prod(len(c) for c in choices)

    def evaluate(combo: Sequence[int]):
        rate = sum(inst.link_rate(iv) for inst, iv in zip(instances, combo))
        host = sum(inst.host_bytes(iv) for inst, iv in zip(instances, combo))
        return rate, host

    if space <= EXACT_SEARCH_LIMIT:
        best = None
        for combo in itertools.product(*choices):
            rate, host = evaluate(combo)
            if rate <= link_bw and (best is None or host > best[0]):
                best = (host, rate, combo)
        if best is None:
            return CoordinationResult(False, {}, 0, 0.0,
                                      "no interval combination fits the link")
        host, rate, combo = best
        return CoordinationResult(
            True, {i.name: v for i, v in zip(instances, combo)}, host, rate)

    # Greedy: start from min intervals (max host memory), lift the interval
    # whose increase sheds the most bandwidth per host-byte sacrificed.
    combo = [c[0] for c in choices]
    idx = [0] * len(instances)
    rate, host = evaluate(combo)
    while rate > link_bw:
        best_j, best_score = -1, -1.0
        for j, inst in enumerate(instances):
            if idx[j] + 1 >= len(choices[j]):
                continue
            nxt = choices[j][idx[j] + 1]
            d_rate = inst.link_rate(combo[j]) - inst.link_rate(nxt)
            d_host = max(inst.host_bytes(combo[j]) - inst.host_bytes(nxt), 1)
            score = d_rate / d_host
            if score > best_score:
                best_j, best_score = j, score
        if best_j < 0:
            return CoordinationResult(False, {}, 0, 0.0,
                                      "greedy: cannot fit link bandwidth")
        idx[best_j] += 1
        combo[best_j] = choices[best_j][idx[best_j]]
        rate, host = evaluate(combo)
    return CoordinationResult(
        True, {i.name: v for i, v in zip(instances, combo)}, host, rate)


class FleetLinkBudget:
    """Fleet-wide owner of the shared host-link budget (the bus arbiter
    promoted to fleet scope). One object per fleet holds the link bandwidth;
    the fleet's step loop asks it to ``certify`` the instance set (the same
    §4.5 arbitration ``coordinate`` runs per bus), and the affinity router
    asks it for per-instance ``pressure`` — the fraction of the shared link
    one instance's current interval + KV traffic would consume — so
    admissions steer away from instances already saturating their share."""

    def __init__(self, link_bw: float):
        self.link_bw = link_bw

    def certify(self, instances: Sequence[InstanceState]
                ) -> CoordinationResult:
        return coordinate(instances, self.link_bw)

    def pressure(self, inst: InstanceState, interval: int) -> float:
        if self.link_bw <= 0:
            return 0.0
        return inst.link_rate(interval if interval else NO_OFFLOAD) \
            / self.link_bw


def max_interval_for_memory(num_units: int, unit_bytes: int,
                            hbm_budget_bytes: float) -> int:
    """Largest interval whose resident set fits the budget; NO_OFFLOAD if the
    whole model fits."""
    full = OffloadPlan(num_units, NO_OFFLOAD)
    if full.device_bytes(unit_bytes) <= hbm_budget_bytes:
        return NO_OFFLOAD
    for i in range(num_units, 0, -1):
        if OffloadPlan(num_units, i).device_bytes(unit_bytes) <= hbm_budget_bytes:
            return i
    return 0  # even interval 1 does not fit
