"""Analytic per-layer/per-iteration cost model (FLOPs + bytes).

Used by (a) the FlexGen baseline's peak-performance estimator — the thing the
paper shows is inaccurate, (b) the modeled-hardware mode of the performance
analyzer for the paper-figure benchmarks, and (c) MODEL_FLOPS for §Roofline.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import BlockSpec, ModelConfig, ShapeSpec
from repro.models import layers as L
from repro.models import spec as S
from repro.models import transformer as T


def block_weight_bytes(cfg: ModelConfig, blk: BlockSpec, cross: bool = False
                       ) -> int:
    return S.tree_bytes(T.block_spec(cfg, blk, cross=cross))


def unit_weight_bytes(cfg: ModelConfig) -> int:
    """Bytes of one scan unit (= one pattern period)."""
    cross = cfg.encoder_layers > 0
    return sum(block_weight_bytes(cfg, blk, cross) for blk in cfg.pattern)


def layer_weight_bytes(cfg: ModelConfig) -> int:
    """Average per-layer weight bytes (unit bytes / pattern length)."""
    return unit_weight_bytes(cfg) // len(cfg.pattern)


def _attn_flops(cfg: ModelConfig, b: int, sq: int, skv: int) -> float:
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    proj = 2 * b * sq * d * (2 * h * hd + 2 * kv * hd)
    skv_eff = min(skv, cfg.sliding_window) if cfg.sliding_window else skv
    core = 2 * b * h * hd * sq * skv_eff * 2
    return proj + core


def _mlp_flops(cfg: ModelConfig, blk: BlockSpec, b: int, s: int) -> float:
    if cfg.d_ff == 0:
        return 0.0
    nmat = 3 if cfg.gated_mlp else 2
    per_tok = 2 * cfg.d_model * cfg.d_ff * nmat
    if blk.mlp == "moe":
        assert cfg.moe is not None
        return b * s * (per_tok * cfg.moe.top_k
                        + 2 * cfg.d_model * cfg.moe.num_experts)
    return b * s * per_tok


def _mixer_flops(cfg: ModelConfig, blk: BlockSpec, b: int, sq: int,
                 skv: int) -> float:
    if blk.mixer == "attention":
        return _attn_flops(cfg, b, sq, skv)
    d = cfg.d_model
    if blk.mixer == "mamba":
        mc = cfg.mamba
        di = (mc.expand if mc else 2) * d
        ds = mc.d_state if mc else 16
        dtr = max(1, d // 16)
        return b * sq * (2 * d * 2 * di + 2 * di * (dtr + 2 * ds)
                         + 10 * di * ds + 2 * di * d)
    if blk.mixer == "mlstm":
        di = 2 * d
        dh = di // cfg.num_heads
        return b * sq * (2 * d * 2 * di + 3 * 2 * di * dh
                         + 4 * cfg.num_heads * dh * dh + 2 * di * d)
    # slstm
    return b * sq * (2 * d * 4 * d + 2 * d * 4 * d)


def layer_flops(cfg: ModelConfig, b: int, sq: int, skv: int,
                layer_idx: int = 0) -> float:
    blk = cfg.blocks[layer_idx % len(cfg.blocks)]
    return _mixer_flops(cfg, blk, b, sq, skv) + _mlp_flops(cfg, blk, b, sq)


def layer_act_bytes(cfg: ModelConfig, b: int, sq: int, skv: int,
                    layer_idx: int = 0, dtype_bytes: int = 2) -> float:
    """HBM traffic of one layer: weights + activations (+ KV read at decode)."""
    blk = cfg.blocks[layer_idx % len(cfg.blocks)]
    w = block_weight_bytes(cfg, blk, cross=cfg.encoder_layers > 0)
    acts = 6 * b * sq * cfg.d_model * dtype_bytes
    kv_read = 0.0
    if blk.mixer == "attention" and sq == 1:  # decode reads the cache
        skv_eff = min(skv, cfg.sliding_window) if cfg.sliding_window else skv
        kv_read = 2 * b * skv_eff * cfg.num_kv_heads * cfg.resolved_head_dim \
            * dtype_bytes
    return w + acts + kv_read


@dataclasses.dataclass(frozen=True)
class IterationCost:
    flops: float
    bytes: float
    layer_flops: tuple[float, ...]   # per scan layer
    layer_bytes: tuple[float, ...]
    rest_flops: float                # embedding + logits


def iteration_cost(cfg: ModelConfig, b: int, sq: int, skv: int) -> IterationCost:
    lf, lb = [], []
    for j in range(cfg.num_layers):
        lf.append(layer_flops(cfg, b, sq, skv, j))
        lb.append(layer_act_bytes(cfg, b, sq, skv, j))
    rest = 2 * b * sq * cfg.d_model * cfg.padded_vocab()  # logits matmul
    return IterationCost(
        flops=float(sum(lf) + rest), bytes=float(sum(lb)),
        layer_flops=tuple(lf), layer_bytes=tuple(lb), rest_flops=float(rest))


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for §Roofline: 6·N·D for training, 2·N_active·D forward."""
    n = cfg.num_active_params()
    if shape.step == "train":
        return 6.0 * n * shape.tokens
    if shape.step == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per row


def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int,
                   virtual_kv: int | None = None, dtype_bytes: int = 2) -> int:
    """Whole-model decode cache bytes (attention KV + SSM states)."""
    vkv = virtual_kv if virtual_kv is not None else cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    total = 0
    for blk in cfg.blocks:
        if blk.mixer == "attention":
            s = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
            total += 2 * batch * s * vkv * hd * dtype_bytes
        elif blk.mixer == "mamba":
            mc = cfg.mamba
            di = (mc.expand if mc else 2) * cfg.d_model
            ds = mc.d_state if mc else 16
            total += batch * di * (ds * 4 + (mc.d_conv - 1 if mc else 3) * dtype_bytes)
        elif blk.mixer == "mlstm":
            di = 2 * cfg.d_model
            dh = di // cfg.num_heads
            total += batch * cfg.num_heads * (dh * dh + dh + 1) * 4
        elif blk.mixer == "slstm":
            total += 4 * batch * cfg.d_model * 4
    return int(total)
