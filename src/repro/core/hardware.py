"""Hardware models.

The Select-N algebra needs two numbers per platform: host-link transfer time
for a byte volume, and (for the FlexGen baseline's flawed estimator) peak
compute. Layer *compute* time is never estimated on the real system — it is
measured (the paper's core observation) — but the analytic models here also
power the paper-figure benchmarks, which reproduce the A10 setup of §5
without a GPU, and the TPU v5e roofline terms.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    hbm_bytes: float            # device memory capacity
    hbm_bw: float               # bytes/s
    peak_flops: float           # dense fp16/bf16 FLOP/s
    host_link_bw: float         # bytes/s, host<->device (PCIe), per *bus*
    host_link_latency_s: float  # fixed per-transfer latency
    devices_per_bus: int = 1    # accelerators sharing the host link
    ici_bw: float = 0.0         # bytes/s per inter-chip link (TPU)
    # Achievable fractions of peak compute / HBM bandwidth. 1.0 = ideal
    # roofline. Calibrated presets (below) carry measured-equivalent values so
    # the "model" analyzer mode stands in for wall-clock measurement — the
    # peak-FLOPs estimator (``peak_exec_time``) deliberately ignores them,
    # reproducing FlexGen's flaw (paper Observation #2).
    compute_eff: float = 1.0
    mem_eff: float = 1.0

    def transfer_time(self, nbytes: float, bw_fraction: float = 1.0) -> float:
        """Seconds to move nbytes over the host link at a bandwidth share."""
        bw = self.host_link_bw * bw_fraction
        return self.host_link_latency_s + nbytes / bw

    def exec_time(self, flops: float, bytes_moved: float) -> float:
        """Roofline execution-time estimate (max of compute and memory),
        derated by the achievable-efficiency factors."""
        return max(flops / (self.peak_flops * self.compute_eff),
                   bytes_moved / (self.hbm_bw * self.mem_eff))

    def peak_exec_time(self, flops: float) -> float:
        """FlexGen-style peak-FLOPs estimate (the paper's Observation #2:
        systematically underestimates real execution time)."""
        return flops / self.peak_flops


# NVIDIA A10: the paper's evaluation platform (§3, §5).
A10 = HardwareModel(
    name="a10",
    hbm_bytes=24e9,
    hbm_bw=600e9,
    peak_flops=125e12,
    host_link_bw=24e9,          # paper: "The PCIe bandwidth is 24GB/s"
    host_link_latency_s=20e-6,
    devices_per_bus=2,
)

# A10 with measured-equivalent efficiency factors, calibrated so the modeled
# per-layer transfer/compute ratios of Qwen2-beta-7B (batch 4, seq 256) match
# the paper's measured Fig. 2(b): t_t/t_c = 3.5x prefill, 13.8x decode.
# compute_eff = 0.69 ~= real GEMM MFU; mem_eff = 0.58 ~= achievable HBM bw for
# decode GEMV. These stand in for the analyzer's wall-clock measurements when
# reproducing the paper's A10 figures on a CPU-only container.
A10_CALIBRATED = dataclasses.replace(
    A10, name="a10_calibrated", compute_eff=0.69, mem_eff=0.58)

# TPU v5e: this system's deployment target (per chip).
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    hbm_bytes=16e9,
    hbm_bw=819e9,
    peak_flops=197e12,
    host_link_bw=32e9,          # PCIe gen4 x16 per host
    host_link_latency_s=20e-6,
    devices_per_bus=4,          # 4 v5e chips per host VM share the link
    ici_bw=50e9,
)

PRESETS = {"a10": A10, "a10_calibrated": A10_CALIBRATED, "tpu_v5e": TPU_V5E}
