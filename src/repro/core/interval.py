"""Offloading interval — the paper's central abstraction (§4.3).

An interval of ``i`` means: of every i consecutive layers, the last one's
weights live in host memory and are prefetched starting at the *first* layer
of the interval, so (i-1) layers of compute hide the transfer. ``i = 1``
degenerates to DeepSpeed (everything offloaded); ``i > L`` means no
offloading.

The algebra below converts between (SLO, measured layer times) and intervals,
and computes the memory/bandwidth consequences a plan has — the quantities
the coordinator (§4.5) trades off.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

NO_OFFLOAD = 10**9  # sentinel interval: everything resident

# Relative tolerance for SLO feasibility comparisons (float accumulation).
_FEAS_RTOL = 1e-9


def _feasible(t: float, slo_s: float) -> bool:
    return t <= slo_s * (1.0 + _FEAS_RTOL) + 1e-12


@dataclasses.dataclass(frozen=True)
class LayerTimes:
    """Deterministic per-iteration timing of one model on one platform."""
    t_compute_s: float      # per layer (uniform; see per_layer for hybrids)
    t_transfer_s: float     # per layer host->device at full link bandwidth
    num_layers: int
    layer_bytes: int        # weight bytes of one layer (per instance shard)
    t_rest_s: float = 0.0   # non-stack time per iteration (embed/logits/...)

    @property
    def t_iter_no_offload_s(self) -> float:
        return self.num_layers * self.t_compute_s + self.t_rest_s


def max_offloadable_layers(times: LayerTimes, slo_s: float) -> int:
    """Paper §4.4: L_offload = floor(t_compute_total * (1+delta) / t_transfer)
    where delta is the SLO headroom over the no-offload iteration time.

    Interpretation: every offloaded layer costs one transfer; transfers
    overlap compute, so the total transfer time must fit inside the compute
    time plus the SLO slack.
    """
    t0 = times.t_iter_no_offload_s
    if slo_s < t0 or times.t_transfer_s <= 0:
        return 0
    delta = (slo_s - t0) / t0
    total_compute = times.num_layers * times.t_compute_s
    budget = total_compute * (1.0 + delta) + times.t_rest_s * delta
    return min(times.num_layers, int(budget / times.t_transfer_s))


def paper_interval_formula(times: LayerTimes, slo_s: float) -> int:
    """The paper's closed form: floor(L / L_offload). NOTE: this is a lower
    bound, not always feasible — it assumes each transfer can overlap *all*
    compute, but an interval-i transfer only overlaps its own group's (i-1)
    layers. Our property tests exhibit violations (e.g. t_c == t_t, zero
    slack => interval 1, 2x the SLO). See DESIGN.md §9.
    """
    l_off = max_offloadable_layers(times, slo_s)
    if l_off <= 0:
        return NO_OFFLOAD
    return max(1, math.floor(times.num_layers / l_off))


def optimal_interval(times: LayerTimes, slo_s: float) -> int:
    """Smallest SLO-feasible interval: the paper's closed form as the initial
    guess, verified against the exact schedule latency and bumped until
    feasible (still O(L) worst case, done offline by the analyzer)."""
    guess = paper_interval_formula(times, slo_s)
    if guess >= NO_OFFLOAD:
        return NO_OFFLOAD
    for i in range(guess, times.num_layers + 1):
        if _feasible(iter_time_with_interval(times, i), slo_s):
            return i
    return NO_OFFLOAD


def link_bandwidth(times: LayerTimes) -> float:
    """Host-link bandwidth (bytes/s) implied by the measured layer transfer
    time. Zero if the times carry no transfer measurement."""
    if times.t_transfer_s <= 0:
        return 0.0
    return times.layer_bytes / times.t_transfer_s


def kv_transfer_seconds(times: LayerTimes, kv_bytes: float,
                        link_bw: float | None = None) -> float:
    """Copy-stream seconds to move ``kv_bytes`` of KV pages over the same
    host link the weight prefetches use."""
    if kv_bytes <= 0:
        return 0.0
    bw = link_bw if link_bw is not None else link_bandwidth(times)
    if bw <= 0:
        raise ValueError("KV traffic needs a link bandwidth: times has "
                         "t_transfer_s == 0 and no link_bw was given")
    return kv_bytes / bw


def iter_time_with_interval(times: LayerTimes, interval: int) -> float:
    """Analytic iteration latency under interval ``i`` with Select-N's
    group-start prefetch and a single copy stream (paper Fig. 7).

    Matches ``simulator.simulate_iteration`` for uniform layer times
    (property-tested).
    """
    return iter_time_with_interval_kv(times, interval)


def disk_transfer_seconds(disk_in_bytes: float, disk_out_bytes: float,
                          disk_bw: float, disk_latency_s: float = 0.0
                          ) -> float:
    """NVMe-channel seconds for one iteration's disk-tier KV traffic
    (three-tier offloading, see serving.kv_offload). The disk link is its
    own channel — its bytes must never ride the PCIe copy stream the
    weight prefetches and host-tier KV share — but it is also never free:
    an iteration that staged or demoted disk pages cannot complete before
    its NVMe queue drains."""
    total = disk_in_bytes + disk_out_bytes
    if total <= 0:
        return 0.0
    if disk_bw <= 0:
        raise ValueError("disk KV traffic needs a disk link bandwidth")
    return disk_latency_s + total / disk_bw


def peer_transfer_seconds(peer_in_bytes: float, peer_out_bytes: float,
                          peer_bw: float, peer_latency_s: float = 0.0
                          ) -> float:
    """Peer-link seconds for one iteration's cross-instance KV handoff
    traffic (disaggregated prefill/decode, see serving.fleet). The peer
    link — NIC/NVLink to another instance's host pool — is its own channel
    like the NVMe link: its bytes never ride the local PCIe copy stream,
    but an iteration that imported or exported handoff pages cannot
    complete before its peer queue drains."""
    total = peer_in_bytes + peer_out_bytes
    if total <= 0:
        return 0.0
    if peer_bw <= 0:
        raise ValueError("peer KV traffic needs a peer link bandwidth")
    return peer_latency_s + total / peer_bw


@dataclasses.dataclass(frozen=True)
class IterTimeBreakdown:
    """One iteration's modeled latency, decomposed by what the clock was
    charged for (the telemetry plane records these per iteration instead of
    the folded ``total_s`` float).

    Identities (the trace auditor machine-checks them):
      ``total_s == max(pcie_s, disk_s, peer_s)`` exactly, and
      ``pcie_s == kv_in_s + compute_s + stall_s`` up to float reassociation.
    """
    total_s: float        # what iter_time_with_interval_kv returns
    pcie_s: float         # PCIe copy-stream schedule incl. all compute
    disk_s: float         # NVMe channel drain (own term, never rides PCIe)
    compute_s: float      # num_layers * t_compute + t_rest (no-offload time)
    kv_in_s: float        # h2d KV copy gating layer-0 compute
    kv_out_s: float       # d2h write-back occupancy of the copy stream
    stall_s: float        # compute stalled on queued weight prefetches
    peer_s: float = 0.0   # peer-link drain (cross-instance KV handoff)


def iter_time_breakdown_kv(times: LayerTimes, interval: int,
                           kv_in_bytes: float = 0.0,
                           kv_out_bytes: float = 0.0,
                           link_bw: float | None = None,
                           disk_in_bytes: float = 0.0,
                           disk_out_bytes: float = 0.0,
                           disk_bw: float = 0.0,
                           disk_latency_s: float = 0.0,
                           peer_in_bytes: float = 0.0,
                           peer_out_bytes: float = 0.0,
                           peer_bw: float = 0.0,
                           peer_latency_s: float = 0.0) -> IterTimeBreakdown:
    """``iter_time_with_interval_kv`` with the latency decomposed into its
    compute / link-queue / disk-queue / peer-queue terms. ``total_s`` is
    bit-identical to the folded form — the wrapper below delegates here, so
    the two can never drift."""
    t_disk = disk_transfer_seconds(disk_in_bytes, disk_out_bytes,
                                   disk_bw, disk_latency_s)
    t_peer = peer_transfer_seconds(peer_in_bytes, peer_out_bytes,
                                   peer_bw, peer_latency_s)
    t_kv_in = kv_transfer_seconds(times, kv_in_bytes, link_bw)
    t_kv_out = kv_transfer_seconds(times, kv_out_bytes, link_bw)
    compute = times.t_iter_no_offload_s
    if interval >= times.num_layers + 1 or interval >= NO_OFFLOAD:
        # no weight prefetches: the d2h write-back overlaps compute without
        # queueing anything behind it (kv_out_s is occupancy, not delay)
        pcie = t_kv_in + times.t_iter_no_offload_s
        return IterTimeBreakdown(total_s=max(pcie, t_disk, t_peer),
                                 pcie_s=pcie,
                                 disk_s=t_disk, compute_s=compute,
                                 kv_in_s=t_kv_in, kv_out_s=t_kv_out,
                                 stall_s=pcie - t_kv_in - compute,
                                 peer_s=t_peer)
    i, tc, tt = interval, times.t_compute_s, times.t_transfer_s
    groups = times.num_layers // i
    t = t_kv_in
    copy_free = t_kv_in + t_kv_out
    for g in range(groups):
        group_start = t
        xfer_start = max(group_start, copy_free)
        xfer_done = xfer_start + tt
        copy_free = xfer_done
        t = group_start + (i - 1) * tc          # resident layers
        t = max(t, xfer_done) + tc              # offloaded layer
    t += (times.num_layers - groups * i) * tc   # remainder layers (resident)
    pcie = t + times.t_rest_s
    return IterTimeBreakdown(total_s=max(pcie, t_disk, t_peer), pcie_s=pcie,
                             disk_s=t_disk, compute_s=compute,
                             kv_in_s=t_kv_in, kv_out_s=t_kv_out,
                             stall_s=pcie - t_kv_in - compute,
                             peer_s=t_peer)


def iter_time_with_interval_kv(times: LayerTimes, interval: int,
                               kv_in_bytes: float = 0.0,
                               kv_out_bytes: float = 0.0,
                               link_bw: float | None = None,
                               disk_in_bytes: float = 0.0,
                               disk_out_bytes: float = 0.0,
                               disk_bw: float = 0.0,
                               disk_latency_s: float = 0.0,
                               peer_in_bytes: float = 0.0,
                               peer_out_bytes: float = 0.0,
                               peer_bw: float = 0.0,
                               peer_latency_s: float = 0.0) -> float:
    """Iteration latency when KV-page traffic shares the copy stream with
    weight prefetch (tiered KV offloading, see serving.kv_offload).

    Model — one PCIe copy stream, strict issue order (matches the event
    simulator's extended ``LayerSchedule``, property-tested):

      1. ``kv_in_bytes`` (host->device swap-in / streamed host-resident KV)
         is issued first and gates layer-0 compute — attention cannot read
         pages that are not on device yet.
      2. ``kv_out_bytes`` (device->host write-back of demoted pages) is
         issued next: demotions must vacate device frames before this
         iteration reuses them.  The write overlaps compute but queues the
         weight prefetches behind it.
      3. Weight prefetches then follow the Fig. 7 group-start schedule.

    Every byte is charged exactly once: KV bytes occupy the copy stream
    before the first weight transfer, so combined traffic is neither
    double-counted nor hidden.

    Disk-tier traffic (``disk_in_bytes`` / ``disk_out_bytes``) runs on its
    OWN channel (NVMe) concurrently with the PCIe schedule, and so does
    cross-instance handoff traffic (``peer_in_bytes`` / ``peer_out_bytes``)
    on the peer link: the iteration ends when every channel drains,
    ``max(t_pcie, t_disk, t_peer)`` — disk and peer bytes get their own
    terms instead of silently riding (or being hidden from) the PCIe
    budget the TPOT math certifies. With no disk or peer traffic this
    reduces exactly to the two-tier model.

    ``iter_time_breakdown_kv`` exposes the same latency decomposed into
    compute / link-queue / disk-queue / peer-queue terms (what the
    telemetry plane records); this wrapper returns its ``total_s``."""
    return iter_time_breakdown_kv(
        times, interval, kv_in_bytes, kv_out_bytes, link_bw,
        disk_in_bytes, disk_out_bytes, disk_bw, disk_latency_s,
        peer_in_bytes, peer_out_bytes, peer_bw, peer_latency_s).total_s


def min_feasible_interval(times: LayerTimes, slo_s: float) -> int:
    """Exact search: smallest interval whose simulated latency meets slo."""
    for i in range(1, times.num_layers + 1):
        if _feasible(iter_time_with_interval(times, i), slo_s):
            return i
    return NO_OFFLOAD


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    """Concrete placement for a stack of ``num_units`` scan units."""
    num_units: int
    interval: int

    @property
    def enabled(self) -> bool:
        return 1 <= self.interval <= self.num_units

    @property
    def num_groups(self) -> int:
        return self.num_units // self.interval if self.enabled else 0

    @property
    def num_offloaded(self) -> int:
        return self.num_groups

    @property
    def num_resident(self) -> int:
        return self.num_units - self.num_offloaded

    @property
    def tail_units(self) -> int:
        """Units after the last full group; always resident."""
        return self.num_units - self.num_groups * self.interval if self.enabled \
            else self.num_units

    def offloaded_indices(self) -> list[int]:
        if not self.enabled:
            return []
        return [g * self.interval + self.interval - 1
                for g in range(self.num_groups)]

    # ---- resource accounting ------------------------------------------------
    def host_bytes(self, layer_bytes: int) -> int:
        return self.num_offloaded * layer_bytes

    def device_bytes(self, layer_bytes: int) -> int:
        # resident layers + two transfer buffers (current + prefetched)
        bufs = 2 if self.enabled else 0
        return (self.num_resident + bufs) * layer_bytes

    def link_bytes_per_iter(self, layer_bytes: int) -> int:
        return self.num_offloaded * layer_bytes

    def link_rate(self, layer_bytes: int, t_iter_s: float) -> float:
        """Host-link bandwidth this plan consumes (paper Fig. 8 lines 4-13)."""
        if t_iter_s <= 0:
            return 0.0
        return self.link_bytes_per_iter(layer_bytes) / t_iter_s


def plan_for(num_units: int, interval: int) -> OffloadPlan:
    return OffloadPlan(num_units=num_units, interval=interval)
