"""Runtime memory manager (§4.3): applies an OffloadPlan to a model.

The layer stack (pattern-period scan units, leaves stacked [R, ...]) is
re-grouped into

    resident  [G, i-1, ...]   -- device HBM
    offloaded [G, ...]        -- pinned_host
    tail      [r, ...]        -- device HBM (units after the last full group)

and the step functions run a scan over G groups. Inside one group, an
explicit in-jit ``device_put`` moves the offloaded unit's weights to device
memory *before* the resident-unit scan (paper Fig. 7: the prefetch is issued
when the first layer of the interval starts computing), so (i-1) units of
compute hide one host transfer — XLA's latency-hiding scheduler has the
structural slack to overlap the copy. Verified to lower on both the TPU
target semantics and the XLA CPU backend (dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.interval import OffloadPlan
from repro.models import layers as L
from repro.models import spec as S
from repro.models import transformer as T
from repro.models.model import Model
from repro.models.spec import TensorSpec

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Splitting stacked trees by plan
# ---------------------------------------------------------------------------


def _is_leaf(x) -> bool:
    return isinstance(x, TensorSpec)


def _split_array(a: jax.Array, plan: OffloadPlan):
    g, i = plan.num_groups, plan.interval
    used = g * i if plan.enabled else 0
    head = a[:used]
    if plan.enabled:
        head = head.reshape(g, i, *a.shape[1:])
        resident = head[:, : i - 1]
        offloaded = head[:, i - 1]
    else:
        resident = a[:0].reshape(0, 1, *a.shape[1:])
        offloaded = a[:0]
    tail = a[used:]
    return resident, offloaded, tail


def _split_spec(s: TensorSpec, plan: OffloadPlan):
    g, i = plan.num_groups, plan.interval
    r = s.shape[0]
    rest, logical = s.shape[1:], s.logical[1:]
    mk = lambda lead: dataclasses.replace(
        s, shape=(*lead, *rest), logical=("stack",) * len(lead) + logical,
        fan_in_axes=tuple(a + len(lead) - 1 for a in s.fan_in_axes))
    if plan.enabled:
        resident = mk((g, i - 1))
        offloaded = mk((g,))
        tail = mk((r - g * i,))
    else:
        resident = mk((0, 1))
        offloaded = mk((0,))
        tail = mk((r,))
    return resident, offloaded, tail


def split_stacked(tree: Any, plan: OffloadPlan) -> dict[str, Any]:
    """Split every leaf (leading dim R) into the three placement groups."""
    def split(leaf):
        if isinstance(leaf, TensorSpec):
            return _split_spec(leaf, plan)
        return _split_array(leaf, plan)

    parts = jax.tree.map(split, tree, is_leaf=_is_leaf)
    pick = lambda k: jax.tree.map(
        lambda p: p[k], parts,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and not isinstance(x, TensorSpec))
    return {"resident": pick(0), "offloaded": pick(1), "tail": pick(2)}


def split_model_params(params: Params, plan: OffloadPlan) -> Params:
    """Model params/spec tree -> offload layout (blocks replaced)."""
    out = dict(params)
    out["blocks"] = split_stacked(params["blocks"], plan)
    return out


def merge_stacked(split: Any, plan: OffloadPlan) -> Any:
    """Inverse of split_stacked for array trees: placement sections back to
    one [R, ...] stack in unit order. Sections may be None (the prefill path
    returns None for empty placements) or zero-length arrays."""
    g, i = plan.num_groups, plan.interval
    res, off, tail = split["resident"], split["offloaded"], split["tail"]
    if not plan.enabled or (res is None and off is None):
        assert tail is not None
        return tail
    if res is None:          # interval == 1: every unit in a group offloaded
        head = off
    else:
        head = jax.tree.map(
            lambda r, o: jnp.concatenate([r, o[:, None]], axis=1)
            .reshape(g * i, *r.shape[2:]), res, off)
    if tail is None:
        return head
    return jax.tree.map(lambda h, t: jnp.concatenate([h, t], axis=0),
                        head, tail)


def merge_model_params(split: Params, plan: OffloadPlan) -> Params:
    """Inverse of split_model_params (arrays only) — checkpoint round-trips."""
    out = dict(split)
    out["blocks"] = merge_stacked(split["blocks"], plan)
    return out


def offload_memory_kind_fn(path: tuple) -> str | None:
    """memory_kind for spec.shardings(): pinned_host under blocks/offloaded."""
    keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
    if "blocks" in keys:
        i = keys.index("blocks")
        if i + 1 < len(keys) and keys[i + 1] == "offloaded":
            return "pinned_host"
    return None


# ---------------------------------------------------------------------------
# Grouped step functions
# ---------------------------------------------------------------------------


def _prefetch(tree: Any, shardings=None):
    """Explicit prefetch: device_put to device-memory shardings at the group
    start (paper Fig. 7 — the copy is issued before the resident-unit
    compute). Without shardings (plain device-resident params, e.g. the CPU
    demo engine), identity: nothing to move."""
    if shardings is None:
        return tree
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def _scan_units(cfg: ModelConfig, apply_unit, x, units_params, units_caches,
                unroll: bool = False):
    """lax.scan over a stacked group of units; handles the 0-length case.

    unroll=True emits a straight-line program: the per-unit cache slices
    become *static*, so XLA updates them in place instead of a
    dynamic-slice/dynamic-update-slice round trip over the whole stacked
    cache every layer (§Perf A3 — halves decode HBM traffic)."""
    n = jax.tree.leaves(units_params)[0].shape[0]
    if n == 0:
        return x, units_caches

    def body(carry, xs):
        p, c = xs
        x2, nc = apply_unit(carry, p, c)
        return x2, nc

    return jax.lax.scan(body, x, (units_params, units_caches),
                        unroll=n if unroll else 1)


@dataclasses.dataclass(frozen=True)
class OffloadRuntime:
    """Bundles a model + plan into offload-aware step callables."""
    model: Model
    plan: OffloadPlan
    # Device-memory shardings for one offloaded unit (leading stack dim
    # dropped); when set, the group body issues an explicit device_put
    # prefetch. None => params already device-resident.
    device_shardings: Any = None
    # Unroll the per-unit scans in decode (static cache slices update in
    # place; see _scan_units). Off for training/prefill where the scan keeps
    # the program compact and remat-friendly.
    unroll_decode: bool = False

    # ----- decode --------------------------------------------------------------
    def decode_step(self, params_split: Params, tokens: jax.Array,
                    pos: jax.Array, caches_split: Any,
                    enc_pos: jax.Array | None = None):
        cfg, model = self.model.cfg, self.model
        vkv = model.virtual_kv

        def apply_unit(x, pslices, cslices):
            new = []
            for j, blk in enumerate(cfg.pattern):
                x, nc = T.apply_block_decode(cfg, blk, pslices[j], x, pos,
                                             cslices[j], vkv, enc_pos)
                new.append(nc)
            return x, new

        x = T.embed_tokens(cfg, params_split, tokens[:, None])
        blk = params_split["blocks"]
        cch = caches_split

        # Single two-index dynamic slice [g] / [g, j] straight out of the
        # carried cache stacks — one ds/dus per layer instead of slicing the
        # whole group block and re-slicing inside (§Perf hillclimb C: the
        # nested-scan double slice was ~40% extra decode HBM traffic).
        def _cache_at(tree, g, j=None):
            nlead = 1 if j is None else 2
            def one(t):
                starts = ((g,) if j is None else (g, j)) \
                    + (0,) * (t.ndim - nlead)
                sizes = (1,) * nlead + t.shape[nlead:]
                return jax.lax.dynamic_slice(t, starts, sizes).reshape(
                    t.shape[nlead:])
            return jax.tree.map(one, tree)

        def _cache_set(tree, new, g, j=None):
            nlead = 1 if j is None else 2
            def one(t, n):
                starts = ((g,) if j is None else (g, j)) \
                    + (0,) * (t.ndim - nlead)
                return jax.lax.dynamic_update_slice(
                    t, n.reshape((1,) * nlead + n.shape), starts)
            return jax.tree.map(one, tree, new)

        new_caches = {}
        g = self.plan.num_groups
        if g > 0:
            def group_body(carry, xs):
                x, res_c, off_c = carry
                gi, res_p, off_p = xs
                off_dev = _prefetch(off_p, self.device_shardings)
                for j in range(self.plan.interval - 1):
                    pj = jax.tree.map(lambda t: t[j], res_p)
                    x, nc = apply_unit(x, pj, _cache_at(res_c, gi, j))
                    res_c = _cache_set(res_c, nc, gi, j)
                x, noc = apply_unit(x, off_dev, _cache_at(off_c, gi))
                off_c = _cache_set(off_c, noc, gi)
                return (x, res_c, off_c), None

            (x, nrc, noc), _ = jax.lax.scan(
                group_body, (x, cch["resident"], cch["offloaded"]),
                (jnp.arange(g), blk["resident"], blk["offloaded"]))
            new_caches["resident"], new_caches["offloaded"] = nrc, noc
        else:
            new_caches["resident"] = cch["resident"]
            new_caches["offloaded"] = cch["offloaded"]
        x, new_caches["tail"] = _scan_units(cfg, apply_unit, x, blk["tail"],
                                            cch["tail"], self.unroll_decode)
        x = L.apply_norm(cfg, params_split["final_norm"], x)
        logits = T.lm_logits(cfg, params_split, x)[:, 0]
        return logits, new_caches

    # ----- paged decode ---------------------------------------------------------
    def paged_decode_step(self, params_split: Params, tokens: jax.Array,
                          pos: jax.Array, pool: jax.Array,
                          block_tables: jax.Array, context_lens: jax.Array,
                          write_frames: jax.Array, write_offsets: jax.Array):
        """One decode iteration through the physical KV page pool.

        Same weight-placement scan as ``decode_step`` (the offloaded unit's
        prefetch still overlaps the resident-unit compute), but instead of
        carrying slot-dense caches the scan carries ``pool`` — the single
        [frames, page, L, 2, vh, hd] buffer the paged Pallas kernel indexes
        through ``block_tables``. Each unit writes the new token's K/V at
        (write_frames, write_offsets) for its global layer index and attends
        over ``context_lens`` tokens. Returns (logits, pool).
        """
        cfg, model = self.model.cfg, self.model
        vkv = model.virtual_kv
        pat = len(cfg.pattern)
        interp = jax.default_backend() != "tpu"

        def apply_unit(x, pslices, unit_idx, pool):
            for j, blk in enumerate(cfg.pattern):
                x, pool = T.apply_block_decode_paged(
                    cfg, blk, pslices[j], x, pos, pool,
                    unit_idx * pat + j, block_tables, context_lens,
                    write_frames, write_offsets, vkv, interp)
            return x, pool

        x = T.embed_tokens(cfg, params_split, tokens[:, None])
        blk = params_split["blocks"]
        g, iv = self.plan.num_groups, self.plan.interval
        if g > 0:
            def group_body(carry, xs):
                x, pool = carry
                gi, res_p, off_p = xs
                off_dev = _prefetch(off_p, self.device_shardings)
                for j in range(iv - 1):
                    pj = jax.tree.map(lambda t: t[j], res_p)
                    x, pool = apply_unit(x, pj, gi * iv + j, pool)
                x, pool = apply_unit(x, off_dev, gi * iv + (iv - 1), pool)
                return (x, pool), None

            (x, pool), _ = jax.lax.scan(
                group_body, (x, pool),
                (jnp.arange(g), blk["resident"], blk["offloaded"]))
        n_tail = jax.tree.leaves(blk["tail"])[0].shape[0]
        for t in range(n_tail):   # unrolled: static layer index per unit
            pt = jax.tree.map(lambda a: a[t], blk["tail"])
            x, pool = apply_unit(x, pt, g * iv + t, pool)
        x = L.apply_norm(cfg, params_split["final_norm"], x)
        logits = T.lm_logits(cfg, params_split, x)[:, 0]
        return logits, pool

    # ----- paged chunk prefill --------------------------------------------------
    def paged_prefill_chunk(self, params_split: Params, tokens: jax.Array,
                            start: jax.Array, pool: jax.Array,
                            block_table: jax.Array, context_len: jax.Array,
                            write_frames: jax.Array,
                            write_offsets: jax.Array):
        """One incremental prefill chunk through the physical KV page pool.

        ``tokens``: [C] — the chunk at absolute positions ``start..start+C-1``
        of one request. Same weight-placement scan as ``paged_decode_step``,
        but each unit writes the chunk's K/V at (write_frames, write_offsets)
        [C] and attends the chunk's queries over the request's resident
        context through ``block_table`` [nb] / ``context_len`` — no prefix
        recompute. Returns (last-position logits [1, V], pool).
        """
        cfg, model = self.model.cfg, self.model
        vkv = model.virtual_kv
        pat = len(cfg.pattern)
        interp = jax.default_backend() != "tpu"
        c = tokens.shape[0]
        posm = (start + jnp.arange(c, dtype=jnp.int32))[None]   # [1, C]

        def apply_unit(x, pslices, unit_idx, pool):
            for j, blk in enumerate(cfg.pattern):
                x, pool = T.apply_block_prefill_paged(
                    cfg, blk, pslices[j], x, posm, pool,
                    unit_idx * pat + j, block_table, context_len,
                    write_frames, write_offsets, vkv, interp)
            return x, pool

        x = T.embed_tokens(cfg, params_split, tokens[None])     # [1, C, D]
        blk = params_split["blocks"]
        g, iv = self.plan.num_groups, self.plan.interval
        if g > 0:
            def group_body(carry, xs):
                x, pool = carry
                gi, res_p, off_p = xs
                off_dev = _prefetch(off_p, self.device_shardings)
                for j in range(iv - 1):
                    pj = jax.tree.map(lambda t: t[j], res_p)
                    x, pool = apply_unit(x, pj, gi * iv + j, pool)
                x, pool = apply_unit(x, off_dev, gi * iv + (iv - 1), pool)
                return (x, pool), None

            (x, pool), _ = jax.lax.scan(
                group_body, (x, pool),
                (jnp.arange(g), blk["resident"], blk["offloaded"]))
        n_tail = jax.tree.leaves(blk["tail"])[0].shape[0]
        for t in range(n_tail):   # unrolled: static layer index per unit
            pt = jax.tree.map(lambda a: a[t], blk["tail"])
            x, pool = apply_unit(x, pt, g * iv + t, pool)
        x = L.apply_norm(cfg, params_split["final_norm"], x)
        logits = T.lm_logits(cfg, params_split, x[:, -1:])[:, 0]
        return logits, pool

    # ----- prefill --------------------------------------------------------------
    def prefill(self, params_split: Params, inputs: dict, cache_len: int,
                attn_impl: str = "chunked", last_pos=None):
        cfg, model = self.model.cfg, self.model
        enc_out = enc_pos = None
        if cfg.encoder_layers > 0:
            enc_out, enc_pos = model.encode(params_split, inputs["enc_embeds"],
                                            attn_impl)
        x = T.embed_tokens(cfg, params_split, inputs["tokens"])
        if cfg.frontend is not None and cfg.family != "audio":
            x = jnp.concatenate(
                [inputs["frontend_embeds"].astype(x.dtype), x], axis=1)
        b, s, _ = x.shape
        posm = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        ctx = T.SeqCtx(positions=posm, want_cache=True, cache_len=cache_len,
                       virtual_kv=model.virtual_kv, enc_out=enc_out,
                       enc_pos=enc_pos, attn_impl=attn_impl)

        def apply_unit(x, pslices, _c):
            caches = []
            for j, blk in enumerate(cfg.pattern):
                x, c, _a = T.apply_block_seq(cfg, blk, pslices[j], x, ctx)
                caches.append(c)
            return x, caches

        blk = params_split["blocks"]

        def group_body(x, xs):
            res_p, off_p = xs
            off_dev = _prefetch(off_p, self.device_shardings)
            n = jax.tree.leaves(res_p)[0].shape[0]
            if n:
                def body(carry, p):
                    x2, c = apply_unit(carry, p, None)
                    return x2, c
                x, res_caches = jax.lax.scan(body, x, res_p)
            else:
                res_caches = None
            x, off_caches = apply_unit(x, off_dev, None)
            return x, (res_caches, off_caches)

        caches: dict[str, Any] = {}
        if self.plan.num_groups > 0:
            x, (rc, oc) = jax.lax.scan(group_body, x,
                                       (blk["resident"], blk["offloaded"]))
            caches["resident"], caches["offloaded"] = rc, oc
        else:
            # No offload groups: nothing cached under these sections (None is
            # a consistent empty pytree for the decode-side scans).
            caches["resident"] = None
            caches["offloaded"] = None
        n_tail = jax.tree.leaves(blk["tail"])[0].shape[0]
        if n_tail:
            def body(carry, p):
                x2, c = apply_unit(carry, p, None)
                return x2, c
            x, caches["tail"] = jax.lax.scan(body, x, blk["tail"])
        else:
            caches["tail"] = None
        x = L.apply_norm(cfg, params_split["final_norm"], x)
        # last_pos: logits position for shape-bucketed prefills whose tokens
        # carry suffix padding — causal attention keeps every position < S
        # bitwise-independent of the padding, but the last ROW is padding,
        # so the caller passes the true last position (traced: one compile
        # serves every prompt length in the bucket)
        h = x[:, -1:] if last_pos is None else \
            jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
        logits = T.lm_logits(cfg, params_split, h)[:, 0]
        return logits, caches, enc_pos

    # ----- cache helpers ---------------------------------------------------------
    def split_caches(self, caches: Any):
        return split_stacked(caches, self.plan)

    def cache_spec_split(self, batch: int, cache_len: int, enc_len: int = 0):
        return split_stacked(
            self.model.cache_spec(batch, cache_len, enc_len), self.plan)

    def spec_split(self) -> Params:
        return split_model_params(self.model.spec, self.plan)

    # ----- accounting ---------------------------------------------------------------
    def memory_report(self) -> dict:
        from repro.core import costs
        ub = costs.unit_weight_bytes(self.model.cfg)
        p, r = T.pattern_info(self.model.cfg)
        other = S.tree_bytes(self.model.spec) - ub * r
        return {
            "unit_bytes": ub,
            "host_bytes": self.plan.host_bytes(ub),
            "device_stack_bytes": self.plan.device_bytes(ub),
            "device_other_bytes": other,
            "link_bytes_per_iter": self.plan.link_bytes_per_iter(ub),
        }


