"""Performance record (§4.4, Table 1).

For each phase (prefill/decode) and SLO bucket (2 ms grid), a table over
(batch, seq) power-of-two buckets storing the optimal (smallest feasible)
offloading interval. Lookups round batch/seq *down* and SLO *down* — both
conservative: assuming less compute-cover and less slack can only produce a
larger (safer) interval.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.interval import NO_OFFLOAD
from repro.core.slo import SLO_GRANULARITY_S, bucket_slo


@dataclasses.dataclass
class PerformanceRecord:
    model_name: str
    hardware: str
    phase: str                      # "prefill" | "decode"
    batches: list[int]              # ascending, powers of two
    seqs: list[int]                 # ascending, powers of two
    # table[slo_bucket_key][bi][si] -> interval
    table: dict[int, list[list[int]]] = dataclasses.field(default_factory=dict)
    # provenance: measured wall-clock or analytic model
    measure: str = "wallclock"

    @staticmethod
    def slo_key(slo_s: float) -> int:
        return int(round(bucket_slo(slo_s) / SLO_GRANULARITY_S))

    def set(self, slo_s: float, batch: int, seq: int, interval: int) -> None:
        k = self.slo_key(slo_s)
        if k not in self.table:
            self.table[k] = [[NO_OFFLOAD] * len(self.seqs)
                             for _ in self.batches]
        bi = self.batches.index(batch)
        si = self.seqs.index(seq)
        self.table[k][bi][si] = interval

    def _bucket_down(self, grid: list[int], v: int) -> int | None:
        idx = None
        for i, g in enumerate(grid):
            if g <= v:
                idx = i
        return idx

    def lookup(self, slo_s: float, batch: int, seq: int) -> int:
        """Optimal interval, conservatively bucketed. NO_OFFLOAD if the SLO
        admits no offloading (or is below any recorded bucket)."""
        keys = sorted(self.table)
        k = self.slo_key(slo_s)
        avail = [x for x in keys if x <= k]
        if not avail:
            return NO_OFFLOAD
        key = avail[-1]
        bi = self._bucket_down(self.batches, batch)
        si = self._bucket_down(self.seqs, seq)
        if bi is None or si is None:
            bi = bi if bi is not None else 0
            si = si if si is not None else 0
        return self.table[key][bi][si]

    # ---- serialization -------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "model_name": self.model_name, "hardware": self.hardware,
            "phase": self.phase, "batches": self.batches, "seqs": self.seqs,
            "measure": self.measure,
            "table": {str(k): v for k, v in self.table.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "PerformanceRecord":
        d = json.loads(s)
        rec = cls(model_name=d["model_name"], hardware=d["hardware"],
                  phase=d["phase"], batches=d["batches"], seqs=d["seqs"],
                  measure=d.get("measure", "wallclock"))
        rec.table = {int(k): v for k, v in d["table"].items()}
        return rec

    def render(self, slo_s: float) -> str:
        """Pretty-print one SLO's table (paper Table 1 style)."""
        k = self.slo_key(slo_s)
        if k not in self.table:
            return "(no record for this SLO)"
        rows = [" b\\s | " + " ".join(f"{s:>6d}" for s in self.seqs)]
        rows.append("-" * len(rows[0]))
        for bi, b in enumerate(self.batches):
            cells = " ".join(
                f"{'inf' if v >= NO_OFFLOAD else v:>6}" for v in self.table[k][bi])
            rows.append(f"{b:>4d} | {cells}")
        return "\n".join(rows)
