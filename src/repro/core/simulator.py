"""Two-stream discrete-event simulator (paper Fig. 7).

Simulates the compute stream and the copy stream of one inference iteration
under any offloading policy, with per-layer compute times (hybrids like jamba
have heterogeneous layers) and a shared host link. This is the validation
harness for the interval algebra and the engine behind the paper-figure
benchmarks (SLO maintenance, contention, throughput).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class LayerSchedule:
    """Offload schedule of one instance for one iteration."""
    t_compute_s: Sequence[float]        # per layer
    transfer_s: Sequence[float]         # per layer; 0.0 = resident
    prefetch_start_layer: Sequence[int]  # layer index at which its transfer may start
    t_rest_s: float = 0.0
    # Two-tier KV traffic sharing the copy stream (serving.kv_offload):
    # kv_in gates layer-0 compute (swapped-in pages must land before
    # attention reads them); kv_out is issued right after (demoted pages
    # vacate device frames) and queues the weight prefetches behind it.
    kv_in_s: float = 0.0
    kv_out_s: float = 0.0


def schedule_for_interval(t_compute_s: Sequence[float], interval: int,
                          t_transfer_s: float, t_rest_s: float = 0.0,
                          lookahead_groups: int = 1,
                          kv_in_s: float = 0.0,
                          kv_out_s: float = 0.0) -> LayerSchedule:
    """Select-N schedule: every interval-th layer offloaded, prefetch issued
    at the first layer of the group (lookahead_groups=1) or earlier."""
    n = len(t_compute_s)
    transfer = [0.0] * n
    start = [0] * n
    if 1 <= interval <= n:
        groups = n // interval
        for g in range(groups):
            off = g * interval + interval - 1
            transfer[off] = t_transfer_s
            start[off] = max(0, (g - (lookahead_groups - 1)) * interval)
    return LayerSchedule(tuple(t_compute_s), tuple(transfer), tuple(start),
                         t_rest_s, kv_in_s=kv_in_s, kv_out_s=kv_out_s)


def schedule_deepspeed(t_compute_s: Sequence[float],
                       t_transfer_s: float, t_rest_s: float = 0.0
                       ) -> LayerSchedule:
    """DeepSpeed ZeRO-Inference: every layer offloaded, prefetch of layer j
    starts when layer j-1 starts (one-layer lookahead)."""
    n = len(t_compute_s)
    return LayerSchedule(
        tuple(t_compute_s), tuple([t_transfer_s] * n),
        tuple([max(0, j - 1) for j in range(n)]), t_rest_s)


def schedule_flexgen(t_compute_s: Sequence[float], fraction: float,
                     t_transfer_full_s: float, t_rest_s: float = 0.0
                     ) -> LayerSchedule:
    """FlexGen: a fixed fraction of every layer offloaded, one-layer
    lookahead prefetch."""
    n = len(t_compute_s)
    return LayerSchedule(
        tuple(t_compute_s), tuple([fraction * t_transfer_full_s] * n),
        tuple([max(0, j - 1) for j in range(n)]), t_rest_s)


def simulate_iteration(sched: LayerSchedule, bw_fraction: float = 1.0
                       ) -> dict:
    """Run one iteration; returns latency and stream utilization.

    bw_fraction scales every transfer (contention from bus neighbours).
    """
    n = len(sched.t_compute_s)
    scale = 1.0 / max(bw_fraction, 1e-9)
    # KV swap traffic leads the copy stream: swap-in gates layer-0 compute,
    # write-back overlaps compute but delays the first weight prefetch.
    t_kv_in = sched.kv_in_s * scale
    t_kv_out = sched.kv_out_s * scale
    # Transfers execute in layer order on a single copy stream.
    xfer_done = [0.0] * n
    copy_free = t_kv_in + t_kv_out
    compute_start = [0.0] * n
    t = t_kv_in
    stall = t_kv_in
    busy_copy = t_kv_in + t_kv_out

    # Precompute, for each layer j, the transfers whose prefetch window opens
    # at j (prefetch_start_layer == j).
    opens: list[list[int]] = [[] for _ in range(n)]
    for j in range(n):
        if sched.transfer_s[j] > 0:
            opens[sched.prefetch_start_layer[j]].append(j)

    pending: list[int] = []
    for j in range(n):
        compute_start[j] = t
        for k in opens[j]:
            pending.append(k)
        # issue pending transfers in order
        while pending:
            k = pending.pop(0)
            s = max(copy_free, t)
            d = s + sched.transfer_s[k] * scale
            xfer_done[k] = d
            copy_free = d
            busy_copy += sched.transfer_s[k] * scale
        if sched.transfer_s[j] > 0:
            wait = max(0.0, xfer_done[j] - t)
            stall += wait
            t += wait
        t += sched.t_compute_s[j]
    total = t + sched.t_rest_s
    return {
        "latency_s": total,
        "stall_s": stall,
        "compute_s": sum(sched.t_compute_s) + sched.t_rest_s,
        "copy_busy_s": busy_copy,
        "copy_util": busy_copy / total if total > 0 else 0.0,
    }


def simulate_shared_bus(scheds: Sequence[LayerSchedule],
                        link_bw_fraction_each: Sequence[float] | None = None,
                        total_bw: float = 1.0,
                        demands: Sequence[float] | None = None) -> list[dict]:
    """Instances sharing one host link.

    If the coordinator admitted them (sum of rates <= link), each instance
    sees its full requested bandwidth. If demands oversubscribe the link,
    every transfer is stretched by the oversubscription factor — the
    fair-share fluid model of PCIe arbitration.
    """
    if demands is not None:
        total = sum(demands)
        factor = min(1.0, total_bw / total) if total > 0 else 1.0
        fractions = [factor] * len(scheds)
    elif link_bw_fraction_each is not None:
        fractions = list(link_bw_fraction_each)
    else:
        fractions = [1.0] * len(scheds)
    return [simulate_iteration(s, f) for s, f in zip(scheds, fractions)]
