"""Latency SLO types (§2.2): TTFT for prefill, TPOT for decode."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SLO:
    """A latency target in seconds for one phase."""
    kind: str           # "ttft" | "tpot"
    target_s: float

    def scaled(self, factor: float) -> "SLO":
        return SLO(self.kind, self.target_s * factor)


@dataclasses.dataclass(frozen=True)
class RequestSLO:
    ttft_s: float
    tpot_s: float

    @property
    def ttft(self) -> SLO:
        return SLO("ttft", self.ttft_s)

    @property
    def tpot(self) -> SLO:
        return SLO("tpot", self.tpot_s)


# The paper's record granularity: SLOs are bucketed at 2 ms (§4.4).
SLO_GRANULARITY_S = 0.002


def bucket_slo(target_s: float) -> float:
    """Round DOWN to the grid (conservative: never assume more slack)."""
    return int(target_s / SLO_GRANULARITY_S) * SLO_GRANULARITY_S
