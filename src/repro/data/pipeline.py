"""Deterministic synthetic data pipeline.

The paper's workload is a "randomly designed dataloader" (§5.1) — workload
content does not change the systems behaviour (deterministic layer times), so
a seeded token stream is the faithful substrate. The pipeline is
host-sharded: every host materializes only its slice of the global batch
(Philox counter-based, so step N is reproducible from (seed, step, host)
without any coordination), then assembles a global jax.Array for the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.frontends import frontend_positions


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # synthetic request mix for serving benches
    mean_prompt_len: int = 256
    mean_output_len: int = 64


class SyntheticTokenStream:
    """Deterministic [B, S] token/label batches for training."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, dcfg: DataConfig,
                 mesh: Mesh | None = None):
        self.cfg, self.shape, self.dcfg = cfg, shape, dcfg
        self.mesh = mesh
        self.n_front = frontend_positions(cfg, shape)

    def _host_batch(self, step: int, lo: int, hi: int) -> dict[str, np.ndarray]:
        """Rows [lo, hi) of the global batch at ``step``."""
        cfg, shape = self.cfg, self.shape
        rng = np.random.Generator(np.random.Philox(
            key=self.dcfg.seed, counter=[step, lo, 0, 0]))
        s_tok = shape.seq_len - (self.n_front
                                 if cfg.frontend and cfg.family != "audio" else 0)
        toks = rng.integers(0, cfg.vocab_size, size=(hi - lo, s_tok + 1),
                            dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.encoder_layers > 0:
            out["enc_embeds"] = rng.standard_normal(
                (hi - lo, shape.seq_len, cfg.d_model), dtype=np.float32) * 0.02
        elif cfg.frontend is not None:
            out["frontend_embeds"] = rng.standard_normal(
                (hi - lo, self.n_front, cfg.d_model), dtype=np.float32) * 0.02
        return out

    def batch(self, step: int) -> dict[str, jax.Array]:
        b = self.shape.global_batch
        if self.mesh is None:
            host = self._host_batch(step, 0, b)
            return {k: jnp.asarray(v) for k, v in host.items()}
        # Host-sharded assembly: every process builds its addressable rows.
        out = {}
        host = self._host_batch(step, 0, b)  # single-process container
        for k, v in host.items():
            spec = P(("pod", "data") if "pod" in self.mesh.axis_names
                     else ("data",), *([None] * (v.ndim - 1)))
            arr = jnp.asarray(v)
            if v.dtype == np.float32 and k != "tokens":
                arr = arr.astype(jnp.bfloat16)
            out[k] = jax.device_put(arr, NamedSharding(self.mesh, spec))
        return out

    def __iter__(self) -> Iterator[dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class SyntheticRequest:
    rid: int
    prompt_len: int
    max_new_tokens: int
    ttft_slo_s: float
    tpot_slo_s: float
    arrival_s: float


def request_stream(dcfg: DataConfig, n: int, *, ttft_slo_s: float,
                   tpot_slo_s: float, rate_per_s: float = 4.0
                   ) -> list[SyntheticRequest]:
    """Poisson arrivals with geometric lengths (paper §5.1 style)."""
    rng = np.random.Generator(np.random.Philox(key=dcfg.seed + 1))
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(1.0 / rate_per_s)
        out.append(SyntheticRequest(
            rid=i,
            prompt_len=int(np.clip(rng.geometric(
                1.0 / dcfg.mean_prompt_len), 8, 4096)),
            max_new_tokens=int(np.clip(rng.geometric(
                1.0 / dcfg.mean_output_len), 4, 1024)),
            ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s, arrival_s=t))
    return out
