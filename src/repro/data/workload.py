"""Trace-driven production workload generator (ROADMAP item 5).

The serving benches so far replayed 2-16 request bursts; this module emits
thousand-request traces with absolute ``arrival_s`` on the modeled clock so
the arrival-aware engine loop (``ServingEngine.run``) can hold each request
invisible to the scheduler until its arrival. Shapes modeled, after
production-stack's multi-round-qa exemplar:

  * **arrival processes** — homogeneous Poisson (exponential gaps) or
    diurnal (nonhomogeneous Poisson, rate ``lambda(t) = rate * (1 +
    diurnal_amplitude * sin(2*pi*t / diurnal_period_s))`` sampled by
    thinning);
  * **multi-round chat sessions** — a session opens with a system prompt
    shared across ALL sessions (what prefix dedup deduplicates) — or, with
    ``tenants > 1``, with its tenant's system prompt, shared across that
    tenant's sessions only — every round's prompt extends the session's own
    growing history prefix, and rounds are spaced by exponential think time;
  * **mixed SLO classes** — each session draws one ``(ttft_slo_s,
    tpot_slo_s)`` class (interactive / standard / batch style) with
    configurable weights;
  * **long-tail prompt lengths** — lognormal per-round user turns, clipped
    to the engine's sequence budget.

Determinism: everything derives from one Philox counter-based generator
keyed on ``seed`` (the ``data.pipeline`` convention), so trace N is
reproducible from its config alone. The output is a flat,
arrival-sorted ``list[Request]`` — ``repro.serving.request.Request`` is a
plain dataclass, so this stays importable without JAX compile machinery.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class SLOClass:
    name: str
    ttft_slo_s: float
    tpot_slo_s: float
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    seed: int = 0
    # arrival process
    process: str = "poisson"            # "poisson" | "diurnal"
    rate_per_s: float = 4.0             # mean arrival rate (sessions/s)
    diurnal_amplitude: float = 0.5      # peak-to-mean swing, in [0, 1)
    diurnal_period_s: float = 60.0
    # session shape
    mean_rounds: float = 3.0            # geometric number of chat rounds
    mean_think_s: float = 1.0           # exponential gap between rounds
    system_prompt_len: int = 32         # shared across every session
    # multi-tenant traces: with tenants > 1 each session draws a tenant id
    # uniformly and opens with that TENANT's system prompt instead of the
    # global one, so same-tenant sessions share identical leading
    # ``prefix_page_keys`` (the fleet router's affinity signal) while
    # different tenants diverge from page 0. tenants == 1 keeps the legacy
    # single shared prompt and makes no extra RNG draws (bitwise-identical
    # traces for every existing config).
    tenants: int = 1
    # per-round user turn: lognormal long tail, clipped to max_prompt_len
    median_turn_len: int = 24
    turn_len_sigma: float = 0.8
    max_prompt_len: int = 512           # cap on the full (history) prompt
    mean_output_len: float = 16.0       # geometric decode budget per round
    max_output_len: int = 256
    vocab_size: int = 128
    slo_classes: tuple[SLOClass, ...] = (
        SLOClass("interactive", ttft_slo_s=0.2, tpot_slo_s=0.04, weight=0.5),
        SLOClass("standard", ttft_slo_s=0.5, tpot_slo_s=0.1, weight=0.35),
        SLOClass("batch", ttft_slo_s=2.0, tpot_slo_s=0.5, weight=0.15),
    )


def _session_arrivals(rng: np.random.Generator, cfg: WorkloadConfig,
                      n: int) -> list[float]:
    """Arrival time of each session's FIRST round."""
    if cfg.process == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate_per_s, size=n)
        return list(np.cumsum(gaps))
    if cfg.process != "diurnal":
        raise ValueError(f"unknown arrival process {cfg.process!r}")
    # thinning of a nonhomogeneous Poisson process: propose at the peak
    # rate, accept with probability lambda(t)/lambda_max
    lam_max = cfg.rate_per_s * (1.0 + cfg.diurnal_amplitude)
    out: list[float] = []
    t = 0.0
    while len(out) < n:
        t += rng.exponential(1.0 / lam_max)
        lam_t = cfg.rate_per_s * (1.0 + cfg.diurnal_amplitude
                                  * math.sin(2 * math.pi * t
                                             / cfg.diurnal_period_s))
        if rng.random() * lam_max <= lam_t:
            out.append(t)
    return out


def generate_workload(cfg: WorkloadConfig, n_requests: int) -> list[Request]:
    """Emit ``n_requests`` requests (across as many sessions as needed),
    sorted by ``arrival_s``. Round k of a session carries the session's full
    accumulated context — system prompt + every earlier round's tokens — as
    a growing shared prefix, which is exactly what ``--prefix-dedup``
    content-addresses."""
    rng = np.random.Generator(np.random.Philox(key=cfg.seed))
    system = rng.integers(0, cfg.vocab_size, cfg.system_prompt_len
                          ).astype(np.int32)
    # per-tenant system prompts (tenant 0 keeps the legacy draw above, so a
    # tenants=1 config reproduces pre-tenant traces bitwise)
    tenant_systems = [system]
    for _ in range(1, max(cfg.tenants, 1)):
        tenant_systems.append(rng.integers(0, cfg.vocab_size,
                                           cfg.system_prompt_len
                                           ).astype(np.int32))
    starts = _session_arrivals(rng, cfg, n_requests)  # upper bound: >=1/sess
    reqs: list[Request] = []
    rid = 0
    for t0 in starts:
        if rid >= n_requests:
            break
        rounds = int(rng.geometric(1.0 / max(cfg.mean_rounds, 1.0)))
        tenant = int(rng.integers(0, cfg.tenants)) if cfg.tenants > 1 else 0
        history = tenant_systems[tenant]
        t = t0
        cls = rng.choice(len(cfg.slo_classes),
                         p=_weights(cfg.slo_classes))
        slo = cfg.slo_classes[int(cls)]
        for _ in range(rounds):
            if rid >= n_requests:
                break
            turn_len = int(np.clip(
                rng.lognormal(math.log(max(cfg.median_turn_len, 1)),
                              cfg.turn_len_sigma), 1, cfg.max_prompt_len))
            turn = rng.integers(0, cfg.vocab_size, turn_len).astype(np.int32)
            prompt = np.concatenate([history, turn])[-cfg.max_prompt_len:]
            new = int(np.clip(rng.geometric(1.0 / cfg.mean_output_len),
                              1, cfg.max_output_len))
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=new,
                                ttft_slo_s=slo.ttft_slo_s,
                                tpot_slo_s=slo.tpot_slo_s, arrival_s=t,
                                tenant=tenant))
            rid += 1
            # the next round's history includes this round's turn (the
            # modeled reply tokens are not knowable at trace time; the
            # growing user-side context is what feeds dedup)
            history = prompt
            t += rng.exponential(cfg.mean_think_s)
    reqs.sort(key=lambda r: r.arrival_s)
    for i, r in enumerate(reqs):
        r.rid = i                      # rids follow arrival order
    return reqs


def _weights(classes: tuple[SLOClass, ...]) -> np.ndarray:
    w = np.asarray([c.weight for c in classes], np.float64)
    return w / w.sum()
