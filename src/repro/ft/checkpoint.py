"""Sharded, atomic, async checkpoints.

Layout on disk:
    <dir>/step_000123.tmp-<nonce>/   (written)
        manifest.json                (tree structure, shapes, dtypes)
        shard_<host>.npz             (this host's addressable slices)
    <dir>/step_000123/               (atomic rename commit)

Restore re-shards: each leaf is rebuilt via make_array_from_callback against
the *target* sharding, so a checkpoint taken on one mesh restores onto any
other (elastic scale up/down) — slices are re-read per device from the saved
full-leaf buffers. Single-process here; the per-host shard file layout is what
a multi-host deployment writes (each host saves only addressable shards).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: dict | None = None) -> str:
    """Write atomically; returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest: dict[str, Any] = {"step": step, "extra": extra or {},
                                "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            manifest["leaves"][key] = {"dtype": "bfloat16",
                                       "shape": list(arr.shape)}
            arr = arr.view(np.uint16)
        else:
            manifest["leaves"][key] = {"dtype": str(arr.dtype),
                                       "shape": list(arr.shape)}
        arrays[key.replace("/", "__")] = arr
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target`` (arrays or SDS tree).

    If ``shardings`` given (or target leaves carry shardings), leaves are
    assembled shard-by-shard against them — elastic restore onto any mesh.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(target)
    shd_flat = (jax.tree.leaves(shardings) if shardings is not None
                else [None] * len(flat_t))
    out = []
    for (pth, leaf), shd in zip(flat_t, shd_flat):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pth)
        info = manifest["leaves"][key]
        raw = data[key.replace("/", "__")]
        if info["dtype"] == "bfloat16":
            arr = jnp.asarray(raw.view(np.uint16)).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(raw)
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        if shd is None and hasattr(leaf, "sharding") and \
                getattr(leaf, "sharding", None) is not None and \
                not isinstance(leaf, jax.ShapeDtypeStruct):
            shd = leaf.sharding
        if shd is not None:
            host = np.asarray(arr)
            arr = jax.make_array_from_callback(
                host.shape, shd, lambda idx, h=host: h[idx])
        out.append(arr)
    return jax.tree.unflatten(treedef, [l for l in out]), manifest["extra"]


class AsyncCheckpointer:
    """Fire-and-forget background saves with a bounded queue of one: a new
    save waits for the previous one (so at most one tmp dir exists)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO off-thread
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and ".tmp" not in d)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
