"""Elastic re-meshing: resume a job on a different device count.

A checkpoint carries no mesh information — leaves are full logical arrays
(assembled per-host shards). On restart we rebuild the mesh from whatever
devices exist, re-resolve shardings through the same rules, and restore. The
data-parallel axis absorbs the size change; tensor-parallel degree is kept
stable by preference (re-sharding TP changes per-device layouts but stays
correct — the rules' divisibility fallback guards impossible splits).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.configs.base import ModelConfig
from repro.ft import checkpoint as ckpt
from repro.models import spec as S
from repro.models.model import build_model
from repro.sharding.rules import make_rules


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]

    @staticmethod
    def for_devices(n_devices: int, tp_preference: int = 16) -> "ElasticPlan":
        """Factor n_devices into (data, model), keeping TP stable when the
        device count allows it and degrading gracefully otherwise."""
        tp = tp_preference
        while tp > 1 and n_devices % tp != 0:
            tp //= 2
        return ElasticPlan((n_devices // tp, tp), ("data", "model"))

    def make_mesh(self):
        from repro.launch.mesh import make_mesh_compat
        return make_mesh_compat(self.mesh_shape, self.axis_names)


def resume(cfg: ModelConfig, directory: str, *, tp_preference: int = 16
           ) -> tuple[Any, dict, Any]:
    """Restore the latest checkpoint onto a mesh built from current devices.

    Returns (params, extra, mesh)."""
    step = ckpt.latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    plan = ElasticPlan.for_devices(len(jax.devices()), tp_preference)
    mesh = plan.make_mesh()
    rules = make_rules(cfg, mesh)
    model = build_model(cfg, tp=mesh.shape["model"])
    target = S.abstract(model.spec)
    shardings = S.shardings(model.spec, mesh, rules)
    params, extra = ckpt.restore_checkpoint(directory, step, target, shardings)
    return params, extra, mesh
