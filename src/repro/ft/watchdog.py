"""Straggler / anomaly watchdog.

Tracks per-step (or per-iteration) wall times with an EWMA + deviation bound.
A straggling host shows up as a step-time spike; the mitigation hook ties
into the Select-N knob: raising the offloading interval sheds host-link work
from the straggler (beyond-paper use of the paper's own mechanism), and the
coordinator redistributes the freed bandwidth.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class WatchdogConfig:
    alpha: float = 0.1           # EWMA smoothing
    warmup_steps: int = 5
    slow_factor: float = 1.5     # step considered straggling beyond this
    hard_timeout_s: float | None = None


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(),
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.ewma: float | None = None
        self.steps = 0
        self.events: list[dict] = []
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self.observe(dt)
        return dt

    def observe(self, dt: float) -> bool:
        """Feed one step duration; returns True if flagged as straggling."""
        self.steps += 1
        flagged = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if (self.steps > self.cfg.warmup_steps
                    and dt > self.cfg.slow_factor * self.ewma):
                flagged = True
                self.events.append({"step": self.steps, "dt": dt,
                                    "ewma": self.ewma})
                if self.on_straggler:
                    self.on_straggler(self.steps, dt, self.ewma)
            # straggler samples pollute the mean less
            a = self.cfg.alpha * (0.25 if flagged else 1.0)
            self.ewma = (1 - a) * self.ewma + a * dt
        if (self.cfg.hard_timeout_s is not None
                and dt > self.cfg.hard_timeout_s):
            raise TimeoutError(
                f"step {self.steps} took {dt:.2f}s "
                f"(> {self.cfg.hard_timeout_s}s hard timeout)")
        return flagged
