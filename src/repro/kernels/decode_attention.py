"""Pallas TPU paged GQA decode attention (TPOT hot spot).

One query token per request reads its KV pages through a block table. The
block table and context lengths ride in scalar-prefetch memory (SMEM) so the
page index map can chase them; online softmax runs over pages with VMEM
scratch. Grid (B, n_pages), pages innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(bt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, page: int,
                   vh: int, g: int, d: int, nb: int, window: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cl = cl_ref[b]
    live = j * page < cl
    if window > 0:
        live &= (j + 1) * page > cl - window

    @pl.when(live)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale          # [H, D]
        qr = q.reshape(vh, g, d)
        k = k_ref[0].astype(jnp.float32)                  # [page, V, D]
        # [V, G, D] x [V, page, D] -> [V, G, page]
        s = jax.lax.dot_general(
            qr, k.transpose(1, 0, 2), (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (vh, g, page), 2)
        valid = kpos < cl
        if window > 0:
            valid &= kpos >= cl - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]                               # [V, G]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        vv = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)   # [V, page, D]
        pv = jax.lax.dot_general(p, vv, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o = acc_scr[...] / l[..., None]                   # [V, G, D]
        o_ref[0] = o.reshape(vh * g, d).astype(o_ref.dtype)


def paged_decode_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array, block_tables: jax.Array,
                                  context_lens: jax.Array, *,
                                  window: int = 0,
                                  interpret: bool = True) -> jax.Array:
    """q: [B,H,D]; pages: [npages, page, V, D]; block_tables: [B, nb] int32;
    context_lens: [B] int32. Returns [B,H,D].

    Padded batches are first-class: table entries past a request's last live
    page may hold any value (they are clamped into the pool range before the
    index map chases them and masked by ``context_lens``), and a row with
    ``context_lens[b] <= 0`` — an idle batch slot — produces a zero output
    instead of reading anything. ``context_lens`` is likewise clamped to the
    table's capacity ``nb * page`` so an oversized length cannot index past
    the last table column. Runs under the Pallas interpreter off-TPU
    (``interpret=True``), which is how CPU CI executes it.
    """
    b, h, d = q.shape
    npages, page, vh, _ = k_pages.shape
    nb = block_tables.shape[1]
    g = h // vh
    # harden padded inputs: every table entry must be a valid frame id for
    # the prefetch index map, every length must fit the table
    block_tables = jnp.clip(block_tables.astype(jnp.int32), 0, npages - 1)
    context_lens = jnp.clip(context_lens.astype(jnp.int32), 0, nb * page)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(d), page=page, vh=vh, g=g, d=d,
        nb=nb, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, j, bt, cl: (b_, 0, 0)),
            pl.BlockSpec((1, page, vh, d),
                         lambda b_, j, bt, cl: (bt[b_, j], 0, 0, 0)),
            pl.BlockSpec((1, page, vh, d),
                         lambda b_, j, bt, cl: (bt[b_, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, j, bt, cl: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((vh, g), jnp.float32),
            pltpu.VMEM((vh, g), jnp.float32),
            pltpu.VMEM((vh, g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pages, v_pages)
