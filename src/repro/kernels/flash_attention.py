"""Pallas TPU flash attention (prefill hot spot).

Online-softmax tiling: grid (B, H, Sq/bq, Sk/bk), KV innermost; running
(m, l, acc) live in VMEM scratch across KV steps. Causal and sliding-window
masks prune fully-masked KV blocks with @pl.when, GQA via head-group index
mapping. fp32 accumulation; MXU-aligned default tiles (128 x head_dim).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, sq: int, kv_len: int, pos_offset: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level pruning: skip KV blocks that are entirely masked.
    q_lo = i * bq + pos_offset                 # absolute pos of first query
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    live = k_lo < kv_len
    if causal:
        live &= k_lo <= q_hi
        if window > 0:
            live &= k_hi > q_lo - window

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_len
        if causal:
            valid &= kpos <= qpos
            if window > 0:
                valid &= kpos > qpos - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        vv = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, vv, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           kv_len: int | None = None, pos_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: [B,H,Sq,D]; k/v: [B,V,Sk,D], V | H. Pads Sq/Sk to block multiples."""
    b, h, sq, d = q.shape
    vh, sk = k.shape[1], k.shape[2]
    g = h // vh
    kv_len = kv_len if kv_len is not None else sk
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))

    pq = (bq - sq % bq) % bq
    pk = (bk - sk % bk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // bq
    nk = k.shape[2] // bk

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, sq=sq, kv_len=kv_len,
        pos_offset=pos_offset + (kv_len - sq if causal else 0))

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :, :sq]
    return out
