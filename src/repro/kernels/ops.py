"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernels via
the Pallas interpreter); on a TPU backend the compiled kernels run natively.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention import paged_decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "kv_len",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    kv_len: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, kv_len=kv_len,
        block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                           window: int = 0, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, block_tables, context_lens, window=window,
        interpret=interp)
