"""Jitted public wrappers for the Pallas kernels + KV page copy paths.

``interpret`` defaults to True off-TPU (this container validates kernels via
the Pallas interpreter); on a TPU backend the compiled kernels run natively.

The page copy helpers move whole KV pages between the device page pool
(``[npages, page, ...]``, the buffer the paged decode kernel indexes through
block tables) and a host pool (numpy — host memory on every backend; on a
TPU host this is the pinned staging buffer). They are the data plane of
serving.kv_offload's two-tier allocator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import paged_decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "kv_len",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    kv_len: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, kv_len=kv_len,
        block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                           window: int = 0, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, block_tables, context_lens, window=window,
        interpret=interp)


# ---------------------------------------------------------------------------
# KV page migration (two-tier host offloading data plane)
# ---------------------------------------------------------------------------


@jax.jit
def gather_kv_pages(pages: jax.Array, page_ids: jax.Array) -> jax.Array:
    """Read pages ``page_ids`` out of a ``[npages, page, ...]`` pool."""
    return jnp.take(pages, page_ids, axis=0)


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_kv_pages(pages: jax.Array, page_ids: jax.Array,
                     values: jax.Array) -> jax.Array:
    """Write ``values`` (``[n, page, ...]``) into pool frames ``page_ids``.
    The pool buffer is donated: XLA updates the frames in place instead of
    rematerializing a multi-GB pool per migration batch. Batch one
    iteration's migrations into a single call."""
    return pages.at[page_ids].set(values)


def pack_token_pages(k_all: np.ndarray, v_all: np.ndarray, page_size: int,
                     dtype=None) -> np.ndarray:
    """Pack per-layer prefill KV into combined page values.

    ``k_all``/``v_all``: [L, S, vh, hd] (global layer order). Returns
    [n_pages, page, L, 2, vh, hd] — the trailing page is zero-padded past S
    (decode fills those slots later). This is the value layout of the
    engine's single physical page pool: one page holds every layer's K and V
    for ``page_size`` consecutive token positions, so one ``scatter_kv_pages``
    call lands a whole prefill.
    """
    L, S, vh, hd = k_all.shape
    n = -(-S // page_size)
    dt = dtype or k_all.dtype
    out = np.zeros((n, page_size, L, 2, vh, hd), dt)
    kt = np.zeros((n * page_size, L, vh, hd), dt)
    vt = np.zeros((n * page_size, L, vh, hd), dt)
    kt[:S] = np.transpose(k_all, (1, 0, 2, 3))
    vt[:S] = np.transpose(v_all, (1, 0, 2, 3))
    out[:, :, :, 0] = kt.reshape(n, page_size, L, vh, hd)
    out[:, :, :, 1] = vt.reshape(n, page_size, L, vh, hd)
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def copy_pages_on_device(pages: jax.Array, src_ids: jax.Array,
                         dst_ids: jax.Array) -> jax.Array:
    """Copy-on-write data plane: duplicate frames ``src_ids`` into frames
    ``dst_ids`` within the same pool (one batched gather+scatter; the pool
    buffer is donated so XLA updates in place). The source frames are read
    before the scatter, so src/dst lists may interleave freely as long as
    they are disjoint."""
    return pages.at[dst_ids].set(jnp.take(pages, src_ids, axis=0))


def copy_pages_to_host(device_pages: jax.Array, device_ids,
                       host_pool: np.ndarray, host_ids) -> None:
    """Swap-out: device frames -> host pool slots (in place on the host
    side; the device pool is unchanged — its frames get recycled by the
    allocator)."""
    if len(device_ids) == 0:
        return
    got = gather_kv_pages(device_pages, jnp.asarray(device_ids, jnp.int32))
    host_pool[np.asarray(host_ids)] = np.asarray(got)


def copy_pages_from_host(host_pool: np.ndarray, host_ids,
                         device_pages: jax.Array, device_ids) -> jax.Array:
    """Swap-in: host pool slots -> device frames. Returns the updated device
    pool (functional, jit-compatible scatter)."""
    if len(device_ids) == 0:
        return device_pages
    vals = jnp.asarray(host_pool[np.asarray(host_ids)],
                       dtype=device_pages.dtype)
    return scatter_kv_pages(device_pages, jnp.asarray(device_ids, jnp.int32),
                            vals)
