"""Pallas TPU paged GQA chunk-prefill attention (incremental prefill).

One prompt chunk of C query tokens attends against the request's resident
paged KV — the prefix pages written by earlier chunks plus the chunk's own
freshly written pages — through a block table, so chunked prefill computes
O(C * prefix) work per chunk instead of recomputing the whole prefix
(quadratic across the schedule). Same template as the decode kernel: block
table and (context_len, start) metadata ride in scalar-prefetch SMEM, the
grid walks pages, and online softmax runs in VMEM scratch sized for the
whole chunk's query rows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _chunk_kernel(bt_ref, meta_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page: int,
                  vh: int, g: int, d: int, c: int, nb: int):
    j = pl.program_id(0)
    cl = meta_ref[0]
    start = meta_ref[1]
    cg = c * g

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * page < cl)
    def _update():
        # q rows laid out [V, C*G]: row r = token (r // g), group lane r % g
        q = q_ref[...].astype(jnp.float32) * scale         # [C, H, D]
        qr = q.reshape(c, vh, g, d).transpose(1, 0, 2, 3).reshape(vh, cg, d)
        k = k_ref[0].astype(jnp.float32)                   # [page, V, D]
        # [V, C*G, D] x [V, page, D] -> [V, C*G, page]
        s = jax.lax.dot_general(
            qr, k.transpose(1, 0, 2), (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        row = jax.lax.broadcasted_iota(jnp.int32, (vh, cg, page), 1)
        col = jax.lax.broadcasted_iota(jnp.int32, (vh, cg, page), 2)
        qpos = start + row // g
        kpos = j * page + col
        # causal within the full context: a chunk query at absolute position
        # qpos sees every key at kpos <= qpos (qpos < cl always holds, so no
        # separate length mask is needed)
        valid = kpos <= qpos

        m_prev = m_scr[...]                                # [V, C*G]
        m_new = jnp.maximum(m_prev,
                            jnp.max(jnp.where(valid, s, NEG_INF), axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        vv = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)   # [V, page, D]
        pv = jax.lax.dot_general(p, vv, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o = acc_scr[...] / l[..., None]                    # [V, C*G, D]
        o_ref[...] = o.reshape(vh, c, g, d).transpose(1, 0, 2, 3).reshape(
            c, vh * g, d).astype(o_ref.dtype)


def paged_chunk_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array, block_table: jax.Array,
                                 start: jax.Array, context_len: jax.Array, *,
                                 interpret: bool = True) -> jax.Array:
    """q: [C,H,D] — the chunk's C query tokens at absolute positions
    ``start .. start+C-1``; pages: [npages, page, V, D]; block_table: [nb]
    int32 covering the request's pages 0..ceil(context_len/page)-1;
    ``context_len`` = start + C (the chunk's own KV is already in the pool).
    Returns [C,H,D].

    Table entries past the last live page may hold any value (clamped into
    pool range, masked by the causal bound); ``start``/``context_len`` are
    clamped to the table capacity. Runs under the Pallas interpreter
    off-TPU, which is how CPU CI executes it.
    """
    c, h, d = q.shape
    npages, page, vh, _ = k_pages.shape
    nb = block_table.shape[0]
    g = h // vh
    block_table = jnp.clip(block_table.astype(jnp.int32), 0, npages - 1)
    context_len = jnp.clip(context_len.astype(jnp.int32), 0, nb * page)
    start = jnp.clip(start.astype(jnp.int32), 0, context_len)
    meta = jnp.stack([context_len, start])

    kernel = functools.partial(
        _chunk_kernel, scale=1.0 / math.sqrt(d), page=page, vh=vh, g=g, d=d,
        c=c, nb=nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((c, h, d), lambda j, bt, meta: (0, 0, 0)),
            pl.BlockSpec((1, page, vh, d),
                         lambda j, bt, meta: (bt[j], 0, 0, 0)),
            pl.BlockSpec((1, page, vh, d),
                         lambda j, bt, meta: (bt[j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((c, h, d), lambda j, bt, meta: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((vh, c * g), jnp.float32),
            pltpu.VMEM((vh, c * g), jnp.float32),
            pltpu.VMEM((vh, c * g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table, meta, q, k_pages, v_pages)
