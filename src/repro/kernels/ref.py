"""Pure-jnp oracles for the Pallas kernels. Dense, O(S^2) memory — used only
for correctness validation at small shapes."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        kv_len: int | None = None) -> jax.Array:
    """q: [B,H,Sq,D]; k/v: [B,V,Sk,D] with V | H. Returns [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    vh, sk = k.shape[1], k.shape[2]
    g = h // vh
    qf = q.reshape(b, vh, g, sq, d).astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bvgqd,bvkd->bvgqk", qf, k.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    valid = jnp.ones((sq, sk), bool)
    if causal:
        # decode-style offset: query position sq-1 aligns with kv position
        # kv_len-1 when sq != sk
        off = (kv_len if kv_len is not None else sk) - sq
        valid &= kpos <= qpos + off
        if window > 0:
            valid &= kpos > qpos + off - window
    if kv_len is not None:
        valid &= kpos < kv_len
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bvgqk,bvkd->bvgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def ref_paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               context_lens: jax.Array, *,
                               window: int = 0) -> jax.Array:
    """q: [B,H,D]; pages: [npages, page, V, D]; block_tables: [B, nb];
    context_lens: [B]. Returns [B,H,D]."""
    b, h, d = q.shape
    npages, page, vh, _ = k_pages.shape
    nb = block_tables.shape[1]
    g = h // vh

    def per_req(qr, bt, cl):
        k = k_pages[bt]          # [nb, page, V, D]
        v = v_pages[bt]
        k = k.reshape(nb * page, vh, d)
        v = v.reshape(nb * page, vh, d)
        qf = qr.reshape(vh, g, d).astype(jnp.float32) / math.sqrt(d)
        s = jnp.einsum("vgd,svd->vgs", qf, k.astype(jnp.float32))
        kpos = jnp.arange(nb * page)
        valid = kpos < cl
        if window > 0:
            valid &= kpos >= cl - window
        s = jnp.where(valid[None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        o = jnp.einsum("vgs,svd->vgd", p, v.astype(jnp.float32))
        return o.reshape(h, d).astype(qr.dtype)

    return jax.vmap(per_req)(q, block_tables, context_lens)
