"""Multi-pod dry run: lower + compile every (architecture × input-shape ×
mesh) cell on the production meshes, record memory/cost/collective analysis.

This proves the distribution config is coherent without real hardware:
sharding mismatches, compile-time OOMs, and unsupported collectives all fail
here. Results feed EXPERIMENTS.md §Dry-run and §Roofline.

NOTE: the os.environ lines below MUST run before any other import (jax locks
the device count at first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape decode_32k --mesh both --offload-interval 4
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import time
import traceback
from collections import Counter
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, LM_SHAPES, cell_is_runnable,
                           get_config, get_shape)
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import costs
from repro.core.interval import NO_OFFLOAD, OffloadPlan
from repro.core.memory_manager import (OffloadRuntime,
                                       offload_memory_kind_fn,
                                       split_model_params)
from repro.launch import hlo_costs
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import spec as S
from repro.models.frontends import encoder_len
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.models.spec import tree_map_spec
from repro.sharding.rules import make_rules, named_sharding, sharding_context
from repro.training.train_loop import (TrainConfig, build_train_step,
                                       opt_state_spec)

# ---------------------------------------------------------------------------
# Collective-byte extraction from compiled HLO
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"= (?:\([^)]*\)|\S+) (all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def _result_bytes(line: str, op_start: int) -> int:
    # result shape(s) sit between '=' and the opcode:
    #   %all-reduce.2 = f32[4,256]{1,0} all-reduce(%dot.1), ...
    eq = line.find("=")
    if eq < 0:
        return 0
    region = line[eq + 1: op_start]
    total = 0
    for m in _SHAPE_RE.finditer(region):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, default_group: int) -> dict:
    """Per-device wire bytes by collective kind (ring-model factors).

    Counts sync collectives and async -start ops (the -done halves are
    skipped to avoid double counting)."""
    out: Counter = Counter()
    count: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line[m.start(1): m.start(1) + 30]:
            continue
        kind = m.group(1)
        rb = _result_bytes(line, m.start(1))
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else default_group
        g = max(g, 2)
        if kind == "all-gather":
            moved = rb * (g - 1) / g
        elif kind == "all-reduce":
            moved = 2 * rb * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = rb * (g - 1)
        elif kind == "all-to-all":
            moved = rb * (g - 1) / g
        else:  # collective-permute
            moved = rb
        out[kind] += int(moved)
        count[kind] += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return {"bytes": dict(out), "count": dict(count)}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, interval: int,
               unroll_decode: bool = False) -> tuple[Any, tuple, dict]:
    """Returns (fn, example_args (SDS), meta). fn is ready for jit."""
    rules = make_rules(cfg, mesh, step=shape.step, global_batch=shape.global_batch)
    model = build_model(cfg, tp=mesh.shape["model"])
    ins = input_specs(cfg, shape, mesh, rules)
    meta: dict[str, Any] = {}

    if shape.step == "train":
        pspec = model.spec
        params_sds = S.abstract_with_sharding(pspec, mesh, rules)
        opt_sds = S.abstract_with_sharding(opt_state_spec(model), mesh, rules)
        step = build_train_step(model, TrainConfig())
        batch = {k: v for k, v in ins.items()}

        def fn(params, opt_state, batch):
            with sharding_context(mesh, rules):
                return step(params, opt_state, batch)

        meta["donate"] = (0, 1)  # params + opt state update in place
        return fn, (params_sds, opt_sds, batch), meta

    def _dev_shardings(pspec):
        # device-memory shardings for one offloaded unit (drop stack dim)
        return tree_map_spec(
            lambda ts: named_sharding(mesh, rules, ts.shape[1:],
                                      ts.logical[1:], memory_kind="device"),
            pspec["blocks"]["offloaded"])

    if shape.step == "prefill":
        plan = OffloadPlan(pattern_info(cfg)[1], interval)
        rt = OffloadRuntime(model=model, plan=plan)
        pspec = rt.spec_split()
        if plan.enabled:
            rt = OffloadRuntime(model=model, plan=plan,
                                device_shardings=_dev_shardings(pspec))
        params_sds = S.abstract_with_sharding(pspec, mesh, rules,
                                              offload_memory_kind_fn)
        meta["offload"] = rt.memory_report()

        def fn(params, inputs):
            with sharding_context(mesh, rules):
                return rt.prefill(params, inputs, cache_len=shape.seq_len)

        return fn, (params_sds, ins), meta

    # decode
    plan = OffloadPlan(pattern_info(cfg)[1], interval)
    rt = OffloadRuntime(model=model, plan=plan, unroll_decode=unroll_decode)
    pspec = rt.spec_split()
    if plan.enabled:
        rt = OffloadRuntime(model=model, plan=plan,
                            device_shardings=_dev_shardings(pspec),
                            unroll_decode=unroll_decode)
    params_sds = S.abstract_with_sharding(pspec, mesh, rules,
                                          offload_memory_kind_fn)
    enc = encoder_len(cfg, shape)
    cspec = rt.cache_spec_split(shape.global_batch, shape.seq_len, enc)
    caches_sds = S.abstract_with_sharding(cspec, mesh, rules)
    meta["offload"] = rt.memory_report()
    meta["cache_bytes_global"] = S.tree_bytes(
        rt.model.cache_spec(shape.global_batch, shape.seq_len, enc))
    enc_pos = ins.get("enc_pos")

    meta["donate"] = (3,)  # in-place KV/state cache update

    def fn(params, tokens, pos, caches, enc_pos=None):
        with sharding_context(mesh, rules):
            return rt.decode_step(params, tokens, pos, caches, enc_pos)

    args = (params_sds, ins["tokens"], ins["pos"], caches_sds)
    if enc_pos is not None:
        args = args + (enc_pos,)
    return fn, args, meta


def run_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, mesh_name: str,
             interval: int = NO_OFFLOAD, verbose: bool = True,
             unroll_decode: bool = False) -> dict:
    t0 = time.time()
    res: dict[str, Any] = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "interval": None if interval >= NO_OFFLOAD else interval,
        "unroll_decode": unroll_decode or None,
    }
    try:
        fn, args, meta = build_cell(cfg, shape, mesh, interval, unroll_decode)
        donate = meta.pop("donate", ())
        with sharding_context(mesh, make_rules(cfg, mesh, step=shape.step, global_batch=shape.global_batch)):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_comp = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        ndev = mesh.devices.size
        # While-aware accounting: XLA's aggregate counts loop bodies once
        # and charges whole buffers to slice fusions (see hlo_costs.py).
        hc = hlo_costs.analyze(txt, default_group=ndev)
        coll = {"bytes": {**{k: int(v) for k, v in
                             hc.collective_bytes.items()},
                          "total": int(hc.collective_total)},
                "count": hc.collective_count}

        res.update({
            "ok": True,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_comp, 1),
            "flops_per_device": hc.flops,
            "bytes_accessed_per_device": hc.hbm_bytes_native,
            "bytes_accessed_as_compiled": hc.hbm_bytes,
            "xla_raw": {"flops": ca.get("flops", 0.0),
                        "bytes_accessed": ca.get("bytes accessed", 0.0)},
            "collectives": coll,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": ma.peak_memory_in_bytes,
            },
            **meta,
        })
        # our own host/device accounting (CPU memory_analysis cannot separate)
        rules = make_rules(cfg, mesh, step=shape.step, global_batch=shape.global_batch)
        model = build_model(cfg, tp=mesh.shape["model"])
        res["param_bytes_global"] = S.tree_bytes(model.spec)
        res["model_flops_global"] = costs.model_flops(cfg, shape)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        res.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    res["wall_s"] = round(time.time() - t0, 1)
    if verbose:
        stat = "OK " if res.get("ok") else "FAIL"
        print(f"[{stat}] {cfg.name:24s} {shape.name:12s} {mesh_name:8s} "
              f"wall={res['wall_s']:7.1f}s "
              + (f"peak={res['memory']['peak_bytes']/2**30:.2f}GiB "
                 f"coll={res['collectives']['bytes']['total']/2**30:.2f}GiB"
                 if res.get("ok") else res["error"][:160]),
              flush=True)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--offload-interval", type=int, default=0,
                    help="also lower the offloaded variant at this interval")
    ap.add_argument("--unroll-decode", action="store_true",
                    help="unroll decode layer scans (perf experiment A3; "
                         "measured slower — kept for reproducibility)")
    ap.add_argument("--out", default="reports/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(LM_SHAPES)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            shape = get_shape(shape_name)
            ok, why = cell_is_runnable(cfg, shape)
            if not ok:
                results.append({"arch": arch, "shape": shape_name,
                                "skipped": why})
                print(f"[SKIP] {arch:24s} {shape_name:12s} {why}", flush=True)
                continue
            for mesh_name, mesh in meshes:
                results.append(run_cell(cfg, shape, mesh, mesh_name,
                                        unroll_decode=args.unroll_decode))
                if args.offload_interval and shape.step != "train":
                    results.append(run_cell(cfg, shape, mesh, mesh_name,
                                            interval=args.offload_interval))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    n_fail = sum(1 for r in results if r.get("ok") is False)
    n_skip = sum(1 for r in results if "skipped" in r)
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"-> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
