"""While-loop-aware cost extraction from compiled HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless of
trip count — a ``lax.scan`` over 64 layers under-reports FLOPs by 64x; its
"bytes accessed" also counts whole-buffer operands of slice fusions (a
one-token cache update "accesses" the entire multi-GiB cache). Both make the
aggregate useless for roofline work on scan-structured models.

This module re-derives the three roofline inputs from ``compiled.as_text()``:

  * ``flops``            — dot/convolution shape math + elementwise counts,
                            each op weighted by the product of trip counts of
                            the while loops enclosing it;
  * ``hbm_bytes``        — per-op operand+result traffic with slice-aware
                            fusion accounting (a fused dynamic-slice read
                            counts the slice, not the buffer);
  * ``collective_bytes`` — per-device wire bytes under ring models
                            (all-gather (g-1)/g, all-reduce 2(g-1)/g,
                            reduce-scatter (g-1), all-to-all (g-1)/g,
                            collective-permute 1), trip-count weighted.

Validated against unrolled-loop ground truth in tests/test_hlo_costs.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import Counter, defaultdict

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(%s)\[([0-9,]*)\]" % "|".join(_DTYPE_BYTES))
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(?:\([^)]*\)|[\w\[\]\{\},.\- ]+?)\s+([\w\-]+)\(")
_OPERAND_NAME_RE = re.compile(r"%[\w\.\-]+")


def _parse_operands(after: str) -> list[str]:
    """Operand names from the text following the opcode. Handles both operand
    list styles XLA prints: bare ``(%a, %b)`` and typed
    ``(f32[128,256]{1,0} %a, (f32[2], s32[]) %b)`` — the region is delimited
    by the *balanced* closing paren so tuple-typed operands stay inside."""
    i = after.find("(")
    if i < 0:
        return []
    depth = 0
    j = len(after)
    for k in range(i, len(after)):
        if after[k] == "(":
            depth += 1
        elif after[k] == ")":
            depth -= 1
            if depth == 0:
                j = k
                break
    return _OPERAND_NAME_RE.findall(after[i + 1:j])
_CALLS_RE = re.compile(r"calls=(%[\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=(%[\w\.\-]+), body=(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "clamp",
    "remainder", "atan2", "shift-left", "shift-right-logical",
    "shift-right-arithmetic",
}
_TRANSCENDENTAL = {"exponential", "exponential-minus-one", "log", "log-plus-one",
                   "tanh", "rsqrt", "sqrt", "cbrt", "logistic", "sine",
                   "cosine", "tan", "erf", "expm1", "log1p"}
_ZERO_FLOP = {"copy", "bitcast", "reshape", "transpose", "broadcast", "iota",
              "constant", "parameter", "get-tuple-element", "tuple", "slice",
              "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
              "convert", "reduce-precision", "pad", "concatenate", "reverse",
              "fusion", "while", "conditional", "call", "custom-call",
              "partition-id", "replica-id", "bitcast-convert", "copy-start",
              "copy-done", "after-all", "rng-bit-generator", "domain",
              "optimization-barrier", "infeed", "outfeed", "map", "sort"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: str          # result type text (before the opcode)
    operands: list[str]
    attrs: str           # full remainder of the line (no metadata)
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.split(" metadata={")[0].rstrip()
        line = re.sub(r"/\*[^*]*\*/", "", line)   # strip /*index=N*/ comments
        stripped = line.strip()
        if not stripped:
            continue
        # computation header: `%name (args) -> type {` or `ENTRY %name ...`
        if stripped.endswith("{") and ("(" in stripped) and "= " not in stripped:
            header = stripped
            if header.startswith("ENTRY"):
                header = header[len("ENTRY"):].strip()
            name = header.split()[0].rstrip("(")
            name = name.split("(")[0]
            cur = Computation(name=name, ops=[])
            comps[name] = cur
            if header.startswith("ENTRY") or "ENTRY" in raw:
                comps["__entry__"] = cur
            continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        om = _OPCODE_RE.match(rest)
        opcode = om.group(1) if om else ""
        # result type = text before the opcode token
        result = rest[: om.start(1)] if om else rest
        # operands: the balanced (...) group after the opcode
        operands: list[str] = []
        if om:
            operands = _parse_operands(rest[om.end(1):])
        cur.ops.append(Op(name, opcode, result, operands, rest, line))
        if "ENTRY" in raw.split("=")[0]:
            comps["__entry__"] = cur
    return comps


def _entry(comps: dict[str, Computation], hlo: str) -> Computation:
    if "__entry__" in comps:
        return comps["__entry__"]
    m = re.search(r"^ENTRY\s+(%[\w\.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return comps[m.group(1)]
    raise ValueError("no ENTRY computation found")


def _trip_count(cond: Computation, shapes: dict[str, str]) -> int:
    """Loop bound from the condition: max integer constant referenced."""
    best = 1
    for op in cond.ops:
        for m in _CONST_RE.finditer(op.attrs):
            best = max(best, int(m.group(1)))
        for o in op.operands:
            d = shapes.get(o, "")
            cm = _CONST_RE.search(d)
            if cm:
                best = max(best, int(cm.group(1)))
    return best


def _multipliers(comps: dict[str, Computation], entry: Computation,
                 defs: dict[str, str]) -> dict[str, float]:
    """Execution count per computation (product of enclosing trip counts).
    Fusion/call targets inherit the caller's count; while bodies multiply."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            wm = _WHILE_RE.search(op.attrs)
            if op.opcode == "while" and wm:
                cond_n, body_n = wm.groups()
                trips = _trip_count(comps[cond_n], defs) if cond_n in comps \
                    else 1
                for tgt, f in ((body_n, trips), (cond_n, trips + 1)):
                    mult[tgt] += m * f
                    if tgt not in seen:
                        seen.add(tgt)
                        order.append(tgt)
                continue
            cm = _CALLS_RE.search(op.attrs)
            if cm:
                tgt = cm.group(1)
                mult[tgt] += m
                if tgt not in seen:
                    seen.add(tgt)
                    order.append(tgt)
            bm = _BRANCHES_RE.search(op.attrs)
            if bm:
                for tgt in (t.strip() for t in bm.group(1).split(",")):
                    mult[tgt] += m
                    if tgt not in seen:
                        seen.add(tgt)
                        order.append(tgt)
    return dict(mult)


_INT_DTYPES = {"s32", "u32", "s64", "u64", "s16", "u16", "s8", "u8", "pred"}


def _result_dtype(text: str) -> str:
    m = _SHAPE_RE.search(text)
    return m.group(1) if m else ""


def _op_flops(op: Op, defs: dict[str, str]) -> float:
    if op.opcode in _ZERO_FLOP or not op.opcode:
        return 0.0
    # Integer/predicate arithmetic is loop control and index math (scan trip
    # counters, while conditions, dynamic-slice offsets) — not floating-point
    # work. Counting it breaks scan/unrolled flop equivalence: the unrolled
    # program has no loop-control ops at all.
    if op.opcode in _ELEMENTWISE and _result_dtype(op.result) in _INT_DTYPES:
        return 0.0
    elems = _shape_elems(op.result)
    if op.opcode == "dot":
        k = 1
        cm = _CONTRACT_RE.search(op.attrs)
        if cm and op.operands:
            lhs_dims = _shape_dims(defs.get(op.operands[0], ""))
            for idx in (int(x) for x in cm.group(1).split(",") if x):
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
        return 2.0 * elems * k
    if op.opcode == "convolution":
        # 2 * result * (kernel elems / output features): output feature dim
        # appears in both kernel and result, divide it out.
        kern = _shape_elems(defs.get(op.operands[1], "")) if len(op.operands) > 1 else 1
        rdims = _shape_dims(op.result)
        out_f = rdims[-1] if rdims else 1
        return 2.0 * elems * max(kern // max(out_f, 1), 1)
    if op.opcode == "reduce" or op.opcode == "reduce-window":
        src = _shape_elems(defs.get(op.operands[0], "")) if op.operands else elems
        return float(max(src, elems))
    if op.opcode in _TRANSCENDENTAL or op.opcode in _ELEMENTWISE:
        return float(elems)
    if op.opcode in _COLLECTIVES or op.opcode.endswith("-done"):
        return 0.0
    return float(elems)   # conservative default for rare ops


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}
# Size-preserving ops treated as transparent aliases of their first operand
# when classifying fusion-parameter traffic (on TPU bf16 there is no convert;
# the XLA-CPU f32 round-trip must not count as a full-buffer read).
_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "transpose",
                "bitcast-convert", "reduce-precision"}


def _fusion_bytes(comp: Computation, defs: dict[str, str],
                  call_operands: list[str], result_text: str) -> float:
    """Slice-aware traffic of one fusion execution: params read through
    slices count the slice size; whole-buffer writes through
    dynamic-update-slice count the update size."""
    params = [op for op in comp.ops if op.opcode == "parameter"]
    param_bytes = {op.name: _shape_bytes(op.result) for op in params}
    # alias chains: value name -> root parameter (through pass-through ops)
    alias: dict[str, str] = {p: p for p in param_bytes}

    def root(name: str) -> str | None:
        return alias.get(name)

    # classify each param: sliced-only or fully read
    sliced: dict[str, float] = {}
    fully: set[str] = set()
    dus_write: float | None = None
    for op in comp.ops:
        if op.opcode in _PASSTHROUGH and op.operands:
            r = root(op.operands[0])
            if r is not None:
                alias[op.name] = r
                continue
        if op.opcode in ("dynamic-update-slice", "scatter") \
                and len(op.operands) >= 2:
            # in-place update: read+write the update window, not the buffer
            base = root(op.operands[0])
            upd = op.operands[2] if op.opcode == "scatter" \
                and len(op.operands) >= 3 else op.operands[1]
            upd_root = root(upd)
            ub = (param_bytes.get(upd_root or "", 0)
                  or _shape_bytes(defs.get(upd, ""))
                  or _shape_bytes(comp_result(comp, upd)))
            if upd_root is not None:
                fully.add(upd_root)
            if op.opcode == "scatter" and len(op.operands) >= 3:
                ir = root(op.operands[1])
                if ir is not None:
                    fully.add(ir)      # indices are read
            if base is not None:
                fully.discard(base)
                sliced.setdefault(base, 0.0)
                dus_write = float(ub or 0.0)
            # index operands are scalars; ignore
            continue
        for pos, o in enumerate(op.operands):
            r = root(o)
            if r is None:
                continue
            if op.opcode in _SLICE_OPS and pos == 0:
                sliced[r] = sliced.get(r, 0.0) + _shape_bytes(op.result)
            else:
                fully.add(r)
    read = 0.0
    for p, b in param_bytes.items():
        if p in fully:
            read += b
        elif p in sliced:
            read += sliced[p]
        # unused params: 0
    write = dus_write if dus_write is not None else _shape_bytes(result_text)
    return read + write


def comp_result(comp: Computation, name: str) -> str:
    for op in comp.ops:
        if op.name == name:
            return op.result
    return ""


def _op_bytes(op: Op, defs: dict[str, str],
              comps: dict[str, Computation]) -> float:
    if op.opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "while", "conditional", "call",
                     "after-all", "partition-id", "replica-id", ""):
        return 0.0
    if op.opcode in _COLLECTIVES or op.opcode.endswith(("-start", "-done")):
        return 0.0            # wire traffic accounted separately
    if op.opcode == "fusion":
        cm = _CALLS_RE.search(op.attrs)
        if cm and cm.group(1) in comps:
            return _fusion_bytes(comps[cm.group(1)], defs, op.operands,
                                 op.result)
    res = _shape_bytes(op.result)
    if op.opcode in _SLICE_OPS:
        return 2.0 * res      # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = _shape_bytes(defs.get(op.operands[1], "")) if len(op.operands) > 1 else res
        return 2.0 * upd      # read update + write region (in-place)
    if op.opcode == "scatter":
        upd = _shape_bytes(defs.get(op.operands[2], "")) if len(op.operands) > 2 else res
        idx = _shape_bytes(defs.get(op.operands[1], "")) if len(op.operands) > 1 else 0
        return 2.0 * upd + idx
    ops_b = sum(_shape_bytes(defs.get(o, "")) for o in op.operands)
    return res + ops_b


def _collective_moved(op: Op, defs: dict[str, str], default_group: int) -> tuple[str, float]:
    kind = op.opcode.replace("-start", "")
    rb = _shape_bytes(op.result)
    if op.opcode.endswith("-start"):
        # result of a start op is a tuple (operand, result[, contexts]);
        # use the operand sizes instead to avoid double counting
        rb = sum(_shape_bytes(defs.get(o, "")) for o in op.operands) or rb // 2
    gm = _GROUPS_RE.search(op.attrs)
    g = int(gm.group(2)) if gm else default_group
    g = max(g, 2)
    if kind == "all-gather":
        moved = rb * (g - 1) / g
    elif kind == "all-reduce":
        moved = 2 * rb * (g - 1) / g
    elif kind == "reduce-scatter":
        moved = rb * (g - 1)
    elif kind == "all-to-all":
        moved = rb * (g - 1) / g
    else:                      # collective-permute
        moved = rb
    return kind, moved


@dataclasses.dataclass
class HloCosts:
    flops: float
    hbm_bytes: float          # as compiled (XLA-CPU f32/layout artifacts in)
    hbm_bytes_native: float   # excluding pure data-movement artifact ops
    collective_bytes: dict[str, float]
    collective_count: dict[str, int]
    trip_weighted: bool = True

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


_ARTIFACT_ONLY = _PASSTHROUGH | {"parameter", "constant", "broadcast",
                                 "tuple", "get-tuple-element", "iota"}


def _is_artifact(op: Op, comps: dict[str, Computation]) -> bool:
    """Pure data-movement ops a TPU-native lowering would not materialize:
    top-level copies (donation/layout), and fusions containing only
    convert/copy/transpose/broadcast chains (the XLA-CPU bf16->f32 round
    trips and layout normalizations)."""
    if op.opcode == "copy":
        return True
    if op.opcode == "fusion":
        cm = _CALLS_RE.search(op.attrs)
        if cm and cm.group(1) in comps:
            return all(o.opcode in _ARTIFACT_ONLY
                       for o in comps[cm.group(1)].ops)
    return False


def analyze(hlo: str, default_group: int = 2) -> HloCosts:
    comps = parse_module(hlo)
    entry = _entry(comps, hlo)
    defs: dict[str, str] = {}
    for comp in comps.values():
        if comp.name == "__entry__":
            continue
        for op in comp.ops:
            defs[op.name] = op.result if op.opcode != "constant" \
                else op.result + " " + op.attrs
    mult = _multipliers({k: v for k, v in comps.items()
                         if k != "__entry__"}, entry, defs)

    flops = 0.0
    hbm = 0.0
    hbm_native = 0.0
    coll: Counter = Counter()
    ccount: Counter = Counter()
    fused_names = set()
    for comp in comps.values():
        if comp.name == "__entry__":
            continue
        for op in comp.ops:
            cm = _CALLS_RE.search(op.attrs)
            if op.opcode == "fusion" and cm:
                fused_names.add(cm.group(1))
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        inside_fusion = cname in fused_names
        for op in comp.ops:
            if not inside_fusion:
                b = m * _op_bytes(op, defs, comps)
                hbm += b
                if not _is_artifact(op, comps):
                    hbm_native += b
            flops += m * _op_flops(op, defs)
            if op.opcode in _COLLECTIVES and not op.opcode.endswith("-done"):
                kind, moved = _collective_moved(op, defs, default_group)
                coll[kind] += m * moved
                ccount[kind] += int(m)
    return HloCosts(flops=flops, hbm_bytes=hbm, hbm_bytes_native=hbm_native,
                    collective_bytes=dict(coll),
                    collective_count=dict(ccount))
