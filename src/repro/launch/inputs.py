"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
no device allocation) for every model input of a (arch × shape) cell."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.frontends import encoder_len, frontend_positions
from repro.sharding.rules import Rules, named_sharding


def _sds(mesh: Mesh, rules: Rules, shape: tuple[int, ...],
         logical: tuple, dtype) -> jax.ShapeDtypeStruct:
    s = named_sharding(mesh, rules, shape, logical)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=s)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: Rules
                ) -> dict[str, Any]:
    """Step-function inputs for the cell (excl. params/caches, built from the
    model spec trees)."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    out: dict[str, Any] = {}

    if shape.step in ("train", "prefill"):
        n_front = frontend_positions(cfg, shape)
        if cfg.encoder_layers > 0:
            out["enc_embeds"] = _sds(mesh, rules, (b, encoder_len(cfg, shape), d),
                                     ("batch", None, None), jnp.bfloat16)
            s_tok = s
        else:
            if cfg.frontend is not None:
                out["frontend_embeds"] = _sds(mesh, rules, (b, n_front, d),
                                              ("batch", None, None),
                                              jnp.bfloat16)
            s_tok = s - (n_front if cfg.family != "audio" else 0)
        out["tokens"] = _sds(mesh, rules, (b, s_tok), ("batch", None),
                             jnp.int32)
        if shape.step == "train":
            out["labels"] = _sds(mesh, rules, (b, s_tok), ("batch", None),
                                 jnp.int32)
    else:  # decode
        out["tokens"] = _sds(mesh, rules, (b,), ("batch",), jnp.int32)
        out["pos"] = _sds(mesh, rules, (b,), ("batch",), jnp.int32)
        if cfg.encoder_layers > 0:
            out["enc_pos"] = _sds(mesh, rules, (b, encoder_len(cfg, shape)),
                                  ("batch", None), jnp.int32)
    return out
