"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state. The dry-run forces 512 host devices *before* any
jax import (see dryrun.py); smoke tests and benches see 1 device.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape: tuple, axes: tuple):
    """jax.make_mesh across jax versions: axis_types/AxisType only exist on
    newer releases; older ones default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods = 512
    chips (pod, data, model). Nothing binds to pod=2 — the same rules extend
    to any pod count."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_from_spec(spec: str):
    """'16x16' -> (data, model); '2x16x16' -> (pod, data, model);
    '1x1' -> degenerate single-device mesh for CPU smoke runs."""
    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) == 2:
        axes = ("data", "model")
    elif len(dims) == 3:
        axes = ("pod", "data", "model")
    else:
        raise ValueError(f"mesh spec {spec!r}")
    return make_mesh_compat(dims, axes)
