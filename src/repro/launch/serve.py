"""End-to-end serving driver: SLO-aware engine with Select-N offloading.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --requests 12 --tpot-slo-ms 60 --hbm-gb 0.05

Builds the offline performance record (the analyzer's two-stage step 1),
starts an engine, replays a synthetic request stream, and reports SLO
attainment + throughput. ``--peer`` starts a second engine sharing the host
link to exercise the per-bus coordinator (step 2). ``--fleet N`` starts N
instances behind a KV-affinity ``Router`` (``--router round_robin`` for the
baseline) with cross-instance preemption and the fleet-wide link-budget
coordinator; the run always audits every instance's trace plus the
cross-instance migration conservation and exits 3 on any violation.
``--disagg`` splits the fleet into ``--prefill-instances`` prefill-role and
``--decode-instances`` decode-role engines: prompts route to the prefill
side, completed prefills hand their KV pages off through the PEER tier to
whichever decode instance certifies the transfer, and the audit adds the
handoff conservation invariant (bytes exported == bytes imported, per
link) — exit 3 again on any violation.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import PRESETS
from repro.data.pipeline import DataConfig, request_stream
from repro.data.workload import SLOClass, WorkloadConfig, generate_workload
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.fleet import Fleet
from repro.serving.request import Request


def build_engine(name: str, cfg, hw, ecfg: EngineConfig,
                 slo_grid_s, measure: str = "model") -> ServingEngine:
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, hw, measure=measure)
    batches = [1, 2, 4, 8, 16, 32, 64, 128]
    seqs = [16, 32, 64, 128, 256, 512, 1024]
    batches = [b for b in batches if b <= ecfg.max_batch * 2]
    seqs = [s for s in seqs if s <= max(ecfg.max_seq * 2, 32)]
    rec_p = an.generate_record(slo_grid_s, batches, seqs, "prefill")
    rec_d = an.generate_record(slo_grid_s, batches, seqs, "decode")
    return ServingEngine(name, model, hw, rec_p, rec_d, an.layer_times, ecfg)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--hw", default="a10", choices=list(PRESETS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--ttft-slo-ms", type=float, default=500.0)
    ap.add_argument("--tpot-slo-ms", type=float, default=100.0)
    ap.add_argument("--hbm-gb", type=float, default=0.05)
    ap.add_argument("--host-kv-gb", type=float, default=0.0,
                    help="pinned-host KV pool (two-tier KV offloading); "
                         "0 disables the host tier")
    ap.add_argument("--disk-kv-gb", type=float, default=0.0,
                    help="NVMe (disk) KV tier below the host pool: parked "
                         "requests and aged-out prefix-cache frames retire "
                         "here under host pressure; 0 disables the tier")
    ap.add_argument("--disk-bw-gbps", type=float, default=3.0,
                    help="disk link bandwidth in GB/s (its traffic gets "
                         "its own term in the SLO latency model)")
    ap.add_argument("--disk-backing-path", default=None,
                    help="file path for the disk pool (np.memmap); default "
                         "keeps a RAM buffer standing in for NVMe")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens (the paged decode kernel's "
                         "block granularity)")
    ap.add_argument("--prefix-dedup", action="store_true",
                    help="content-address prompt pages across requests: "
                         "shared prefixes map onto the same physical frames "
                         "(refcounted, copy-on-write)")
    ap.add_argument("--shared-prefix-frac", type=float, default=0.0,
                    help="fraction of the prompt budget drawn from one "
                         "common prefix (chat-style system prompt; what "
                         "--prefix-dedup deduplicates)")
    ap.add_argument("--preemption", action="store_true",
                    help="preempt-to-host: park an active victim's whole KV "
                         "on the host tier when a queued request cannot be "
                         "admitted (wait-only otherwise)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="chunked prefill: scatter long prompts in "
                         "page-aligned chunks of this many tokens, "
                         "piggybacked on decode iterations (0 = one-shot "
                         "prefill at admission)")
    ap.add_argument("--async-data-plane", action="store_true",
                    help="double-buffered copy-stage engine: stage "
                         "iteration i+1's physical page copies (park legs, "
                         "disk retirements, resume promotions, resume "
                         "prefetch) while iteration i decodes, draining at "
                         "iteration boundaries (default: synchronous "
                         "copies inside the issuing iteration)")
    ap.add_argument("--incremental-prefill", action="store_true",
                    help="chunked prefills attend only the new chunk's "
                         "queries against resident paged KV instead of "
                         "recomputing the whole prefix per chunk (requires "
                         "--prefill-chunk-tokens; incompatible with "
                         "--prefix-dedup)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="mean arrival rate in requests/s on the modeled "
                         "clock. Arrivals are HONORED: a request stays "
                         "invisible to the scheduler until the clock "
                         "reaches its arrival_s (see --submit-all)")
    ap.add_argument("--arrival-process", choices=["poisson", "diurnal"],
                    default="poisson",
                    help="arrival process shape; 'diurnal' modulates the "
                         "rate sinusoidally (nonhomogeneous Poisson)")
    ap.add_argument("--workload", choices=["stream", "chat"],
                    default="stream",
                    help="request source: 'stream' = i.i.d. Poisson "
                         "request_stream; 'chat' = multi-round session "
                         "generator (data.workload: growing shared context "
                         "feeding prefix dedup, mixed SLO classes, "
                         "long-tail prompts)")
    ap.add_argument("--diurnal-period-s", type=float, default=60.0,
                    help="period of the diurnal rate modulation")
    ap.add_argument("--submit-all", action="store_true",
                    help="compat path: replay the whole trace as a burst at "
                         "clock 0 instead of honoring arrival_s")
    ap.add_argument("--autotune", action="store_true",
                    help="online interval autotuning (the paper's §5 online "
                         "stage): re-pick the offloading interval every "
                         "iteration inside the offline record's feasible "
                         "range from runtime gauges, lifting host-ward "
                         "when TPOT headroom allows and retreating before "
                         "a predicted violation")
    ap.add_argument("--peer", action="store_true",
                    help="second engine on the same host link (coordinator)")
    ap.add_argument("--fleet", type=int, default=1,
                    help="number of independent serving instances; > 1 "
                         "starts a Fleet with a Router placing each arrival "
                         "by claimed prefix hits, queue depth and link "
                         "pressure, cross-instance preemption migrating "
                         "parked requests off overloaded instances, and "
                         "the fleet-wide link-budget coordinator")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode fleet: prompts route "
                         "to prefill-role instances, completed prefills "
                         "hand their KV off through the PEER tier to a "
                         "decode-role instance that certified the transfer "
                         "against its live TPOT budgets; TTFT is charged on "
                         "the prefill side, TPOT-plus-transfer on the "
                         "decode side")
    ap.add_argument("--prefill-instances", type=int, default=1,
                    help="prefill-role instance count (--disagg)")
    ap.add_argument("--decode-instances", type=int, default=1,
                    help="decode-role instance count (--disagg)")
    ap.add_argument("--router", choices=["affinity", "round_robin"],
                    default="affinity",
                    help="fleet placement policy (--fleet > 1): 'affinity' "
                         "scores prefix hits + load + link pressure; "
                         "'round_robin' is the byte-traffic baseline")
    ap.add_argument("--trace-out", default=None,
                    help="write the iteration trace as Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing); also "
                         "runs the conservation auditor and exits nonzero "
                         "on any invariant violation")
    ap.add_argument("--metrics-out", default=None,
                    help="write the full structured trace (repro-trace/v1 "
                         "schema: per-iteration records, request events, "
                         "counter footer, audit report) as JSON")
    args = ap.parse_args(argv)
    if args.disk_kv_gb > 0 and args.host_kv_gb <= 0:
        ap.error("--disk-kv-gb requires a host tier to stage through: "
                 "set --host-kv-gb > 0")
    if args.incremental_prefill and args.prefix_dedup:
        ap.error("--incremental-prefill is incompatible with "
                 "--prefix-dedup (shared prompt frames would need COW "
                 "inside the chunk kernel)")
    if args.autotune and args.peer:
        ap.error("--autotune and --peer are mutually exclusive: when a "
                 "link is shared, the per-bus coordinator owns the "
                 "interval")
    if args.fleet > 1 and args.peer:
        ap.error("--fleet subsumes --peer: the fleet coordinates every "
                 "instance on the shared link already")
    if args.fleet > 1 and args.autotune:
        ap.error("--fleet and --autotune are mutually exclusive: the "
                 "fleet-wide link-budget coordinator owns the interval")
    if args.disagg:
        if args.fleet > 1 or args.peer or args.autotune:
            ap.error("--disagg builds its own role-typed fleet: drop "
                     "--fleet/--peer/--autotune")
        if args.host_kv_gb <= 0:
            ap.error("--disagg requires a host KV tier (--host-kv-gb > 0): "
                     "prefill instances park completed prefills on host "
                     "before the peer handoff")
        if args.prefill_instances < 1 or args.decode_instances < 1:
            ap.error("--disagg needs at least one prefill and one decode "
                     "instance")

    cfg = reduce_config(get_config(args.arch))
    hw = PRESETS[args.hw]
    ecfg = EngineConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                        hbm_budget_bytes=args.hbm_gb * 1e9,
                        host_kv_bytes=args.host_kv_gb * 1e9,
                        disk_kv_bytes=args.disk_kv_gb * 1e9,
                        disk_bw_bytes_s=args.disk_bw_gbps * 1e9,
                        disk_backing_path=args.disk_backing_path,
                        page_size=args.page_size,
                        prefix_dedup=args.prefix_dedup,
                        preemption=args.preemption,
                        prefill_chunk_tokens=args.prefill_chunk_tokens,
                        async_data_plane=args.async_data_plane,
                        incremental_prefill=args.incremental_prefill,
                        autotune=args.autotune)
    slos = [0.002 * k for k in range(1, 120)]
    eng = None
    if not args.disagg:
        eng = build_engine("e0", cfg, hw, ecfg, slos)
    peers = []
    if args.peer:
        peers.append(build_engine("e1", cfg, hw, ecfg, slos))

    ttft_slo = args.ttft_slo_ms / 1e3
    tpot_slo = args.tpot_slo_ms / 1e3
    if args.workload == "chat":
        wcfg = WorkloadConfig(
            seed=0, process=args.arrival_process,
            rate_per_s=args.arrival_rate,
            diurnal_period_s=args.diurnal_period_s,
            # think time between a session's rounds paces with the load so
            # multi-round sessions interleave instead of serializing the run
            mean_think_s=4.0 / args.arrival_rate,
            system_prompt_len=max(int(args.shared_prefix_frac
                                      * (args.max_seq // 2)), 8),
            median_turn_len=8, max_prompt_len=args.max_seq // 2,
            mean_output_len=6.0, max_output_len=args.max_seq // 4,
            vocab_size=cfg.vocab_size,
            slo_classes=(
                SLOClass("interactive", ttft_slo, tpot_slo, 0.5),
                SLOClass("standard", 2.5 * ttft_slo, 2.5 * tpot_slo, 0.35),
                SLOClass("batch", 10 * ttft_slo, 10 * tpot_slo, 0.15)))
        reqs = generate_workload(wcfg, args.requests)
    else:
        rng = np.random.default_rng(0)
        stream = request_stream(DataConfig(seed=0, mean_prompt_len=12,
                                           mean_output_len=8), args.requests,
                                ttft_slo_s=ttft_slo, tpot_slo_s=tpot_slo,
                                rate_per_s=args.arrival_rate)
        common = rng.integers(0, cfg.vocab_size,
                              int(args.shared_prefix_frac
                                  * (args.max_seq // 2))).astype(np.int32)

        def _prompt(plen: int) -> np.ndarray:
            rest = rng.integers(0, cfg.vocab_size,
                                max(plen - len(common), 0)).astype(np.int32)
            return np.concatenate([common[:plen], rest])

        reqs = [Request(rid=r.rid,
                        prompt=_prompt(min(r.prompt_len, args.max_seq // 2)),
                        max_new_tokens=min(r.max_new_tokens,
                                           args.max_seq // 4),
                        ttft_slo_s=r.ttft_slo_s, tpot_slo_s=r.tpot_slo_s,
                        arrival_s=r.arrival_s) for r in stream]

    if args.disagg:
        # parked staging + resume are the handoff transport: force the
        # preemption machinery on regardless of the flag
        pcfg = dataclasses.replace(ecfg, role="prefill", preemption=True)
        dcfg = dataclasses.replace(ecfg, role="decode", preemption=True)
        engines = ([build_engine(f"p{i}", cfg, hw, pcfg, slos)
                    for i in range(args.prefill_instances)]
                   + [build_engine(f"d{i}", cfg, hw, dcfg, slos)
                      for i in range(args.decode_instances)])
        fleet = Fleet(engines, policy=args.router, link_bw=hw.host_link_bw)
        out = fleet.run(reqs, submit_all=args.submit_all)
        summary = {k: v for k, v in out.items() if k != "per_request"}
        # per-instance conservation invariants (I1-I12) plus the fleet's
        # handoff conservation cross-check: bytes exported == imported,
        # per link — exit 3 on any violation (the CI smoke gate)
        ok, violations = fleet.audit()
        summary["audit"] = {"ok": ok, "violations": violations[:20]}
        if args.trace_out:
            for e in engines:
                e.trace.write_perfetto(f"{args.trace_out}.{e.name}")
        if args.metrics_out:
            for e in engines:
                e.trace.write_trace(f"{args.metrics_out}.{e.name}",
                                    audit=e.trace.audit())
        print(json.dumps(summary, indent=1))
        if not ok:
            raise SystemExit(3)
        return out

    if args.fleet > 1:
        engines = [eng] + [build_engine(f"e{i}", cfg, hw, ecfg, slos)
                           for i in range(1, args.fleet)]
        fleet = Fleet(engines, policy=args.router,
                      link_bw=hw.host_link_bw)
        out = fleet.run(reqs, submit_all=args.submit_all)
        summary = {k: v for k, v in out.items() if k != "per_request"}
        # the fleet always audits: per-instance conservation invariants
        # (I1-I11) plus the cross-instance migration-byte cross-check
        ok, violations = fleet.audit()
        summary["audit"] = {"ok": ok, "violations": violations[:20]}
        if args.trace_out:
            for e in engines:
                e.trace.write_perfetto(f"{args.trace_out}.{e.name}")
        if args.metrics_out:
            for e in engines:
                e.trace.write_trace(f"{args.metrics_out}.{e.name}",
                                    audit=e.trace.audit())
        print(json.dumps(summary, indent=1))
        if not ok:
            raise SystemExit(3)
        return out

    out = eng.run(reqs, peers=peers or None,
                  link_bw=hw.host_link_bw if peers else None,
                  submit_all=args.submit_all)
    summary = {k: v for k, v in out.items() if k != "per_request"}
    summary["final_interval"] = (None if eng.interval >= 10**9
                                 else eng.interval)
    summary["host_kv_peak_pages"] = eng.host_kv_peak_pages
    summary["disk_kv_peak_pages"] = eng.disk_kv_peak_pages
    summary["kv_tiers"] = (1 + int(eng.kv.host.total_pages > 0)
                           + int(eng.kv.disk.total_pages > 0))
    summary["decode_path"] = "paged"     # single page pool + Pallas kernel
    summary["streamed_pages_peak"] = eng.streamed_pages_peak
    summary["prefix_dedup"] = args.prefix_dedup
    summary["device_pages_peak"] = eng.device_pages_peak
    summary["dedup_pages_reused"] = eng.kv.dedup_pages_reused
    summary["cow_events"] = eng.cow_events
    summary["scheduler"] = {"preemption": args.preemption,
                            "prefill_chunk_tokens": args.prefill_chunk_tokens}
    summary["data_plane"] = {"async": args.async_data_plane,
                             "incremental_prefill": args.incremental_prefill}
    summary["arrival"] = {"process": args.arrival_process,
                          "rate_per_s": args.arrival_rate,
                          "honored": not args.submit_all,
                          "workload": args.workload}
    summary["autotune_enabled"] = args.autotune
    # preemptions / resumes / chunked_prefill_iters / queue_delay_p99_s come
    # from engine.run (scheduler IterationOutcome stats) and are already in
    # the summary dict above
    report = None
    if args.trace_out or args.metrics_out:
        report = eng.trace.audit()
        summary["audit"] = {"ok": report.ok, "checks": report.checks,
                            "violations": report.violations[:20]}
        if args.trace_out:
            eng.trace.write_perfetto(args.trace_out)
        if args.metrics_out:
            eng.trace.write_trace(args.metrics_out, audit=report)
    print(json.dumps(summary, indent=1))
    if report is not None and not report.ok:
        raise SystemExit(3)
    return out


if __name__ == "__main__":
    main()
