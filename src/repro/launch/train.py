"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck

On a cluster this runs under one process per host with the production mesh;
on this container it runs reduced configs on CPU (the same code path:
sharded data pipeline, remat, AdamW, async checkpoints, watchdog, elastic
resume).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.configs.reduced import reduce_config
from repro.data.pipeline import DataConfig, SyntheticTokenStream
from repro.ft import checkpoint as ckpt
from repro.ft.watchdog import StepWatchdog, WatchdogConfig
from repro.launch.mesh import make_mesh_from_spec
from repro.models import spec as S
from repro.models.model import build_model
from repro.sharding.rules import make_rules, sharding_context
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import (TrainConfig, build_train_step,
                                       init_train_state, opt_state_spec)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dp-compress", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = make_mesh_from_spec(args.mesh)
    rules = make_rules(cfg, mesh)
    model = build_model(cfg, tp=mesh.shape.get("model", 1))
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr, warmup_steps=2,
                                             total_steps=args.steps),
                       microbatches=args.microbatches,
                       dp_compress=args.dp_compress)
    step_fn = build_train_step(model, tcfg)

    def wrapped(params, opt_state, batch):
        with sharding_context(mesh, rules):
            return step_fn(params, opt_state, batch)

    jstep = jax.jit(wrapped, donate_argnums=(0, 1))

    start_step = 0
    if args.resume and args.ckpt_dir and (ckpt.latest_step(args.ckpt_dir)
                                          is not None):
        last = ckpt.latest_step(args.ckpt_dir)
        target = {"params": S.abstract(model.spec),
                  "opt": S.abstract(opt_state_spec(model))}
        restored, extra = ckpt.restore_checkpoint(args.ckpt_dir, last, target)
        params, opt_state = restored["params"], restored["opt"]
        start_step = extra.get("step", last)
        print(f"resumed from step {start_step}")
    else:
        params, opt_state = init_train_state(model, jax.random.PRNGKey(0))

    ds = SyntheticTokenStream(cfg, shape, DataConfig(seed=0),
                              mesh if mesh.devices.size > 1 else None)
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    wd = StepWatchdog(WatchdogConfig())
    losses = []
    for s in range(start_step, args.steps):
        batch = ds.batch(s)
        if args.microbatches > 1:
            batch = jax.tree.map(
                lambda x: x.reshape(args.microbatches, -1, *x.shape[1:]),
                batch)
        wd.start()
        params, opt_state, metrics = jstep(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = wd.stop()
        losses.append(loss)
        print(f"step {s:4d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
              f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
              flush=True)
        if saver and (s + 1) % args.ckpt_every == 0:
            saver.save(s + 1, {"params": params, "opt": opt_state},
                       extra={"step": s + 1})
    if saver:
        saver.wait()
    return {"losses": losses, "straggler_events": wd.events}


if __name__ == "__main__":
    main()
