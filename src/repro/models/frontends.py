"""Modality frontend stubs.

Per the assignment, [audio]/[vlm] entries specify the transformer backbone
only; the frontend (w2v-BERT conformer for seamless, SigLIP ViT for
paligemma) is a stub: ``input_specs()`` provides precomputed frame/patch
embeddings with the documented output shape. These helpers centralize those
shapes and generate deterministic stub embeddings for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec


def frontend_positions(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Number of frontend embedding positions for a given shape cell."""
    if cfg.frontend is None:
        return 0
    if cfg.family == "audio":
        # Encoder consumes frames; frontend fills the whole encoder input.
        return encoder_len(cfg, shape)
    # Vision: fixed patch grid (e.g. SigLIP 224px/14 -> 256 patches).
    return cfg.frontend.num_positions


def encoder_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Encoder source length for enc-dec cells."""
    if cfg.encoder_layers == 0:
        return 0
    if shape.step == "decode":
        # Decode cells measure decoder-side TPOT; a moderate, fixed source.
        return min(shape.seq_len, 4096)
    return shape.seq_len


def stub_embeddings(cfg: ModelConfig, batch: int, positions: int,
                    key: jax.Array) -> jax.Array:
    """Deterministic random embeddings standing in for the frontend output."""
    dim = (cfg.frontend.embed_dim or cfg.d_model) if cfg.frontend else cfg.d_model
    return jax.random.normal(key, (batch, positions, dim), jnp.float32).astype(
        jnp.bfloat16) * 0.02
