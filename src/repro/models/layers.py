"""Layer library: norms, RoPE, attention (3 impls), MLP, MoE, Mamba, xLSTM.

All functions are pure; parameters are dicts of arrays described by TensorSpec
trees (see spec.py). Activation sharding is expressed through
``repro.sharding.rules.shard`` logical constraints, so the same code runs on a
single CPU device (constraints no-op) and on the production mesh.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MambaConfig, XLSTMConfig
from repro.models.spec import TensorSpec
from repro.sharding.rules import shard

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_spec(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": TensorSpec((d,), (None,), init="ones"),
                "bias": TensorSpec((d,), (None,), init="zeros")}
    return {"scale": TensorSpec((d,), (None,), init="ones")}


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int32)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., seq, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_spec(cfg: ModelConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    spec: Params = {
        "wq": TensorSpec((d, h, hd), ("fsdp", "heads", "head_dim")),
        "wk": TensorSpec((d, kv, hd), ("fsdp", "kv", "head_dim")),
        "wv": TensorSpec((d, kv, hd), ("fsdp", "kv", "head_dim")),
        "wo": TensorSpec((h, hd, d), ("heads", "head_dim", "fsdp"),
                         fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        spec["bq"] = TensorSpec((h, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = TensorSpec((kv, hd), ("kv", "head_dim"), init="zeros")
        spec["bv"] = TensorSpec((kv, hd), ("kv", "head_dim"), init="zeros")
    return spec


def _expand_kv(x: jax.Array, virtual: int) -> jax.Array:
    """[..., kv, hd] -> [..., virtual, hd] by repetition (vLLM-style)."""
    kv = x.shape[-2]
    if virtual == kv:
        return x
    reps = virtual // kv
    return jnp.repeat(x, reps, axis=-2)


def qkv_project(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
                virtual_kv: int):
    """x: [B, S, D] -> q [B,S,H,hd], k/v [B,S,V,hd] (virtual heads, roped)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, virtual_kv)
    v = _expand_kv(v, virtual_kv)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv", None)
    v = shard(v, "batch", None, "kv", None)
    return q, k, v


def _mask_bias(q_pos, k_pos, window: int, cross: bool) -> jax.Array:
    """Additive mask bias: 0 where visible, -inf where masked.

    q_pos: [..., Sq], k_pos: [..., Sk] (absolute positions; -1 = invalid slot).
    """
    valid = k_pos[..., None, :] >= 0
    if not cross:
        causal = k_pos[..., None, :] <= q_pos[..., None]
        valid = valid & causal
        if window > 0:
            valid = valid & (q_pos[..., None] - k_pos[..., None, :] < window)
    return jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)


def attn_reference(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                   window: int = 0, cross: bool = False) -> jax.Array:
    """Dense softmax attention (oracle / small shapes).

    q: [B,Sq,H,hd], k/v: [B,Sk,V,hd] with V | H.
    """
    b, sq, h, hd = q.shape
    vheads = k.shape[2]
    g = h // vheads
    qf = q.reshape(b, sq, vheads, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqvgk,bsvk->bvgqs", qf, kf) / math.sqrt(hd)
    s = s + _mask_bias(q_pos, k_pos, window, cross)[:, None, None]
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bvgqs,bsvk->bqvgk", pr, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def attn_chunked(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                 window: int = 0, cross: bool = False,
                 chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention, scanning KV in chunks.

    Linear memory in Sk; this is the jnp analogue of the Pallas kernel and the
    impl used at dry-run scale (32k/500k sequences).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    vheads = k.shape[2]
    g = h // vheads
    chunk = min(chunk, sk)
    nc = (sk + chunk - 1) // chunk
    pad = nc * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    qf = (q.reshape(b, sq, vheads, g, hd) / math.sqrt(hd))

    kc = k.reshape(b, nc, chunk, vheads, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, vheads, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    m0 = jnp.full((b, vheads, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, vheads, g, sq), jnp.float32)
    a0 = jnp.zeros((b, vheads, g, sq, hd), jnp.float32)

    def body(carry, ck):
        m, l, acc = carry
        k_i, v_i, kp_i = ck
        s = jnp.einsum("bqvgk,bsvk->bvgqs", qf, k_i,
                       preferred_element_type=jnp.float32)
        s = s + _mask_bias(q_pos, kp_i, window, cross)[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        pexp = jnp.exp(s - m_safe[..., None])
        scale = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        l = l * scale + jnp.sum(pexp, axis=-1)
        acc = acc * scale[..., None] + jnp.einsum(
            "bvgqs,bsvk->bvgqk", pexp, v_i.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attn_out(cfg: ModelConfig, p: Params, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard(y, "batch", None, None)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_spec(cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    spec = {
        "w1": TensorSpec((d, f), ("fsdp", "mlp")),
        "w2": TensorSpec((f, d), ("mlp", "fsdp")),
    }
    if cfg.gated_mlp:
        spec["w3"] = TensorSpec((d, f), ("fsdp", "mlp"))
    return spec


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    act = _ACTS[cfg.act]
    h = act(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    if cfg.gated_mlp:
        h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    h = shard(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    return shard(y, "batch", None, None)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, per-row capacity dispatch; EP when experts divide
# the model axis, expert-TP otherwise — the rules engine decides)
# ---------------------------------------------------------------------------


def moe_spec(cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    spec = {
        "router": TensorSpec((d, e), ("fsdp", None)),
        "w1": TensorSpec((e, d, f), ("experts", "fsdp", "expert_mlp"), fan_in_axes=(1,)),
        "w2": TensorSpec((e, f, d), ("experts", "expert_mlp", "fsdp"), fan_in_axes=(1,)),
    }
    if cfg.gated_mlp:
        spec["w3"] = TensorSpec((e, d, f), ("experts", "fsdp", "expert_mlp"),
                                fan_in_axes=(1,))
    return spec


def moe_capacity(cfg: ModelConfig, seq: int) -> int:
    m = cfg.moe
    assert m is not None
    return max(1, int(math.ceil(seq * m.top_k / m.num_experts * m.capacity_factor)))


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array):
    """x: [B, S, D]. Dispatch is per batch row so the sort/scatter stays local
    to the data shard (no cross-device gather of activations).

    Returns (y, aux_loss).
    """
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    cap = moe_capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [B,S,E] f32
    top_p, top_i = jax.lax.top_k(probs, k)   # [B,S,k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style), per batch row then averaged.
    me = jnp.mean(probs, axis=1)                                   # [B,E]
    ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e

    def dispatch_row(xr, ir, pr):
        # xr: [S,D], ir: [S,k] expert ids, pr: [S,k] weights
        flat_e = ir.reshape(-1)                      # [S*k]
        order = jnp.argsort(flat_e)                  # stable sort by expert
        sorted_e = flat_e[order]
        tok = order // k
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
        pos_in_e = jnp.arange(s * k) - seg_start[sorted_e]
        keep = pos_in_e < cap
        dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
        xe = jnp.zeros((e * cap + 1, d), xr.dtype).at[dest].set(xr[tok])
        return xe[:-1].reshape(e, cap, d), (order, tok, dest, keep)

    xe, (order, tok, dest, keep) = jax.vmap(dispatch_row)(x, top_i, top_p)
    # "moe_batch" == "batch" for train/prefill; replicated at decode so the
    # 2D-sharded expert weights stay put and only tokens move (§Perf A).
    xe = shard(xe, "moe_batch", "experts", None, None)

    act = _ACTS[cfg.act]
    h = act(jnp.einsum("becd,edf->becf", xe, p["w1"]))
    if cfg.gated_mlp:
        h = h * jnp.einsum("becd,edf->becf", xe, p["w3"])
    h = shard(h, "moe_batch", "experts", None, "expert_mlp")
    ye = jnp.einsum("becf,efd->becd", h, p["w2"])
    ye = shard(ye, "moe_batch", "experts", None, None)

    def combine_row(ye_r, xr, order_r, tok_r, dest_r, keep_r, pr):
        yflat = jnp.concatenate(
            [ye_r.reshape(e * cap, d), jnp.zeros((1, d), ye_r.dtype)], axis=0)
        w = pr.reshape(-1)[order_r] * keep_r.astype(pr.dtype)
        contrib = yflat[dest_r] * w[:, None].astype(ye_r.dtype)
        return jnp.zeros((s, d), ye_r.dtype).at[tok_r].add(contrib)

    y = jax.vmap(combine_row)(ye, x, order, tok, dest, keep, top_p)
    return shard(y, "batch", None, None), aux


# ---------------------------------------------------------------------------
# Mamba (selective SSM, Mamba-1 style with chunked parallel scan)
# ---------------------------------------------------------------------------


def _mamba_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    mc = cfg.mamba or MambaConfig()
    di = mc.expand * cfg.d_model
    dtr = max(1, cfg.d_model // 16)
    return di, mc.d_state, mc.d_conv, dtr


def mamba_spec(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di, ds, dc, dtr = _mamba_dims(cfg)
    return {
        "w_in": TensorSpec((d, 2 * di), ("fsdp", "mlp")),
        "conv_w": TensorSpec((dc, di), ("conv", "mlp")),
        "conv_b": TensorSpec((di,), ("mlp",), init="zeros"),
        "w_x": TensorSpec((di, dtr + 2 * ds), ("mlp", None)),
        "w_dt": TensorSpec((dtr, di), (None, "mlp")),
        "dt_bias": TensorSpec((di,), ("mlp",), init="zeros"),
        "a_log": TensorSpec((di, ds), ("mlp", None), init="zeros"),
        "d_skip": TensorSpec((di,), ("mlp",), init="ones"),
        "w_out": TensorSpec((di, d), ("mlp", "fsdp")),
    }


def _mamba_gates(cfg: ModelConfig, p: Params, xz: jax.Array, conv_state=None):
    """Shared projection math. xz: [B,S,D] input (pre in-proj)."""
    di, ds, dc, dtr = _mamba_dims(cfg)
    proj = jnp.einsum("bsd,de->bse", xz, p["w_in"])
    x_in, z = jnp.split(proj, 2, axis=-1)  # [B,S,di]
    return x_in, z


def _causal_conv(x_in: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None):
    """Depthwise causal conv. x_in [B,S,di], w [dc,di]. state [B,dc-1,di]."""
    dc = w.shape[0]
    if state is None:
        pad = jnp.zeros((x_in.shape[0], dc - 1, x_in.shape[2]), x_in.dtype)
    else:
        pad = state.astype(x_in.dtype)
    xp = jnp.concatenate([pad, x_in], axis=1)  # [B, S+dc-1, di]
    out = sum(xp[:, i:i + x_in.shape[1], :] * w[i] for i in range(dc))
    new_state = xp[:, -(dc - 1):, :] if dc > 1 else pad[:, :0]
    return out + b, new_state


def apply_mamba_seq(cfg: ModelConfig, p: Params, x: jax.Array,
                    chunk: int = 32):
    """Full-sequence selective scan (train/prefill). Returns (y, final_state).

    Chunked: within a chunk, an associative scan materializes h per position
    ([B,Q,di,ds] — the HBM-traffic hot spot the Pallas kernel removes);
    across chunks a lax.scan carries h.
    """
    b, s, d = x.shape
    di, ds, dc, dtr = _mamba_dims(cfg)
    x_in, z = _mamba_gates(cfg, p, x)
    x_conv, conv_state = _causal_conv(x_in, p["conv_w"], p["conv_b"], None)
    x_conv = jax.nn.silu(x_conv)
    xdb = jnp.einsum("bsi,ie->bse", x_conv, p["w_x"])
    dt_raw, bmat, cmat = jnp.split(xdb, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_raw, p["w_dt"])
                         + p["dt_bias"]).astype(jnp.float32)      # [B,S,di]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                   # [di,ds]
    u = (dt * x_conv.astype(jnp.float32))                          # [B,S,di]

    chunk = min(chunk, s)
    nc = (s + chunk - 1) // chunk
    pad = nc * chunk - s
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))

    dt_c = dt.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)
    u_c = u.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)
    b_c = bmat.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3).astype(jnp.float32)
    c_c = cmat.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3).astype(jnp.float32)

    h0 = jnp.zeros((b, di, ds), jnp.float32)

    def chunk_body(h, ck):
        dt_i, u_i, b_i, c_i = ck                      # [B,Q,di] / [B,Q,ds]
        decay = jnp.exp(dt_i[..., None] * a)          # [B,Q,di,ds]
        inp = (u_i[..., None] * b_i[:, :, None, :])   # [B,Q,di,ds]

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, b1 * a2 + b2

        dec_cum, h_all = jax.lax.associative_scan(combine, (decay, inp), axis=1)
        h_all = h_all + dec_cum * h[:, None]          # include carry-in
        y_i = jnp.einsum("bqis,bqs->bqi", h_all, c_i)
        return h_all[:, -1], y_i

    hN, y = jax.lax.scan(chunk_body, h0, (dt_c, u_c, b_c, c_c))
    y = y.transpose(1, 0, 2, 3).reshape(b, nc * chunk, di)[:, :s]
    y = y + p["d_skip"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return shard(out, "batch", None, None), {"conv": conv_state, "ssm": hN}


def apply_mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params):
    """Single-token step. x: [B,1,D]; cache {conv [B,dc-1,di], ssm [B,di,ds]}."""
    b, _, d = x.shape
    di, ds, dc, dtr = _mamba_dims(cfg)
    x_in, z = _mamba_gates(cfg, p, x)
    x_conv, conv_state = _causal_conv(x_in, p["conv_w"], p["conv_b"],
                                      cache["conv"])
    x_conv = jax.nn.silu(x_conv)
    xdb = jnp.einsum("bsi,ie->bse", x_conv, p["w_x"])
    dt_raw, bmat, cmat = jnp.split(xdb, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_raw, p["w_dt"])
                         + p["dt_bias"]).astype(jnp.float32)[:, 0]   # [B,di]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * a)                              # [B,di,ds]
    u = dt * x_conv[:, 0].astype(jnp.float32)                       # [B,di]
    h = cache["ssm"] * decay + u[..., None] * bmat[:, 0, None, :].astype(jnp.float32)
    y = jnp.einsum("bis,bs->bi", h, cmat[:, 0].astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * x_conv[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["w_out"])[:, None]
    return shard(out, "batch", None, None), {"conv": conv_state, "ssm": h}


def mamba_cache_spec(cfg: ModelConfig, batch: int) -> Params:
    di, ds, dc, _ = _mamba_dims(cfg)
    return {
        "conv": TensorSpec((batch, dc - 1, di), ("batch", None, "mlp"),
                           dtype=jnp.bfloat16, init="zeros"),
        "ssm": TensorSpec((batch, di, ds), ("batch", "mlp", None),
                          dtype=jnp.float32, init="zeros"),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) + sLSTM (scalar memory), sequential scans.
# 125M-scale arch; sequential recurrence compiles compactly (lax.scan).
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: ModelConfig) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    xc = cfg.xlstm or XLSTMConfig()
    di = int(xc.proj_factor_mlstm * d)
    dh = di // h
    return {
        "w_up": TensorSpec((d, 2 * di), ("fsdp", "mlp")),
        "wq": TensorSpec((di, h, dh), ("mlp", "heads", None)),
        "wk": TensorSpec((di, h, dh), ("mlp", "heads", None)),
        "wv": TensorSpec((di, h, dh), ("mlp", "heads", None)),
        "w_gates": TensorSpec((di, 2 * h), ("mlp", None)),  # i, f pre-acts
        "w_down": TensorSpec((di, d), ("mlp", "fsdp")),
    }


def _mlstm_step(q, k, v, i_pre, f_pre, state):
    """One mLSTM step (stabilized exponential gating).

    q/k/v: [B,H,dh]; i_pre/f_pre: [B,H]; state: dict(C [B,H,dh,dh],
    n [B,H,dh], m [B,H]).
    """
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fgate = jnp.exp(logf + state["m"] - m_new)
    igate = jnp.exp(i_pre - m_new)
    c = state["C"] * fgate[..., None, None] + \
        igate[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = state["n"] * fgate[..., None] + igate[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    return num / den[..., None], {"C": c, "n": n, "m": m_new}


def apply_mlstm_seq(cfg: ModelConfig, p: Params, x: jax.Array):
    b, s, d = x.shape
    h = cfg.num_heads
    xc = cfg.xlstm or XLSTMConfig()
    di = int(xc.proj_factor_mlstm * d)
    dh = di // h
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsi,ihk->bshk", xi, p["wq"]).astype(jnp.float32)
    k = (jnp.einsum("bsi,ihk->bshk", xi, p["wk"]) / math.sqrt(dh)).astype(jnp.float32)
    v = jnp.einsum("bsi,ihk->bshk", xi, p["wv"]).astype(jnp.float32)
    gates = jnp.einsum("bsi,ig->bsg", xi, p["w_gates"]).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)  # [B,S,H]

    state = {
        "C": jnp.zeros((b, h, dh, dh), jnp.float32),
        "n": jnp.zeros((b, h, dh), jnp.float32),
        "m": jnp.zeros((b, h), jnp.float32),
    }

    def body(st, xs):
        qt, kt, vt, it, ft = xs
        yt, st = _mlstm_step(qt, kt, vt, it, ft, st)
        return st, yt

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    state, ys = jax.lax.scan(body, state, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_down"]), state


def apply_mlstm_decode(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params):
    b, _, d = x.shape
    h = cfg.num_heads
    xc = cfg.xlstm or XLSTMConfig()
    di = int(xc.proj_factor_mlstm * d)
    dh = di // h
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bi,ihk->bhk", xi[:, 0], p["wq"]).astype(jnp.float32)
    k = (jnp.einsum("bi,ihk->bhk", xi[:, 0], p["wk"]) / math.sqrt(dh)).astype(jnp.float32)
    v = jnp.einsum("bi,ihk->bhk", xi[:, 0], p["wv"]).astype(jnp.float32)
    gates = jnp.einsum("bi,ig->bg", xi[:, 0], p["w_gates"]).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    y, state = _mlstm_step(q, k, v, i_pre, f_pre, cache)
    y = y.reshape(b, 1, di).astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_down"]), state


def mlstm_cache_spec(cfg: ModelConfig, batch: int) -> Params:
    h = cfg.num_heads
    xc = cfg.xlstm or XLSTMConfig()
    di = int(xc.proj_factor_mlstm * cfg.d_model)
    dh = di // h
    f32 = jnp.float32
    return {
        "C": TensorSpec((batch, h, dh, dh), ("batch", "heads", None, None),
                        dtype=f32, init="zeros"),
        "n": TensorSpec((batch, h, dh), ("batch", "heads", None),
                        dtype=f32, init="zeros"),
        "m": TensorSpec((batch, h), ("batch", "heads"), dtype=f32, init="zeros"),
    }


def slstm_spec(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    return {
        "w_x": TensorSpec((d, 4 * d), ("fsdp", "mlp")),   # i,f,z,o from input
        "w_h": TensorSpec((d, 4 * d), (None, "mlp")),     # recurrent
        "b": TensorSpec((4 * d,), ("mlp",), init="zeros"),
    }


def _slstm_step(pre, state):
    """pre: [B,4D] (input contribution); state: c,n,h,m each [B,D]."""
    d = state["c"].shape[-1]
    it, ft, zt, ot = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state["m"], it)
    ig = jnp.exp(it - m_new)
    fg = jnp.exp(logf + state["m"] - m_new)
    c = fg * state["c"] + ig * jnp.tanh(zt)
    n = fg * state["n"] + ig
    hh = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return hh, {"c": c, "n": n, "h": hh, "m": m_new}


def apply_slstm_seq(cfg: ModelConfig, p: Params, x: jax.Array):
    b, s, d = x.shape
    xpre = jnp.einsum("bsd,de->bse", x, p["w_x"]) + p["b"]

    state = {k: jnp.zeros((b, d), jnp.float32) for k in ("c", "n", "h", "m")}

    def body(st, xp):
        pre = xp.astype(jnp.float32) + jnp.einsum(
            "bd,de->be", st["h"], p["w_h"].astype(jnp.float32))
        hh, st = _slstm_step(pre, st)
        return st, hh

    state, ys = jax.lax.scan(body, state, xpre.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2).astype(x.dtype), state


def apply_slstm_decode(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params):
    xpre = jnp.einsum("bd,de->be", x[:, 0], p["w_x"]) + p["b"]
    pre = xpre.astype(jnp.float32) + jnp.einsum(
        "bd,de->be", cache["h"], p["w_h"].astype(jnp.float32))
    hh, state = _slstm_step(pre, cache)
    return hh[:, None].astype(x.dtype), state


def slstm_cache_spec(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    return {k: TensorSpec((batch, d), ("batch", None), dtype=jnp.float32,
                          init="zeros") for k in ("c", "n", "h", "m")}
