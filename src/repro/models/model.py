"""Model facade: build_model(cfg) -> Model with init / loss / prefill / decode.

The decode path (``decode_step``) is what Select-N wraps: its parameter tree
is re-grouped by the offload plan (core/memory_manager.py) while the math here
stays unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import spec as S
from repro.models import transformer as T
from repro.sharding.rules import virtual_kv_heads

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    tp: int  # model-axis size the activation layout targets

    # ---- specs ------------------------------------------------------------
    @functools.cached_property
    def spec(self) -> Params:
        return T.model_spec(self.cfg)

    @property
    def virtual_kv(self) -> int:
        return virtual_kv_heads(self.cfg, self.tp)

    def cache_spec(self, batch: int, cache_len: int, enc_len: int = 0):
        return T.cache_spec(self.cfg, batch, cache_len, self.virtual_kv,
                            enc_len)

    # ---- materialization ----------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        return S.initialize(self.spec, key)

    def init_cache(self, key: jax.Array, batch: int, cache_len: int,
                   enc_len: int = 0) -> Any:
        return S.initialize(self.cache_spec(batch, cache_len, enc_len), key)

    # ---- encoder ------------------------------------------------------------
    def encode(self, params: Params, enc_embeds: jax.Array,
               attn_impl: str = "chunked"):
        """Encoder forward (seamless). enc_embeds: [B, S_enc, D]."""
        cfg = self.cfg
        b, s, _ = enc_embeds.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        ctx = T.SeqCtx(positions=pos, virtual_kv=self.virtual_kv,
                       attn_impl=attn_impl)
        enc = params["encoder"]
        x, _, _ = T.apply_stack_seq(
            cfg, enc["blocks"], enc_embeds.astype(jnp.bfloat16), ctx,
            pattern=(T.BlockSpec(mixer="attention", mlp="dense"),))
        x = L.apply_norm(cfg, enc["final_norm"], x)
        return x, pos

    # ---- full-sequence forward ----------------------------------------------
    def forward(self, params: Params, inputs: dict, *, want_cache: bool = False,
                cache_len: int = 0, attn_impl: str = "chunked",
                remat: bool = False):
        """Returns (hidden [B,S,D], caches_or_None, aux, enc_pos_or_None).

        inputs: {"tokens": [B,S_tok]} (+"frontend_embeds" [B,S_f,D] for vlm,
        +"enc_embeds" [B,S_enc,D] for enc-dec audio).
        """
        cfg = self.cfg
        enc_out = enc_pos = None
        if cfg.encoder_layers > 0:
            enc_out, enc_pos = self.encode(params, inputs["enc_embeds"],
                                           attn_impl)

        x = T.embed_tokens(cfg, params, inputs["tokens"])
        if cfg.frontend is not None and cfg.family != "audio":
            fe = inputs["frontend_embeds"].astype(x.dtype)
            x = jnp.concatenate([fe, x], axis=1)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        ctx = T.SeqCtx(positions=pos, want_cache=want_cache,
                       cache_len=cache_len or s, virtual_kv=self.virtual_kv,
                       enc_out=enc_out, enc_pos=enc_pos, attn_impl=attn_impl)
        x, caches, aux = T.apply_stack_seq(cfg, params["blocks"], x, ctx,
                                           remat=remat)
        x = L.apply_norm(cfg, params["final_norm"], x)
        return x, caches, aux, enc_pos

    # ---- losses ---------------------------------------------------------------
    def loss_fn(self, params: Params, batch: dict, *, remat: bool = True,
                attn_impl: str = "chunked"):
        """batch: {"tokens" [B,S], "labels" [B,S], (+frontend/enc inputs)}."""
        cfg = self.cfg
        hidden, _, aux, _ = self.forward(params, batch, attn_impl=attn_impl,
                                         remat=remat)
        n_front = 0
        if cfg.frontend is not None and cfg.family != "audio":
            n_front = batch["frontend_embeds"].shape[1]
            hidden = hidden[:, n_front:]
        # NOTE: xent_loss_chunked exists as an alternative for big-vocab
        # archs but is NOT wired in: measured on the compiled artifact it
        # moved no HBM traffic (post-B2 the logits are ~2% of the memory
        # term; attention scores dominate) and its per-chunk head re-reads
        # added collective traffic. Recorded as refuted in §Perf B4.
        logits = T.lm_logits(cfg, params, hidden)
        loss = T.xent_loss(cfg, logits, batch["labels"])
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    # ---- serving steps ---------------------------------------------------------
    def prefill(self, params: Params, inputs: dict, cache_len: int,
                attn_impl: str = "chunked", last_pos=None):
        """Returns (last-token logits [B,V], caches, enc_pos).

        ``last_pos`` selects the logits position for shape-bucketed prefills
        whose token rows carry causally-inert suffix padding (default: the
        final row, i.e. unpadded inputs)."""
        cfg = self.cfg
        hidden, caches, _, enc_pos = self.forward(
            params, inputs, want_cache=True, cache_len=cache_len,
            attn_impl=attn_impl)
        h = hidden[:, -1:] if last_pos is None else \
            jax.lax.dynamic_slice_in_dim(hidden, last_pos, 1, axis=1)
        logits = T.lm_logits(cfg, params, h)[:, 0]
        return logits, caches, enc_pos

    def decode_step(self, params: Params, tokens: jax.Array, pos: jax.Array,
                    caches: Any, enc_pos: jax.Array | None = None):
        """One token for every row. tokens/pos: [B]. Returns (logits, caches)."""
        cfg = self.cfg
        x = T.embed_tokens(cfg, params, tokens[:, None])
        x, new_caches = T.apply_stack_decode(
            cfg, params["blocks"], x, pos, caches, self.virtual_kv, enc_pos)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = T.lm_logits(cfg, params, x)[:, 0]
        return logits, new_caches


def build_model(cfg: ModelConfig, tp: int = 1) -> Model:
    return Model(cfg=cfg, tp=tp)
