"""Parameter/cache spec trees.

A model is *described* first (nested dict of TensorSpec) and only then
materialized. The same spec tree drives: real initialization (smoke tests),
ShapeDtypeStruct stand-ins (dry-run), and NamedShardings (logical axes →
mesh axes via sharding rules, with optional pinned_host memory kinds for
offloaded layer stacks).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import Rules, named_sharding
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones
    fan_in_axes: tuple[int, ...] = (0,)  # axes treated as fan-in for scaling

    def stacked(self, n: int) -> "TensorSpec":
        return dataclasses.replace(
            self, shape=(n, *self.shape), logical=("stack", *self.logical),
            fan_in_axes=tuple(a + 1 for a in self.fan_in_axes),
        )


SpecTree = Any  # nested dict of TensorSpec
ArrayTree = Any


def tree_map_spec(fn: Callable[[TensorSpec], Any], tree: SpecTree) -> Any:
    return jax.tree.map(fn, tree, is_leaf=lambda x: isinstance(x, TensorSpec))


def abstract(tree: SpecTree) -> ArrayTree:
    return tree_map_spec(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def shardings(
    tree: SpecTree, mesh: Mesh, rules: Rules,
    memory_kind_fn: Callable[[tuple], str | None] | None = None,
) -> Any:
    """NamedSharding tree. memory_kind_fn(path)-> kind lets the offload plan
    mark specific subtrees pinned_host."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, TensorSpec))
    out = []
    for path, spec in flat:
        kind = memory_kind_fn(path) if memory_kind_fn else None
        out.append(named_sharding(mesh, rules, spec.shape, spec.logical, kind))
    return jax.tree.unflatten(treedef, out)


def abstract_with_sharding(tree: SpecTree, mesh: Mesh, rules: Rules,
                           memory_kind_fn=None) -> ArrayTree:
    shd = shardings(tree, mesh, rules, memory_kind_fn)
    ab = abstract(tree)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), ab, shd)


def initialize(tree: SpecTree, key: jax.Array) -> ArrayTree:
    flat, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, TensorSpec))
    keys = jax.random.split(key, len(flat))
    out = []
    for spec, k in zip(flat, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            fan_in = max(int(np.prod([spec.shape[a] for a in spec.fan_in_axes])), 1)
            scale = 1.0 / np.sqrt(fan_in)
            out.append(
                (jax.random.normal(k, spec.shape, jnp.float32) * scale
                 ).astype(spec.dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(tree: SpecTree) -> int:
    flat = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, TensorSpec))
    return sum(int(np.prod(s.shape)) for s in flat)


def tree_bytes(tree: SpecTree) -> int:
    flat = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, TensorSpec))
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in flat)
