"""Transformer stack assembly.

The layer stack is organized by the config's repeating *pattern* of P block
kinds (P=1 for homogeneous models, 8 for jamba, 2 for xlstm). Parameters for
pattern position j are stacked over the R = num_layers / P repetitions, and
the forward pass is a ``lax.scan`` over R with the P positions unrolled inside
— the same scan unit the Select-N memory manager later re-groups into
offloading intervals.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import layers as L
from repro.models.spec import TensorSpec, tree_map_spec
from repro.sharding.rules import shard

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, blk: BlockSpec, cross: bool = False) -> Params:
    spec: Params = {"norm1": L.norm_spec(cfg)}
    if blk.mixer == "attention":
        spec["attn"] = L.attn_spec(cfg)
    elif blk.mixer == "mamba":
        spec["attn"] = L.mamba_spec(cfg)
    elif blk.mixer == "mlstm":
        spec["attn"] = L.mlstm_spec(cfg)
    elif blk.mixer == "slstm":
        spec["attn"] = L.slstm_spec(cfg)
    if cross:
        spec["norm_cross"] = L.norm_spec(cfg)
        spec["cross"] = L.attn_spec(cfg)
    if cfg.d_ff > 0:
        spec["norm2"] = L.norm_spec(cfg)
        spec["mlp"] = L.moe_spec(cfg) if blk.mlp == "moe" else L.mlp_spec(cfg)
    return spec


def pattern_info(cfg: ModelConfig) -> tuple[int, int]:
    """(P, R): pattern length and repetitions. num_layers must be P*R."""
    p = len(cfg.pattern)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return p, cfg.num_layers // p


def decoder_stack_spec(cfg: ModelConfig, cross: bool = False) -> list[Params]:
    p, r = pattern_info(cfg)
    out = []
    for j in range(p):
        bs = block_spec(cfg, cfg.pattern[j], cross=cross)
        out.append(tree_map_spec(lambda s: s.stacked(r), bs))
    return out


def encoder_stack_spec(cfg: ModelConfig) -> list[Params]:
    bs = block_spec(cfg, BlockSpec(mixer="attention", mlp="dense"))
    return [tree_map_spec(lambda s: s.stacked(cfg.encoder_layers), bs)]


def model_spec(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    vp = cfg.padded_vocab()
    spec: Params = {
        "embed": TensorSpec((vp, d), ("vocab", "fsdp"), fan_in_axes=(1,)),
        "blocks": decoder_stack_spec(cfg, cross=cfg.encoder_layers > 0),
        "final_norm": L.norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = TensorSpec((d, vp), ("fsdp", "vocab"))
    if cfg.encoder_layers > 0:
        spec["encoder"] = {
            "blocks": encoder_stack_spec(cfg),
            "final_norm": L.norm_spec(cfg),
        }
    return spec


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def attn_cache_spec(cfg: ModelConfig, batch: int, cache_len: int,
                    virtual_kv: int) -> Params:
    hd = cfg.resolved_head_dim
    return {
        "k": TensorSpec((batch, cache_len, virtual_kv, hd),
                        ("batch", "cache_seq", "kv", None),
                        dtype=jnp.bfloat16, init="zeros"),
        "v": TensorSpec((batch, cache_len, virtual_kv, hd),
                        ("batch", "cache_seq", "kv", None),
                        dtype=jnp.bfloat16, init="zeros"),
        "pos": TensorSpec((batch, cache_len), ("batch", "cache_seq"),
                          dtype=jnp.int32, init="zeros"),
    }


def mixer_cache_spec(cfg: ModelConfig, blk: BlockSpec, batch: int,
                     cache_len: int, virtual_kv: int) -> Params:
    if blk.mixer == "attention":
        clen = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        return attn_cache_spec(cfg, batch, clen, virtual_kv)
    if blk.mixer == "mamba":
        return L.mamba_cache_spec(cfg, batch)
    if blk.mixer == "mlstm":
        return L.mlstm_cache_spec(cfg, batch)
    if blk.mixer == "slstm":
        return L.slstm_cache_spec(cfg, batch)
    raise ValueError(blk.mixer)


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, virtual_kv: int,
               enc_len: int = 0) -> list[Params]:
    """Per pattern position, stacked over R. Cross caches included for encdec."""
    p, r = pattern_info(cfg)
    out = []
    for j in range(p):
        cs: Params = {"self": mixer_cache_spec(
            cfg, cfg.pattern[j], batch, cache_len, virtual_kv)}
        if cfg.encoder_layers > 0 and enc_len > 0:
            cs["cross"] = attn_cache_spec(cfg, batch, enc_len, virtual_kv)
            del cs["cross"]["pos"]  # cross positions are static iota
        out.append(tree_map_spec(lambda s: s.stacked(r), cs))
    return out


# ---------------------------------------------------------------------------
# Cache fill helpers
# ---------------------------------------------------------------------------


def fill_cache(full: jax.Array, positions: jax.Array, cache_len: int):
    """Store the last cache_len entries of [B,S,...] at slots p % cache_len.

    Returns (cache, pos_array [B, cache_len]).
    """
    b, s = full.shape[0], full.shape[1]
    if s <= cache_len:
        pad = [(0, 0)] * full.ndim
        pad[1] = (0, cache_len - s)
        cache = jnp.pad(full, pad)
        pos = jnp.pad(positions, ((0, 0), (0, cache_len - s)),
                      constant_values=-1)
        return cache, pos
    tail = full[:, s - cache_len:]
    tpos = positions[:, s - cache_len:]
    shift = s % cache_len
    return (jnp.roll(tail, shift, axis=1), jnp.roll(tpos, shift, axis=1))


def cache_write_decode(cache_k, cache_v, cache_pos, k1, v1, pos):
    """Write one token at slot pos % cache_len (per batch row). pos: [B]."""
    clen = cache_k.shape[1]
    slot = pos % clen

    def wr(c, x1, s):
        return jax.lax.dynamic_update_slice(c, x1, (s,) + (0,) * (c.ndim - 1))

    ck = jax.vmap(wr)(cache_k, k1, slot)
    cv = jax.vmap(wr)(cache_v, v1, slot)
    cp = jax.vmap(lambda c, p, s: jax.lax.dynamic_update_slice(c, p[None], (s,))
                  )(cache_pos, pos, slot)
    return ck, cv, cp


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SeqCtx:
    """Context for a full-sequence pass (train/prefill)."""
    positions: jax.Array            # [B, S]
    want_cache: bool = False
    cache_len: int = 0
    virtual_kv: int = 0
    enc_out: jax.Array | None = None
    enc_pos: jax.Array | None = None
    attn_impl: str = "chunked"      # chunked | reference


def _self_attn_seq(cfg, p, x, ctx: SeqCtx):
    q, k, v = L.qkv_project(cfg, p, x, ctx.positions, ctx.virtual_kv)
    impl = L.attn_chunked if ctx.attn_impl == "chunked" else L.attn_reference
    o = impl(cfg, q, k, v, ctx.positions, ctx.positions,
             window=cfg.sliding_window)
    y = L.attn_out(cfg, p, o)
    cache = None
    if ctx.want_cache:
        clen = (min(ctx.cache_len, cfg.sliding_window)
                if cfg.sliding_window else ctx.cache_len)
        ck, cpos = fill_cache(k, ctx.positions, clen)
        cv, _ = fill_cache(v, ctx.positions, clen)
        cache = {"k": ck, "v": cv, "pos": cpos}
    return y, cache


def _cross_attn_seq(cfg, p, x, ctx: SeqCtx):
    """Cross attention for enc-dec; enc_out already normed."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    k = jnp.einsum("bsd,dhk->bshk", ctx.enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", ctx.enc_out, p["wv"])
    k = L._expand_kv(k, ctx.virtual_kv)
    v = L._expand_kv(v, ctx.virtual_kv)
    o = L.attn_chunked(cfg, q, k, v, ctx.positions, ctx.enc_pos, cross=True)
    y = L.attn_out(cfg, p, o)
    cache = {"k": k, "v": v}
    return y, cache


def apply_block_seq(cfg: ModelConfig, blk: BlockSpec, p: Params, x: jax.Array,
                    ctx: SeqCtx):
    """Returns (x, cache_dict_or_None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg, p["norm1"], x)
    cache: Params = {}
    if blk.mixer == "attention":
        y, self_cache = _self_attn_seq(cfg, p["attn"], h, ctx)
        state = None
    elif blk.mixer == "mamba":
        y, state = L.apply_mamba_seq(cfg, p["attn"], h)
        self_cache = None
    elif blk.mixer == "mlstm":
        y, state = L.apply_mlstm_seq(cfg, p["attn"], h)
        self_cache = None
    else:  # slstm
        y, state = L.apply_slstm_seq(cfg, p["attn"], h)
        self_cache = None
    x = x + y
    if ctx.want_cache:
        cache["self"] = self_cache if self_cache is not None else state

    if "cross" in p:
        h = L.apply_norm(cfg, p["norm_cross"], x)
        y, xcache = _cross_attn_seq(cfg, p["cross"], h, ctx)
        x = x + y
        if ctx.want_cache:
            cache["cross"] = xcache

    if cfg.d_ff > 0:
        h = L.apply_norm(cfg, p["norm2"], x)
        if blk.mlp == "moe":
            y, a = L.apply_moe(cfg, p["mlp"], h)
            aux = aux + a
        else:
            y = L.apply_mlp(cfg, p["mlp"], h)
        x = x + y
    return x, (cache if ctx.want_cache else None), aux


def _self_attn_decode(cfg, p, x, pos, cache, virtual_kv):
    q, k1, v1 = L.qkv_project(cfg, p, x, pos[:, None], virtual_kv)
    ck, cv, cpos = cache_write_decode(
        cache["k"], cache["v"], cache["pos"], k1, v1, pos)
    o = L.attn_reference(cfg, q, ck, cv, pos[:, None], cpos,
                         window=cfg.sliding_window)
    y = L.attn_out(cfg, p, o)
    return y, {"k": ck, "v": cv, "pos": cpos}


def _cross_attn_decode(cfg, p, x, pos, cache, enc_pos):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    o = L.attn_reference(cfg, q, cache["k"], cache["v"], pos[:, None],
                         enc_pos, cross=True)
    return L.attn_out(cfg, p, o), cache


def apply_block_decode(cfg: ModelConfig, blk: BlockSpec, p: Params,
                       x: jax.Array, pos: jax.Array, cache: Params,
                       virtual_kv: int, enc_pos: jax.Array | None = None):
    """x: [B,1,D]; pos: [B]. Returns (x, new_cache)."""
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache: Params = {}
    if blk.mixer == "attention":
        y, new_cache["self"] = _self_attn_decode(
            cfg, p["attn"], h, pos, cache["self"], virtual_kv)
    elif blk.mixer == "mamba":
        y, new_cache["self"] = L.apply_mamba_decode(cfg, p["attn"], h,
                                                    cache["self"])
    elif blk.mixer == "mlstm":
        y, new_cache["self"] = L.apply_mlstm_decode(cfg, p["attn"], h,
                                                    cache["self"])
    else:
        y, new_cache["self"] = L.apply_slstm_decode(cfg, p["attn"], h,
                                                    cache["self"])
    x = x + y

    if "cross" in p:
        h = L.apply_norm(cfg, p["norm_cross"], x)
        y, new_cache["cross"] = _cross_attn_decode(
            cfg, p["cross"], h, pos, cache["cross"], enc_pos)
        x = x + y

    if cfg.d_ff > 0:
        h = L.apply_norm(cfg, p["norm2"], x)
        if blk.mlp == "moe":
            y, _ = L.apply_moe(cfg, p["mlp"], h)
        else:
            y = L.apply_mlp(cfg, p["mlp"], h)
        x = x + y
    return x, new_cache


def apply_block_decode_paged(cfg: ModelConfig, blk: BlockSpec, p: Params,
                             x: jax.Array, pos: jax.Array, pool: jax.Array,
                             layer: jax.Array, block_tables: jax.Array,
                             context_lens: jax.Array,
                             write_frames: jax.Array,
                             write_offsets: jax.Array, virtual_kv: int,
                             interpret: bool):
    """One decode block through the paged KV pool (no slot-dense cache).

    ``pool``: [frames, page, L, 2, vh, hd] — the single physical page buffer
    shared by every layer; ``layer`` (traced) selects the L-axis slice. The
    new token's K/V land at (write_frames[b], write_offsets[b]) and attention
    reads through ``block_tables``/``context_lens`` with the Pallas paged
    decode kernel. Returns (x, pool).
    """
    if blk.mixer != "attention":
        raise NotImplementedError(
            "paged decode supports attention mixers only; recurrent-state "
            f"mixer {blk.mixer!r} needs a per-slot state slab (ROADMAP)")
    from repro.kernels.decode_attention import paged_decode_attention_pallas

    h = L.apply_norm(cfg, p["norm1"], x)
    q, k1, v1 = L.qkv_project(cfg, p["attn"], h, pos[:, None], virtual_kv)
    pool = pool.at[write_frames, write_offsets, layer, 0].set(
        k1[:, 0].astype(pool.dtype))
    pool = pool.at[write_frames, write_offsets, layer, 1].set(
        v1[:, 0].astype(pool.dtype))
    kv_l = jax.lax.dynamic_index_in_dim(pool, layer, axis=2, keepdims=False)
    o = paged_decode_attention_pallas(
        q[:, 0], kv_l[:, :, 0], kv_l[:, :, 1], block_tables, context_lens,
        window=cfg.sliding_window, interpret=interpret)
    x = x + L.attn_out(cfg, p["attn"], o[:, None])

    if cfg.d_ff > 0:
        h = L.apply_norm(cfg, p["norm2"], x)
        if blk.mlp == "moe":
            y, _ = L.apply_moe(cfg, p["mlp"], h)
        else:
            y = L.apply_mlp(cfg, p["mlp"], h)
        x = x + y
    return x, pool


def apply_block_prefill_paged(cfg: ModelConfig, blk: BlockSpec, p: Params,
                              x: jax.Array, positions: jax.Array,
                              pool: jax.Array, layer: jax.Array,
                              block_table: jax.Array,
                              context_len: jax.Array,
                              write_frames: jax.Array,
                              write_offsets: jax.Array, virtual_kv: int,
                              interpret: bool):
    """One prefill *chunk* through the paged KV pool (incremental prefill).

    ``x``: [1, C, D] — the chunk's tokens at absolute ``positions`` [1, C];
    the chunk's K/V land at (write_frames[t], write_offsets[t]) per token,
    then the chunk's queries attend over the request's whole resident
    context (``block_table``/``context_len``) with the Pallas chunk kernel —
    no prefix recompute. Returns (x, pool).
    """
    if blk.mixer != "attention":
        raise NotImplementedError(
            "paged chunk prefill supports attention mixers only; "
            f"recurrent-state mixer {blk.mixer!r} needs a state slab")
    if cfg.sliding_window > 0:
        raise NotImplementedError(
            "paged chunk prefill has no sliding-window mask")
    from repro.kernels.prefill_attention import paged_chunk_attention_pallas

    h = L.apply_norm(cfg, p["norm1"], x)
    q, k1, v1 = L.qkv_project(cfg, p["attn"], h, positions, virtual_kv)
    pool = pool.at[write_frames, write_offsets, layer, 0].set(
        k1[0].astype(pool.dtype))
    pool = pool.at[write_frames, write_offsets, layer, 1].set(
        v1[0].astype(pool.dtype))
    kv_l = jax.lax.dynamic_index_in_dim(pool, layer, axis=2, keepdims=False)
    o = paged_chunk_attention_pallas(
        q[0], kv_l[:, :, 0], kv_l[:, :, 1], block_table, positions[0, 0],
        context_len, interpret=interpret)
    x = x + L.attn_out(cfg, p["attn"], o[None])

    if cfg.d_ff > 0:
        h = L.apply_norm(cfg, p["norm2"], x)
        if blk.mlp == "moe":
            y, _ = L.apply_moe(cfg, p["mlp"], h)
        else:
            y = L.apply_mlp(cfg, p["mlp"], h)
        x = x + y
    return x, pool


# ---------------------------------------------------------------------------
# Stack application (scan over R periods)
# ---------------------------------------------------------------------------


def apply_stack_seq(cfg: ModelConfig, blocks: list[Params], x: jax.Array,
                    ctx: SeqCtx, pattern: tuple[BlockSpec, ...] | None = None,
                    remat: bool = False):
    """Returns (x, caches_or_None, total_aux)."""
    pattern = pattern if pattern is not None else cfg.pattern

    def period(x, pslices):
        caches = []
        aux = jnp.zeros((), jnp.float32)
        for j, blk in enumerate(pattern):
            x, c, a = apply_block_seq(cfg, blk, pslices[j], x, ctx)
            caches.append(c)
            aux = aux + a
        return x, caches, aux

    if remat:
        period = jax.checkpoint(period)

    def body(carry, pslices):
        x = carry
        x, caches, aux = period(x, pslices)
        return x, (caches, aux)

    x, (caches, aux) = jax.lax.scan(body, x, blocks)
    return x, (caches if ctx.want_cache else None), jnp.sum(aux)


def apply_stack_decode(cfg: ModelConfig, blocks: list[Params], x: jax.Array,
                       pos: jax.Array, caches: list[Params], virtual_kv: int,
                       enc_pos: jax.Array | None = None,
                       pattern: tuple[BlockSpec, ...] | None = None):
    pattern = pattern if pattern is not None else cfg.pattern

    def body(x, xs):
        pslices, cslices = xs
        new = []
        for j, blk in enumerate(pattern):
            x, nc = apply_block_decode(cfg, blk, pslices[j], x, pos,
                                       cslices[j], virtual_kv, enc_pos)
            new.append(nc)
        return x, new

    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array):
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, "batch", None, None)


def lm_logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", None, "vocab")


def xent_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
              mask: jax.Array | None = None) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def xent_loss_chunked(cfg: ModelConfig, params: Params, hidden: jax.Array,
                      labels: jax.Array, mask: jax.Array | None = None,
                      chunk: int = 512) -> jax.Array:
    """Fused big-vocab cross entropy (§Perf hillclimb B4): computes the loss
    from the final *hidden* states, materializing logits only one sequence
    chunk at a time. The [B, S, V] f32 logits of a 256k-vocab model are the
    single largest training tensor (fwd write, lse read, gather read, bwd
    softmax re-materialization); chunking bounds that to [B, chunk, V] and
    jax.checkpoint recomputes it in the backward pass. Numerically identical
    to xent_loss(lm_logits(...)) — see tests/test_system.py."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    nc = (s + chunk - 1) // chunk
    pad = nc * chunk - s
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(h, y, m):
        lf = jnp.einsum("bsd,dv->bsv", h, head,
                        preferred_element_type=jnp.float32)
        lf = shard(lf, "batch", None, "vocab")
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, y[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * m)

    def body(carry, xs):
        h, y, m = xs
        return carry + chunk_nll(h, y, m), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, yc, mc))
    return total / jnp.maximum(jnp.sum(mc), 1.0)
