"""Online offloading-interval autotuner (paper §5, the online stage).

The offline stage (``core.record.PerformanceRecord`` + ``core.coordinator.
max_interval_for_memory``) brackets the interval: below ``min_interval`` the
SLO breaks even on an idle link, above ``max_interval`` the resident weights
don't fit HBM. Inside that range the best interval depends on runtime state
the record cannot see — pending KV link traffic, the tightest live TPOT
budget, queue depth — so the ``IntervalTuner`` re-picks it every iteration
from the same gauges the telemetry plane records.

Policy (the paper's objective is to maximize host memory, i.e. run at the
SMALLEST interval the latency budget tolerates):

  * candidates are the offline range ``[min_interval, max_interval]``
    (plus NO_OFFLOAD only when the whole model genuinely fits);
  * with an empty queue the tuner chases the objective directly: the
    smallest (= most host memory) candidate whose predicted latency fits
    the budget. Under a backlog the queue, not the iteration, is the
    user-visible latency, so it instead picks the SLO-feasible candidate
    with the highest estimated service rate (sustainable batch over
    predicted iteration time) — offloading harder than the backlog can
    afford would starve the drain and eventually the TTFT tail;
  * each candidate's next-iteration latency is predicted with the same
    analytic model the scheduler certifies against
    (``iter_time_with_interval_kv``), including the one-off demotion
    write-back a pool-shrinking resize would charge;
  * the tuner LIFTS host-ward (smaller interval) only after the same target
    stays feasible for ``lift_patience`` consecutive iterations — resizes
    demote/permute KV frames, so thrash is not free — and RETREATS
    (larger interval) immediately when the current interval's predicted
    latency leaves less than ``headroom_frac`` of the TPOT budget;
  * the executor may still refuse a resize (``ServingEngine.set_interval``
    returns False when the host pool cannot absorb the demoted KV). The
    engine bans the refused interval and asks again — ``note_refusal``
    keeps the count the trace footer exports.

Everything the tuner reads arrives through ``TunerGauges`` (plain values +
callables), so the policy is unit-testable without an engine, like the
scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Collection

from repro.core.interval import (LayerTimes, NO_OFFLOAD,
                                 iter_time_with_interval_kv)


@dataclasses.dataclass
class TunerConfig:
    # fraction of the tightest TPOT budget the predicted iteration may fill;
    # the rest is slack for traffic the prediction cannot see (COW copies,
    # chunk spill, admission growth)
    headroom_frac: float = 0.8
    # consecutive iterations a host-ward lift target must stay stable before
    # the tuner pays the resize
    lift_patience: int = 2


@dataclasses.dataclass
class TunerGauges:
    """One iteration's runtime state, snapshotted by the engine (or stubbed
    by a policy test)."""
    batch: int                    # active decode slots
    queue_depth: int              # waiting + preempted requests
    min_interval: int             # offline floor (record, over live+head)
    max_interval: int             # memory ceiling (max_interval_for_memory)
    num_units: int
    times: LayerTimes
    kv_in_bytes: float            # pending PCIe in (streamed + swap-in)
    kv_out_bytes: float           # pending PCIe out (write-backs)
    tpot_budget_s: float          # tightest live TPOT SLO (inf if none)
    # one-off demotion write-back bytes a switch to interval i would charge
    # (0 for the current interval)
    resize_out_bytes: Callable[[int], float]
    # decode slots the KV capacity at interval i could sustain (device pool
    # + host spill headroom, clamped to the slot count) — the batch the
    # backlog could actually run at, not the batch running now
    batch_capacity: Callable[[int], int] | None = None
    disk_in_bytes: float = 0.0
    disk_out_bytes: float = 0.0
    disk_bw: float = 0.0
    disk_latency_s: float = 0.0
    # pending PEER-tier handoff traffic (live post-prefill KV import/export
    # in a disaggregated fleet) — its own concurrent link channel, like disk
    peer_in_bytes: float = 0.0
    peer_out_bytes: float = 0.0
    peer_bw: float = 0.0
    peer_latency_s: float = 0.0


class IntervalTuner:
    def __init__(self, cfg: TunerConfig | None = None):
        self.cfg = cfg or TunerConfig()
        self._streak: tuple[int, int] = (0, 0)   # (lift target, run length)
        self.lifts = 0
        self.retreats = 0
        self.refusals = 0

    # ------------------------------------------------------------- model --
    def candidates(self, g: TunerGauges) -> list[int]:
        """The offline range, memory bound respected (same shape as
        ``InstanceState.valid_intervals`` — no fallback when empty)."""
        top = min(g.max_interval, g.num_units)
        cands = list(range(max(1, g.min_interval), top + 1))
        if g.max_interval >= NO_OFFLOAD:
            cands.append(NO_OFFLOAD)
        return cands

    def predicted_dt_s(self, g: TunerGauges, interval: int,
                       current: int) -> float:
        """Next-iteration latency at ``interval``, including the demotion
        write-back a switch away from ``current`` would charge."""
        kv_out = g.kv_out_bytes
        if interval != current:
            kv_out += g.resize_out_bytes(interval)
        return iter_time_with_interval_kv(
            g.times, interval, g.kv_in_bytes, kv_out,
            disk_in_bytes=g.disk_in_bytes, disk_out_bytes=g.disk_out_bytes,
            disk_bw=g.disk_bw, disk_latency_s=g.disk_latency_s,
            peer_in_bytes=g.peer_in_bytes, peer_out_bytes=g.peer_out_bytes,
            peer_bw=g.peer_bw, peer_latency_s=g.peer_latency_s)

    # ------------------------------------------------------------ policy --
    def propose(self, g: TunerGauges, current: int,
                banned: Collection[int] = ()) -> int:
        """Interval for the next iteration. Returns ``current`` when holding
        position; the engine applies anything else through ``set_interval``
        and calls again with the target banned if the executor refuses."""
        cands = [c for c in self.candidates(g) if c not in banned]
        if not cands:
            return current
        budget = g.tpot_budget_s * self.cfg.headroom_frac
        feas = [c for c in cands
                if self.predicted_dt_s(g, c, current) <= budget]
        if not feas:
            # nothing feasible: shed as much transfer as memory allows
            target = cands[-1]
        elif g.queue_depth > 0:
            # backlog: the queue is the latency now, so pick the feasible
            # interval that drains it fastest — estimated tokens/s =
            # sustainable batch / predicted iteration time. A small interval
            # wins this only when its extra KV room grows the batch by more
            # than the extra weight transfers cost; otherwise the tuner
            # holds throughput and resumes chasing host memory once the
            # queue empties. Ties go host-ward.
            if g.batch_capacity is None:
                # the packing-plan gauge is mandatory in backlog mode:
                # falling back to a constant reduces the rate objective to
                # plain latency and silently re-introduces the
                # average-footprint over-admission the packing plan fixed
                raise ValueError("backlog-mode tuning requires the "
                                 "batch_capacity packing-plan gauge")

            def score(c: int) -> float:
                return (max(g.batch_capacity(c), 1)
                        / self.predicted_dt_s(g, c, current))
            best = max(score(c) for c in feas)
            target = next(c for c in feas if score(c) >= best * (1 - 1e-12))
        else:
            # keeping up: smallest feasible = most host memory (the
            # paper's objective)
            target = feas[0]
        if target == current:
            self._streak = (current, 0)
            return current
        current_ok = (current in cands
                      and self.predicted_dt_s(g, current, current) <= budget)
        if target < current and current_ok:
            # host-ward lift from a healthy position: demand stability
            last, n = self._streak
            n = n + 1 if last == target else 1
            self._streak = (target, n)
            if n < self.cfg.lift_patience:
                return current
            self.lifts += 1
            return target
        # retreat, or current position is itself infeasible/banned: move now
        self._streak = (target, 0)
        if target > current:
            self.retreats += 1
        else:
            self.lifts += 1
        return target

    def note_refusal(self, interval: int) -> None:
        """The executor could not apply ``interval`` (host pool cannot absorb
        the demoted KV). Counted for the trace footer; the engine bans the
        interval for the current iteration's re-plan."""
        self.refusals += 1
