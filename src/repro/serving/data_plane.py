"""Double-buffered async copy-stage engine for the tiered KV data plane.

PR 5 wired the allocator's ``disk_copy``/``park_copy``/``promote_copy``
hooks straight into per-page synchronous copies: every park leg, disk
retirement and resume staging executed inline, inside the iteration whose
plan issued it, while the modeled clock (``iter_time_with_interval_kv``)
assumes iteration i+1's traffic overlaps iteration i's compute. This module
closes that gap. The allocator's hooks now stage copy *ops* — (tier, src
frame) -> (tier, dst frame) in planning order — and the plane either
executes each op immediately (sync mode: bitwise the PR 5 behavior) or
queues them and drains at the next iteration boundary, batching contiguous
same-kind runs into single gather/scatter calls and pushing host->disk
retirements to a background worker thread that overlaps decode.

Hazard rules (the planning-order guarantees the PR 5 token-corruption gate
pins):

* The queue is FIFO and a drain executes ops in queue order — a linear
  extension of every WAW/RAW hazard the allocator's planning pass created.
  Transit-frame reuse is the canonical case: a host frame freed by a
  demotion and reallocated by a later park in the same pass is written
  only after the demotion has read it.
* A batched run flushes early when two ops in the run write the same dst
  frame: XLA scatter duplicate-index order is unspecified, so duplicate
  dst writes never share a batch.
* Host->disk retirements run on the background worker. A drain waits for
  in-flight background jobs before executing any op that touches a frame
  a background job still reads or writes, and the engine guards its own
  host-pool writes (`guard_host_writes`) the same way.
* Every drain starts by waiting out the previous iteration's background
  jobs: a staging issued in iteration i is complete — and counted in the
  completion totals — by the boundary of i+1.

The issued/completed page counters feed the telemetry plane (per-iteration
``staged_issued_pages``/``staged_completed_pages`` and the footer
conservation check I10): every staged page is charged exactly once, and at
any trace prefix completions never exceed issues.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

import numpy as np

from repro.kernels import ops

# op kinds: device<->host, host<->disk, and the direct device<->disk path
# that bypasses the host bounce buffer (GPUDirect-style).
KINDS = ("d2h", "h2d", "h2disk", "disk2h", "disk2d", "d2disk")


class CopyStageEngine:
    """Stages, batches and (optionally) overlaps physical page copies.

    ``host_pool``/``disk_pool`` are the engine's numpy pools (stable
    identity, mutated in place); the device pool is functional JAX state, so
    it is reached through ``get_pool``/``set_pool``.
    """

    def __init__(self, *, host_pool: np.ndarray, disk_pool: np.ndarray,
                 get_pool: Callable, set_pool: Callable,
                 async_mode: bool = False, background: bool = True):
        self.host_pool = host_pool
        self.disk_pool = disk_pool
        self._get_pool = get_pool
        self._set_pool = set_pool
        self.async_mode = async_mode
        self._background = background and async_mode

        self._queue: list[tuple[str, int, int]] = []
        self._cv = threading.Condition()
        self.issued_pages_total = 0
        self.completed_pages_total = 0
        self._iter_issued = 0
        self._iter_completed = 0
        # wall seconds the physical copies cost the iteration thread (sync
        # stage() execs, drains, hazard waits) vs. seconds absorbed by the
        # background worker. blocking_copy_s is the real-clock overhead the
        # data plane adds on top of the modeled dt — the fidelity gap
        # fig18's clock-vs-wall claim measures.
        self.blocking_copy_s = 0.0
        self.background_copy_s = 0.0

        # background h2disk worker state (guarded by self._cv)
        self._bg_pending = 0          # jobs submitted, not yet finished
        self._bg_host: set[int] = set()   # host frames in-flight jobs read
        self._bg_disk: set[int] = set()   # disk frames in-flight jobs write
        self._jobs: list[tuple[list[int], list[int]]] = []
        self._worker: threading.Thread | None = None

    # ----- staging ---------------------------------------------------------

    def stage(self, kind: str, src: int, dst: int) -> None:
        """Stage one page copy. Sync mode executes it immediately (planning
        order == execution order, per page — the PR 5 hook semantics); async
        mode queues it for the next drain."""
        assert kind in KINDS, kind
        with self._cv:
            self.issued_pages_total += 1
            self._iter_issued += 1
        if not self.async_mode:
            t0 = time.perf_counter()
            self._exec_group(kind, [src], [dst])
            self.blocking_copy_s += time.perf_counter() - t0
            with self._cv:
                self.completed_pages_total += 1
                self._iter_completed += 1
            return
        self._queue.append((kind, src, dst))

    # ----- draining --------------------------------------------------------

    def drain(self) -> None:
        """Iteration boundary: complete last iteration's background jobs,
        then execute every queued op in FIFO order, batching maximal
        consecutive same-kind runs (flushing on duplicate dst frames).
        Host->disk runs go to the background worker and overlap the rest of
        the iteration; everything else executes inline."""
        if not self.async_mode:
            return
        t0 = time.perf_counter()
        try:
            self._drain_locked()
        finally:
            self.blocking_copy_s += time.perf_counter() - t0

    def _drain_locked(self) -> None:
        self._wait_bg()
        q, self._queue = self._queue, []
        i = 0
        while i < len(q):
            kind = q[i][0]
            srcs, dsts = [q[i][1]], [q[i][2]]
            seen = {q[i][2]}
            i += 1
            while i < len(q) and q[i][0] == kind and q[i][2] not in seen:
                srcs.append(q[i][1])
                dsts.append(q[i][2])
                seen.add(q[i][2])
                i += 1
            if kind == "h2disk" and self._background:
                self._submit_bg(srcs, dsts)
            else:
                self._guard_group(kind, srcs, dsts)
                self._exec_group(kind, srcs, dsts)
                with self._cv:
                    self.completed_pages_total += len(srcs)
                    self._iter_completed += len(srcs)

    def sync(self) -> None:
        """Complete every queued and in-flight op (run end, trace export,
        or any external read of the physical pools)."""
        if not self.async_mode:
            return
        self.drain()
        t0 = time.perf_counter()
        self._wait_bg()
        self.blocking_copy_s += time.perf_counter() - t0

    # ----- peer handoff ----------------------------------------------------

    def peer_export(self, srcs: list[int], out: np.ndarray) -> None:
        """Gather host frames into a handoff ticket payload (PEER tier
        export). The gather is a host-pool read, so every queued op that
        writes these frames — the park's d2h legs staged in the same
        planning pass — must land first: an async queue is drained before
        the copy. In-flight background retirements only *read* host
        frames, so they need no wait. The bytes themselves are charged to
        the peer link's own latency term via the allocator's pending peer
        counters, never to the staged-plane totals."""
        if self.async_mode:
            self.drain()
        t0 = time.perf_counter()
        for i, s in enumerate(srcs):
            out[i] = self.host_pool[s]
        self.blocking_copy_s += time.perf_counter() - t0

    def peer_import(self, payload: np.ndarray, dsts: list[int]) -> None:
        """Scatter a handoff ticket payload into freshly claimed host
        frames (PEER tier import). A host-pool write: any in-flight
        background retirement still reading these frames must finish
        first — the same guard every engine-side host write takes."""
        self.guard_host_writes(dsts)
        t0 = time.perf_counter()
        for i, d in enumerate(dsts):
            self.host_pool[d] = payload[i]
        self.blocking_copy_s += time.perf_counter() - t0

    # ----- hazard guards ---------------------------------------------------

    def guard_host_writes(self, frames) -> None:
        """Engine-side host-pool writes (prefill spill scatter, streamed
        writeback, COW landing) must not overwrite a frame an in-flight
        background retirement is still reading."""
        if not self._background:
            return
        with self._cv:
            if self._bg_pending == 0:
                self._bg_host.clear()
                self._bg_disk.clear()
                return
            conflict = any(f in self._bg_host for f in frames)
        if conflict:
            t0 = time.perf_counter()
            self._wait_bg()
            self.blocking_copy_s += time.perf_counter() - t0

    def _guard_group(self, kind: str, srcs: list[int],
                     dsts: list[int]) -> None:
        """Before an inline group runs, wait out background jobs whose
        frames it conflicts with. Background jobs read host frames and
        write disk frames; read-read sharing is safe."""
        if not self._background:
            return
        with self._cv:
            if self._bg_pending == 0:
                self._bg_host.clear()
                self._bg_disk.clear()
                return
            bh, bd = self._bg_host, self._bg_disk
            if kind in ("d2h", "disk2h"):          # writes host dsts
                conflict = any(f in bh for f in dsts)
            else:
                conflict = False
            if kind in ("disk2h", "disk2d"):       # reads disk srcs
                conflict = conflict or any(f in bd for f in srcs)
            if kind in ("h2disk", "d2disk"):       # writes disk dsts
                conflict = conflict or any(f in bd for f in dsts)
        if conflict:
            self._wait_bg()

    # ----- background worker -----------------------------------------------

    def _submit_bg(self, srcs: list[int], dsts: list[int]) -> None:
        with self._cv:
            # WAW on a reclaimed disk frame (or RAR on a reused host frame)
            # against an earlier in-flight job: drain it first.
            conflict = (self._bg_pending > 0
                        and (any(d in self._bg_disk for d in dsts)
                             or any(s in self._bg_host for s in srcs)))
        if conflict:
            self._wait_bg()
        with self._cv:
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._worker_loop, daemon=True,
                    name="kv-copy-stage")
                self._worker.start()
            self._bg_pending += 1
            self._bg_host.update(srcs)
            self._bg_disk.update(dsts)
            self._jobs.append((srcs, dsts))
            self._cv.notify_all()

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._jobs:
                    self._cv.wait()
                srcs, dsts = self._jobs.pop(0)
            t0 = time.perf_counter()
            for s, d in zip(srcs, dsts):
                self.disk_pool[d] = self.host_pool[s]
            dt = time.perf_counter() - t0
            with self._cv:
                self.background_copy_s += dt
                self._bg_pending -= 1
                self.completed_pages_total += len(srcs)
                self._iter_completed += len(srcs)
                self._cv.notify_all()

    def _wait_bg(self) -> None:
        with self._cv:
            while self._bg_pending:
                self._cv.wait()
            self._bg_host.clear()
            self._bg_disk.clear()

    # ----- counters --------------------------------------------------------

    def inflight_pages(self) -> int:
        with self._cv:
            return self.issued_pages_total - self.completed_pages_total

    def take_iteration_counters(self) -> tuple[int, int]:
        """(issued, completed) page deltas since the last call — sampled
        once per iteration into the trace record."""
        with self._cv:
            out = (self._iter_issued, self._iter_completed)
            self._iter_issued = 0
            self._iter_completed = 0
        return out

    # ----- execution -------------------------------------------------------

    def _exec_group(self, kind: str, srcs: list[int],
                    dsts: list[int]) -> None:
        if kind == "d2h":
            ops.copy_pages_to_host(self._get_pool(), srcs,
                                   self.host_pool, dsts)
        elif kind == "h2d":
            self._set_pool(ops.copy_pages_from_host(
                self.host_pool, srcs, self._get_pool(), dsts))
        elif kind == "disk2h":
            for s, d in zip(srcs, dsts):
                self.host_pool[d] = self.disk_pool[s]
        elif kind == "h2disk":
            for s, d in zip(srcs, dsts):
                self.disk_pool[d] = self.host_pool[s]
        elif kind == "disk2d":
            self._set_pool(ops.copy_pages_from_host(
                self.disk_pool, srcs, self._get_pool(), dsts))
        elif kind == "d2disk":
            ops.copy_pages_to_host(self._get_pool(), srcs,
                                   self.disk_pool, dsts)
        else:  # pragma: no cover
            raise ValueError(f"unknown copy kind {kind!r}")
