"""SLO-aware serving executor: paged-kernel decode with Select-N offloading.

One engine = one model instance (one TP group on real hardware). Scheduling
POLICY lives in ``serving.scheduler``: per iteration the engine snapshots
its state into a ``SchedulerView``, receives an ``IterationPlan``
(preemptions, resumes, admissions, prefill chunks, decode slots), applies it
— page copies, prefill compute + scatter, one decode step for all active
slots — and reports an ``IterationOutcome`` back. The engine still owns the
*modeled* clock (LayerTimes under the current offload plan — token flow is
real JAX compute; SLO timing is the deterministic analytic schedule, which on
a real TPU host would be wall clock) and every physical page byte.

Decode computes through the paged Pallas kernel against a SINGLE physical
page-pool buffer: the frames the ``TieredKVAllocator`` accounts for are the
frames the kernel reads, so the accounting pool and the compute pool are one
object. Layout of ``self.pool`` ([frames, page, L, 2, vh, hd], bf16 like the
dense cache spec):

  frames [0, dev_cap)          device-tier frames. Accounting frame ids are
                               always < dev_cap because the free list is
                               LIFO: a fresh id is handed out only when every
                               lower id is in use, so the high-water mark is
                               bounded by peak concurrency
                               (max_batch * pages_for(max_seq), plus one
                               still-unconsumed COW reserve per slot when
                               prefix dedup is on).
  frames [dev_cap, 2*dev_cap)  the streaming slab: host-resident pages of
                               active requests are gathered here each
                               iteration for attention (no residency change —
                               this is the per-iteration streamed traffic the
                               swap scheduler charges to the link).
  frame  2*dev_cap             the null frame: idle batch rows and padded
                               block-table slots point here.

Prefill scatters new KV into allocated frames (``kernels.ops`` batched
scatter); swap-in/out and interval-driven resizes copy directly between the
pinned-host pool and this same buffer (no repack). The offloading interval is
re-evaluated every iteration through the per-bus coordinator when the engine
shares a link with peers (§4.5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core.coordinator import (InstanceState, coordinate,
                                    max_interval_for_memory)
from repro.core.hardware import HardwareModel
from repro.core.interval import (LayerTimes, NO_OFFLOAD, OffloadPlan,
                                 iter_time_breakdown_kv,
                                 iter_time_with_interval_kv, link_bandwidth)
from repro.core.memory_manager import (OffloadRuntime, merge_stacked,
                                       split_model_params)
from repro.core.record import PerformanceRecord
from repro.kernels import ops
from repro.models.model import Model
from repro.models.transformer import pattern_info
from repro.serving.autotune import IntervalTuner, TunerGauges
from repro.serving.data_plane import CopyStageEngine
from repro.serving.kv_cache import PageConfig, PagedKVAllocator
from repro.serving.kv_offload import (DEVICE, DISK, HOST, LinkSpec,
                                      MigrationTicket, SwapScheduler,
                                      TieredKVAllocator)
from repro.serving.request import Request, State
from repro.serving.scheduler import (ActiveInfo, IterationOutcome,
                                     IterationPlan, PlannedPreemption,
                                     PlannedResume, PrefillChunk, Scheduler,
                                     SchedulerConfig, SchedulerView)
from repro.serving.telemetry import (IterationRecord, SlotGauge,
                                     TraceRecorder, summarize_latency)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 64
    hbm_budget_bytes: float = 16e9
    page_size: int = 16
    greedy: bool = True          # greedy sampling
    # Two-tier KV offloading (serving.kv_offload): pinned-host page pool
    # budget. 0 disables the host tier — admission then falls back to the
    # device-only behavior (wait for pages).
    host_kv_bytes: float = 0.0
    # Cross-request prefix dedup + copy-on-write pages: prompts sharing a
    # prefix with a live/host-parked request map onto the same physical
    # frames (refcount += 1); a write into a shared page moves the writer
    # onto its pre-claimed private frame first. Off by default — the
    # dedup-off engine is the PR-2 baseline the differential suite locksteps
    # against.
    prefix_dedup: bool = False
    # Scheduling policies (serving.scheduler). Both default off — the
    # policy-off scheduler reproduces the fused engine's admission decisions
    # exactly, which is what the differential suite locksteps.
    preemption: bool = False           # preempt-to-host under admission stalls
    prefill_chunk_tokens: int = 0      # >0: chunked prefill, page-aligned
    # Prefix-cache keep-alive: host frames whose last owner freed survive
    # (LRU, this many pages) so a re-submitted shared prefix still dedups.
    host_prefix_cache_pages: int = 0
    # Disk (NVMe) KV tier below the host pool: parked/preempted requests
    # and aged-out prefix-cache frames retire here under host pressure
    # instead of blocking parks / evicting cache. 0 disables the tier —
    # the three-tier engine with disk disabled is bit-identical to the
    # two-tier baseline (differential-gated).
    disk_kv_bytes: float = 0.0
    # NVMe link model: traffic to/from the disk tier gets its own term in
    # the iteration-latency model (it never rides the PCIe budget).
    disk_bw_bytes_s: float = 3e9
    disk_latency_s: float = 1e-4
    # Optional file path for the disk pool's backing store (np.memmap);
    # None keeps a RAM buffer standing in for NVMe.
    disk_backing_path: str | None = None
    # Async data plane (serving.data_plane): queue the allocator's copy
    # hooks in planning order and drain them at the next iteration
    # boundary — batched gather/scatter runs, host->disk retirements on a
    # background worker overlapping decode, and a staged prefetch of the
    # oldest parked request's disk pages ahead of its predicted resume.
    # Off = every hook copy executes synchronously at plan time (the PR 5
    # behavior, bitwise identical token streams either way).
    async_data_plane: bool = False
    # Staged-prefetch depth: disk pages of the oldest parked request staged
    # host-ward per iteration boundary (async mode only). 1 keeps the
    # conservative one-page cadence; deeper drains a parked request's disk
    # set in fewer boundaries at the cost of host frames held earlier. The
    # effective depth is always bounded by free host frames.
    prefetch_pages_per_boundary: int = 1
    # Incremental chunked prefill: each chunk attends only its own queries
    # against the resident paged KV (Pallas chunk kernel) instead of
    # recomputing the whole prefix per chunk. Opt-in: chunk logits now see
    # the pool's bf16-rounded prefix KV, so numerics differ from the
    # whole-prefix recompute path at rounding level.
    incremental_prefill: bool = False
    # Online interval autotuning (serving.autotune): re-pick the offloading
    # interval every iteration inside the offline record's feasible range
    # from runtime gauges (pending link traffic, tightest live TPOT budget,
    # queue depth) — the paper's §5 online stage. Mutually exclusive with
    # the peer coordinator, which owns the interval when a link is shared.
    autotune: bool = False
    # Instance role in a disaggregated fleet (serving.fleet): "mixed" runs
    # the full request lifecycle (the symmetric fleet behavior); "prefill"
    # computes prompts and hands each finished prefill peer-ward; "decode"
    # adopts handed-off requests and decodes them. Role typing only changes
    # fleet routing/handoff policy — the engine itself can always do both.
    role: str = "mixed"
    # Peer link model (PEER tier): KV handoff traffic to/from other
    # instances gets its own term in the iteration-latency model, exactly
    # like the NVMe link — it never rides the PCIe budget.
    peer_bw_bytes_s: float = 16e9
    peer_latency_s: float = 1e-5


class ServingEngine:
    def __init__(self, name: str, model: Model, hw: HardwareModel,
                 rec_prefill: PerformanceRecord, rec_decode: PerformanceRecord,
                 times_fn: Callable[[int, int, str], LayerTimes],
                 ecfg: EngineConfig = EngineConfig()):
        self.name = name
        self.model = model
        self.cfg: ModelConfig = model.cfg
        if (any(b.mixer != "attention" for b in self.cfg.pattern)
                or self.cfg.encoder_layers > 0
                or self.cfg.frontend is not None
                or self.cfg.sliding_window > 0):
            raise NotImplementedError(
                "the paged engine path requires an attention-only decoder "
                "(no encoder / frontend / sliding window): recurrent-state "
                "slabs and windowed prefill unpacking are ROADMAP items")
        self.hw = hw
        self.rec = {"prefill": rec_prefill, "decode": rec_decode}
        self.times_fn = times_fn
        self.ecfg = ecfg
        _, self.num_units = pattern_info(self.cfg)
        self.unit_bytes = costs.unit_weight_bytes(self.cfg)

        self.params = model.init(jax.random.PRNGKey(0))
        self.clock_s = 0.0
        self.interval = NO_OFFLOAD
        self.finished: list[Request] = []
        self.rejected: list[Request] = []

        # slot state
        b = ecfg.max_batch
        self.slot_req: list[Request | None] = [None] * b
        self.tokens = np.zeros((b,), np.int32)
        self.pos = np.zeros((b,), np.int32)
        self.active = np.zeros((b,), bool)

        kv_tok = max(costs.kv_cache_bytes(self.cfg, 1, 1,
                                          self.model.virtual_kv), 1)
        weight_free = (ecfg.hbm_budget_bytes
                       - OffloadPlan(self.num_units, NO_OFFLOAD)
                       .device_bytes(self.unit_bytes))
        # prefix-dedup scope: frames are content-addressed per model config
        # AND page geometry — two engines with different weights or page
        # sizes must never map onto each other's hashes
        scope = f"{self.cfg!r}|page={ecfg.page_size}"
        self.kv = TieredKVAllocator(
            max(int(weight_free), 0), ecfg.host_kv_bytes,
            PageConfig(ecfg.page_size, bytes_per_token=kv_tok),
            scope=scope, enable_dedup=ecfg.prefix_dedup,
            host_prefix_cache_pages=ecfg.host_prefix_cache_pages,
            disk_bytes=ecfg.disk_kv_bytes,
            disk_link=LinkSpec(bw_bytes_s=ecfg.disk_bw_bytes_s,
                               latency_s=ecfg.disk_latency_s),
            disk_backing_path=ecfg.disk_backing_path,
            peer_link=LinkSpec(bw_bytes_s=ecfg.peer_bw_bytes_s,
                               latency_s=ecfg.peer_latency_s))
        if ecfg.role not in ("mixed", "prefill", "decode"):
            raise ValueError(f"unknown instance role {ecfg.role!r}")
        self.role = ecfg.role
        self.swap = SwapScheduler(self.kv)
        # policy layer: owns the queue, the preempted set and slot
        # assignment; this engine executes the plans it emits
        self.scheduler = Scheduler(
            self.kv, self.swap, ecfg.max_batch, ecfg.max_seq,
            rec_decode, self.times_fn, self._modeled_ttft,
            self._max_interval_now,
            SchedulerConfig(preemption=ecfg.preemption,
                            prefill_chunk_tokens=ecfg.prefill_chunk_tokens),
            prefill_seconds=self._prefill_seconds)
        # prefill-role instances hold parked requests for peer handoff
        # instead of resuming them locally
        self.scheduler.hold_resumes = self.role == "prefill"
        self.host_kv_peak_pages = 0
        self.streamed_pages_peak = 0
        self.device_pages_peak = 0
        self.disk_kv_peak_pages = 0
        self.cow_events = 0

        # physical page pool (see module docstring for the frame map).
        # With dedup, a slot can pin pages_for(max_seq) block-table frames
        # PLUS one still-unconsumed COW reserve, so the LIFO high-water
        # bound gains one frame per slot.
        self.nb = self.kv.device.pages_for(ecfg.max_seq)
        self.dev_cap = ecfg.max_batch * (self.nb + int(ecfg.prefix_dedup))
        self.slab_base = self.dev_cap
        self.null_frame = 2 * self.dev_cap
        vh, hd = self.model.virtual_kv, self.cfg.resolved_head_dim
        self.page_shape = (ecfg.page_size, self.cfg.num_layers, 2, vh, hd)
        self.pool = jnp.zeros((self.null_frame + 1, *self.page_shape),
                              jnp.bfloat16)
        self.host_pool = (self.kv.host.make_pool_buffer(self.page_shape,
                                                        jnp.bfloat16)
                          if self.kv.host.total_pages > 0 else None)
        # disk-tier data plane: every host<->disk accounting move fires a
        # copy hook into the copy-stage engine, which executes it at once
        # (sync mode) or queues it in planning order for the next
        # iteration-boundary drain (async mode)
        self.disk_pool = (self.kv.disk.make_pool_buffer(self.page_shape,
                                                        jnp.bfloat16)
                          if self.kv.disk.total_pages > 0 else None)
        self.data_plane: CopyStageEngine | None = None
        if self.disk_pool is not None:
            assert self.host_pool is not None, \
                "a disk KV tier requires a host tier to stage through"
            self.data_plane = CopyStageEngine(
                host_pool=self.host_pool, disk_pool=self.disk_pool,
                get_pool=lambda: self.pool,
                set_pool=self._set_pool,
                async_mode=ecfg.async_data_plane)
            self.kv.disk_copy = self._disk_page_copy
            # resume staging chains disk pages through host transit frames:
            # its h2d promotion legs must read those frames in planning
            # order, before the next staging overwrites them; park's d2h
            # legs must likewise land before a same-pass demotion retires
            # the parked frames to NVMe. The copy-stage engine preserves
            # exactly that order (FIFO queue, duplicate-dst batch flushes).
            self.kv.promote_copy = self._promote_page_copy
            self.kv.park_copy = self._park_page_copy
            # GPUDirect-style disk->device staging that skips the host
            # bounce buffer whenever a device frame is free
            self.kv.direct_copy = self._direct_page_copy
        self.prefetch_pages_total = 0

        if ecfg.incremental_prefill and ecfg.prefix_dedup:
            raise NotImplementedError(
                "incremental prefill under prefix dedup needs skip-write/"
                "COW handling for deduped chunk pages (ROADMAP)")
        self.prefill_tokens_computed = 0   # quadratic-vs-linear evidence

        self._runtime: dict[int, OffloadRuntime] = {}
        self._jit_decode: dict[int, Any] = {}
        self._jit_prefill: dict[int, Any] = {}
        self._jit_chunk: dict[int, Any] = {}
        self._params_split: dict[int, Any] = {}

        # per-step observability for the differential harness
        self.prefill_log: list[tuple[Request, int, np.ndarray]] = []
        self.last_decode: dict | None = None

        # iteration-level telemetry plane (serving.telemetry): always on —
        # records are tiny and the differential suites audit every run
        self.trace = TraceRecorder(name, ecfg.max_batch, self.kv.page_bytes)
        self.trace._footer_fn = self._trace_footer
        self.cow_in_bytes_total = 0.0
        self.cow_out_bytes_total = 0.0

        # online interval autotuner (§5 online stage) + interval telemetry
        self.tuner: IntervalTuner | None = \
            IntervalTuner() if ecfg.autotune else None
        self.interval_refusals = 0     # set_interval refused a resize
        self.interval_switches = 0     # applied interval changes
        # modeled time run() spent idle waiting for the next arrival; the
        # pending amount is stamped on the next iteration record so the
        # trace auditor can still tile the clock
        self.idle_wait_s = 0.0
        self.idle_wait_total_s = 0.0
        # cross-instance migration (fleet): ticket bytes sent/received over
        # the peer link and the modeled transfer seconds charged to THIS
        # instance's clock. Pending amounts accumulate between iterations
        # and are stamped on the next record (same idle_wait_s discipline),
        # so the trace auditor can tile the clock and conserve the bytes
        self.mig_in_bytes_total = 0.0
        self.mig_out_bytes_total = 0.0
        self.pending_mig_in_bytes = 0.0
        self.pending_mig_out_bytes = 0.0
        self.mig_wait_s = 0.0
        self.mig_wait_total_s = 0.0
        self.n_migrated_in = 0
        self.n_migrated_out = 0
        # live post-prefill KV handoff (disaggregated fleet): ticket bytes
        # exported/imported over the PEER tier's link. Unlike emergency
        # migration, a handoff transfer is never charged synchronously to
        # either clock — each side's pages drain into its own next
        # iteration's peer-link term (note_peer_export/import ->
        # SwapPlan.peer_* -> peer_s), so the transfer overlaps modeled
        # compute like any other offload channel.
        self.handoff_in_bytes_total = 0.0
        self.handoff_out_bytes_total = 0.0
        self.n_handoff_in = 0
        self.n_handoff_out = 0

    # ------------------------------------------------------------------ plan --
    @property
    def allocator(self) -> PagedKVAllocator:
        """Device-tier page pool (back-compat accessor)."""
        return self.kv.device

    def _plan(self, interval: int) -> OffloadPlan:
        return OffloadPlan(self.num_units, interval)

    def set_interval(self, interval: int) -> bool:
        """Apply a (possibly new) offloading interval before the next
        iteration (coordinator/tuner output). Re-splits params lazily; the
        KV pool is re-accounted and the physical frames follow the remap.

        Returns True when the interval is in effect afterwards (applied, or
        already current), False when the executor REFUSED the resize:
        growing the resident set would orphan live KV pages the host pool
        cannot absorb. Callers owning interval policy (coordinator re-plan,
        tuner) must treat False as "re-plan without this interval" — the
        engine still runs at the old interval and ``instance_state`` keeps
        reporting it."""
        if interval == self.interval:
            return True
        weight_free_new = (self.ecfg.hbm_budget_bytes
                           - self._plan(interval).device_bytes(self.unit_bytes))
        if not self.kv.can_resize_device(max(int(weight_free_new), 0)):
            # memory-bound refusal: ``max_interval_for_memory`` bounds the
            # resident weights against HBM minus *used* KV, but absorbing
            # the displaced KV needs host free pages too — under host
            # pressure that can fail, and the caller must re-plan
            self.interval_refusals += 1
            if self.tuner is not None:
                self.tuner.note_refusal(interval)
            return False
        self.interval = interval
        self.interval_switches += 1
        # re-account KV budget: resident bytes changed. A shrinking device
        # pool demotes KV pages host-ward; the write-back bytes are charged
        # to the next iteration's link budget by the swap scheduler. The
        # physical pool mirrors the accounting moves: demoted frames are
        # copied out while still intact, then surviving frames permute.
        res = self.kv.resize_device(max(int(weight_free_new), 0))
        if self.data_plane is not None:
            # any retire-to-disk ops the resize staged must read their host
            # frames before the demotion copies below can reuse them
            self.data_plane.drain()
        if res.demotions:
            assert self.host_pool is not None
            self._guard_host_writes([m.dst_page for m in res.demotions])
            ops.copy_pages_to_host(self.pool,
                                   [m.src_page for m in res.demotions],
                                   self.host_pool,
                                   [m.dst_page for m in res.demotions])
            self.swap.note_demotions(len(res.demotions))
        moves = [(o, n) for o, n in res.remap if o != n]
        if moves:
            got = ops.gather_kv_pages(
                self.pool, jnp.asarray([o for o, _ in moves], jnp.int32))
            self.pool = ops.scatter_kv_pages(
                self.pool, jnp.asarray([n for _, n in moves], jnp.int32), got)
        return True

    def _rt(self, interval: int) -> OffloadRuntime:
        if interval not in self._runtime:
            rt = OffloadRuntime(model=self.model, plan=self._plan(interval))
            self._runtime[interval] = rt
            self._params_split[interval] = split_model_params(
                self.params, rt.plan)
            self._jit_decode[interval] = jax.jit(rt.paged_decode_step,
                                                 donate_argnums=(3,))
        return self._runtime[interval]

    # ------------------------------------------------------------ admission --
    @property
    def queue(self) -> list[Request]:
        """Waiting requests (owned by the scheduler; back-compat accessor)."""
        return self.scheduler.queue

    def _active_rids(self) -> list[int]:
        return [r.rid for r in self.slot_req if r is not None]

    def _max_interval_now(self) -> int:
        """Memory-bounded interval ceiling under current KV usage (shared by
        the coordinator state and the scheduler's admission check)."""
        return max_interval_for_memory(
            self.num_units, self.unit_bytes,
            self.ecfg.hbm_budget_bytes
            - self.allocator.used_pages * self.allocator.page_bytes)

    def _min_interval_now(self) -> int:
        """SLO floor on the interval under the CURRENT population: the max
        of the record lookups for the head-of-queue waiting request (at the
        batch its admission would create) and every active slot's TPOT SLO.
        A coordinator/tuner rebalance below this would break a live request,
        not just the next admission."""
        batch = self._active_batch()
        waiting = self.queue[0] if self.queue else None
        floors = []
        if waiting is not None:
            seq = waiting.prompt_len + waiting.max_new_tokens
            floors.append(self.rec["decode"].lookup(waiting.tpot_slo_s,
                                                    batch + 1, seq))
        for req in self.slot_req:
            if req is None:
                continue
            seq = req.prompt_len + req.max_new_tokens
            floors.append(self.rec["decode"].lookup(req.tpot_slo_s,
                                                    max(batch, 1), seq))
        if not floors:
            # empty engine: hold the current position (idle instances don't
            # constrain the coordinator anyway)
            return self.interval if self.interval < NO_OFFLOAD else 1
        return max(floors)

    def instance_state(self, idle: bool | None = None) -> InstanceState:
        min_i = self._min_interval_now()
        times = self.times_fn(max(self._active_batch(), 1),
                              self.ecfg.max_seq, "decode")
        max_i = self._max_interval_now()
        kv_stream = (self.swap.streamed_bytes(self._active_rids())
                     + self.swap.pending_in_bytes())
        kv_out = self.swap.pending_out_bytes()
        return InstanceState(
            name=self.name, num_units=self.num_units,
            unit_bytes=self.unit_bytes,
            # NVMe is instance-local: its pending traffic lengthens this
            # instance's iteration (own term) but is not part of the
            # shared-PCIe rate the coordinator arbitrates
            # (kv_bytes_per_iter stays PCIe-only)
            t_iter_s=iter_time_with_interval_kv(
                times, self.interval if self.interval else NO_OFFLOAD,
                kv_stream, kv_out,
                disk_in_bytes=self.swap.pending_disk_in_bytes(),
                disk_out_bytes=self.swap.pending_disk_out_bytes(),
                disk_bw=self.kv.disk_link.bw_bytes_s,
                disk_latency_s=self.kv.disk_link.latency_s,
                peer_in_bytes=self.swap.pending_peer_in_bytes(),
                peer_out_bytes=self.swap.pending_peer_out_bytes(),
                peer_bw=self.kv.peer_link.bw_bytes_s,
                peer_latency_s=self.kv.peer_link.latency_s),
            min_interval=min_i, max_interval=max_i,
            idle=idle if idle is not None else self._active_batch() == 0
            and not self.scheduler.has_work(),
            kv_bytes_per_iter=kv_stream + kv_out,
            # pending handoff traffic: its own link, but the fleet budget
            # arbitrates it alongside weight prefetch (FleetLinkBudget)
            peer_bytes_per_iter=(self.swap.pending_peer_in_bytes()
                                 + self.swap.pending_peer_out_bytes()))

    # ------------------------------------------------------------ autotune --
    def _resize_out_bytes(self, interval: int) -> float:
        """Demotion write-back bytes a switch to ``interval`` would charge
        to the next iteration's link budget (KV pages displaced from the
        shrinking device pool, host-ward)."""
        if interval == self.interval:
            return 0.0
        weight_free = max(int(self.ecfg.hbm_budget_bytes
                              - self._plan(interval)
                              .device_bytes(self.unit_bytes)), 0)
        new_pages = weight_free // self.kv.page_bytes
        return float(max(self.kv.device.used_pages - new_pages, 0)
                     * self.kv.page_bytes)

    def _batch_capacity(self, interval: int) -> int:
        """Decode slots the KV capacity at ``interval`` could sustain for
        the current population, as a packing plan over the allocator's
        ACTUAL free frames (device headroom at that interval, free host
        frames, and reclaimable keep-alive cache pages): residents keep
        their claimed frames and charge only their remaining growth, then
        waiting requests' full footprints pack greedily smallest-first.
        The tuner's backlog mode trades this against the interval's
        iteration time. (Replaces the average-footprint estimate, which
        counted the WHOLE host pool — pages already claimed by parked
        requests included — and over-admitted under host pressure.)"""
        weight_free = max(int(self.ecfg.hbm_budget_bytes
                              - self._plan(interval)
                              .device_bytes(self.unit_bytes)), 0)
        dev_pages = weight_free // self.kv.page_bytes
        free_pages = (max(dev_pages - self.kv.device.used_pages, 0)
                      + self.kv.host.free_pages
                      + self.kv.reclaimable_host_pages())
        residents = ([r for r in self.slot_req if r is not None]
                     + list(self.scheduler.preempted))
        if not residents and not self.queue:
            return self.ecfg.max_batch

        def need_pages(r: Request) -> int:
            return self.kv.device.pages_for(r.prompt_len + r.max_new_tokens)

        fit = len(residents)
        growth = 0
        for r in residents:
            have = len(self.kv.refs(r.rid)) \
                + len(self.kv.reserves_of(r.rid))
            growth += max(need_pages(r) - have, 0)
        budget = free_pages - growth
        for need in sorted(need_pages(r) for r in self.queue):
            if need > budget:
                break
            budget -= need
            fit += 1
        return int(max(1, min(self.ecfg.max_batch, fit)))

    def _tuner_gauges(self) -> TunerGauges:
        """Snapshot the runtime state the online tuner decides from — the
        same quantities the telemetry plane records per iteration."""
        batch = self._active_batch()
        # tightest budget over live slots AND every waiter: the scheduler's
        # admission pass scans the whole queue (plus parked requests), so
        # the tuner must pre-position for whichever of them it certifies
        # next, not just the population already decoding
        tpots = [r.tpot_slo_s for r in self.slot_req if r is not None]
        tpots += [r.tpot_slo_s for r in self.queue]
        tpots += [r.tpot_slo_s for r in self.scheduler.preempted]
        return TunerGauges(
            batch=batch,
            queue_depth=len(self.queue) + len(self.scheduler.preempted),
            min_interval=self._min_interval_now(),
            max_interval=self._max_interval_now(),
            num_units=self.num_units,
            times=self.times_fn(max(batch, 1), self.ecfg.max_seq, "decode"),
            kv_in_bytes=(self.swap.streamed_bytes(self._active_rids())
                         + self.swap.pending_in_bytes()),
            kv_out_bytes=self.swap.pending_out_bytes(),
            tpot_budget_s=min(tpots) if tpots else float("inf"),
            resize_out_bytes=self._resize_out_bytes,
            batch_capacity=self._batch_capacity,
            disk_in_bytes=self.swap.pending_disk_in_bytes(),
            disk_out_bytes=self.swap.pending_disk_out_bytes(),
            disk_bw=self.kv.disk_link.bw_bytes_s,
            disk_latency_s=self.kv.disk_link.latency_s,
            peer_in_bytes=self.swap.pending_peer_in_bytes(),
            peer_out_bytes=self.swap.pending_peer_out_bytes(),
            peer_bw=self.kv.peer_link.bw_bytes_s,
            peer_latency_s=self.kv.peer_link.latency_s)

    def _autotune_interval(self) -> None:
        """§5 online stage: let the tuner re-pick the interval for this
        iteration; on an executor refusal, ban the interval and re-plan
        (bounded — the candidate set only shrinks)."""
        gauges = self._tuner_gauges()
        banned: set[int] = set()
        for _ in range(self.num_units + 2):
            target = self.tuner.propose(gauges, self.interval, banned=banned)
            if target == self.interval or self.set_interval(target):
                return
            banned.add(target)

    def submit(self, req: Request) -> None:
        req.submitted_s = self.clock_s
        self.scheduler.submit(req)

    def _active_batch(self) -> int:
        return int(self.active.sum())

    def _view(self) -> SchedulerView:
        active = [ActiveInfo(req, slot)
                  for slot, req in enumerate(self.slot_req)
                  if req is not None and self.active[slot]]
        free_slots = [i for i in range(self.ecfg.max_batch)
                      if self.slot_req[i] is None]
        return SchedulerView(interval=self.interval, free_slots=free_slots,
                             active=active)

    def _admit(self) -> IterationPlan:
        """Plan one iteration and apply everything but the decode step:
        preemption write-backs, resume promotions, admissions (one-shot
        prefill for non-chunked ones). Chunk compute is applied by ``step``
        so its time rides the decode iteration."""
        plan = self.scheduler.plan(self._view())
        if self.data_plane is not None:
            # iteration boundary for the copy-stage engine: complete last
            # iteration's background retirements, then execute every op the
            # plan just staged — BEFORE any same-plan prefill scatters into
            # frames those ops still read (transit-frame reuse)
            self.data_plane.drain()
        self.rejected.extend(plan.rejections)
        for req in plan.rejections:
            self.trace.event("reject", req.rid, self.clock_s,
                             reason=req.reject_reason)
        # data-plane order MUST follow planning order: resumes were planned
        # before preemptions, so a park's host destination may be the very
        # slot a resume promotion vacated — the resume must read its host
        # bytes before the park overwrites them
        self._apply_resumes(plan.resumes)
        self._apply_preemptions(plan.preemptions)
        for adm in plan.admissions:
            adm.req.admitted_s = self.clock_s
            self.trace.event("admit", adm.req.rid, self.clock_s,
                             slot=adm.slot, chunked=adm.chunked,
                             certified_ttft_s=adm.certified_ttft_s)
            if adm.chunked:
                adm.req.state = State.PREFILLING
                adm.req.slot = adm.slot
                self.slot_req[adm.slot] = adm.req
            else:
                self._prefill_into_slot(adm.req, adm.slot)
        return plan

    def _apply_preemptions(self, items: list[PlannedPreemption]) -> None:
        """Park victims: copy their device-resident pages into the host
        slots the scheduler claimed (BEFORE anything re-writes the freed
        frames — admissions in the same plan may reuse them), snapshot the
        decode cursor for a token-exact resume, and vacate the slot. The
        write-back bytes were charged by the scheduler
        (``swap.note_demotions``) and land on this iteration's link."""
        for it in items:
            req, slot = it.req, it.slot
            if it.migrations and self.kv.park_copy is None:
                # with a disk tier the parked bytes already moved in
                # planning order (see _park_page_copy)
                assert self.host_pool is not None
                ops.copy_pages_to_host(self.pool,
                                       [m.src_page for m in it.migrations],
                                       self.host_pool,
                                       [m.dst_page for m in it.migrations])
            req.state = State.PREEMPTED
            req.preempt_count += 1
            req.parked_at_s = self.clock_s
            self.trace.event("park", req.rid, self.clock_s, slot=slot)
            req.next_token = int(self.tokens[slot])
            req.resume_pos = int(self.pos[slot])
            req.slot = -1
            self.active[slot] = False
            self.slot_req[slot] = None

    def _apply_resumes(self, items: list[PlannedResume]) -> None:
        """Un-park: copy promoted pages back into their device frames and
        restore the decode cursor exactly where preemption snapshot it —
        the next decode step continues the token stream bit-for-bit.
        Promotion bytes were charged by the scheduler
        (``swap.note_promotions``)."""
        for it in items:
            req, slot = it.req, it.slot
            if it.migrations and self.kv.promote_copy is None:
                # with a disk tier the promotion bytes already moved in
                # planning order (see _promote_page_copy); copying again
                # here would re-read transit frames later stagings reused
                assert self.host_pool is not None
                self.pool = ops.copy_pages_from_host(
                    self.host_pool, [m.src_page for m in it.migrations],
                    self.pool, [m.dst_page for m in it.migrations])
            req.state = State.DECODING
            if req.parked_at_s is not None:
                req.preempt_stall_s += self.clock_s - req.parked_at_s
                req.parked_at_s = None
            self.trace.event("resume", req.rid, self.clock_s, slot=slot)
            req.slot = slot
            self.slot_req[slot] = req
            self.tokens[slot] = req.next_token
            self.pos[slot] = req.resume_pos
            self.active[slot] = True

    def _set_pool(self, pool) -> None:
        """Device-pool setter for the copy-stage engine (the pool is
        functional JAX state, reassigned per scatter)."""
        self.pool = pool

    def _guard_host_writes(self, frames) -> None:
        """Engine-side host-pool writes must wait out any in-flight
        background disk retirement still reading those frames."""
        if self.data_plane is not None:
            self.data_plane.guard_host_writes(frames)

    def _issue_prefetch(self) -> None:
        """Async mode: stage the oldest parked request's disk pages into
        FREE host frames ahead of its scheduler-predicted resume (parked
        requests re-enter oldest-first, so the queue head is the next
        resume candidate). The ops queue now and drain at the next
        iteration boundary; the NVMe reads ride the next iteration's disk
        term through the allocator's pending counters — by the time the
        resume is planned, its staging is already host-resident and its
        shortfall shrinks accordingly."""
        if (self.data_plane is None or not self.ecfg.async_data_plane
                or not self.scheduler.preempted):
            return
        req = self.scheduler.preempted[0]
        depth = min(self.kv.host.free_pages,
                    max(self.ecfg.prefetch_pages_per_boundary, 1))
        if depth <= 0:
            return
        self.prefetch_pages_total += self.kv.prefetch_from_disk(req.rid,
                                                                depth)

    # ------------------------------------------- cross-instance migration --
    def export_parked_request(self, rid: int) -> tuple[Request,
                                                       MigrationTicket] | None:
        """Serialize a parked request for cross-instance preemption: its
        host frames (payload copy, token order) plus the decode-cursor
        snapshot the park took — everything a peer instance needs to resume
        it bitwise-exactly. On success the request leaves this instance's
        books entirely (scheduler preempted set + allocator frames); the
        ticket bytes are charged to this instance's clock by the fleet when
        the transfer is modeled. None (nothing changed) when the request is
        not an exportable shape — disk-demoted pages, a held COW reserve,
        or not parked here at all."""
        pages = self.kv.export_parked(rid)
        if pages is None:
            return None
        req = self.scheduler.take_preempted(rid)
        if req is None:
            return None
        if req.parked_at_s is not None:
            # close the source-side park stall; the destination opens its
            # own segment at adoption time
            req.preempt_stall_s += self.clock_s - req.parked_at_s
            req.parked_at_s = None
        assert self.host_pool is not None
        self._guard_host_writes(pages)
        ticket = MigrationTicket(
            rid=rid, n_pages=len(pages), page_bytes=self.kv.page_bytes,
            payload=np.stack([np.asarray(self.host_pool[p])
                              for p in pages]),
            next_token=req.next_token, resume_pos=req.resume_pos)
        self.kv.free(rid)
        self.mig_out_bytes_total += ticket.bytes_total
        self.pending_mig_out_bytes += ticket.bytes_total
        self.n_migrated_out += 1
        self.trace.event("migrate_out", rid, self.clock_s,
                         n_pages=ticket.n_pages)
        return req, ticket

    def import_parked_request(self, req: Request,
                              ticket: MigrationTicket) -> bool:
        """Adopt a request migrated in from a peer: claim private host
        frames, land the ticket payload, and park it in the scheduler's
        preempted set — it resumes through the ordinary priority path,
        token-exactly, from the carried cursor snapshot. False (nothing
        claimed) when the host tier cannot absorb the set."""
        assert ticket.page_bytes == self.kv.page_bytes, \
            "migration between incompatible page geometries"
        pages = self.kv.import_parked(req.rid, ticket.n_pages)
        if pages is None:
            return False
        assert self.host_pool is not None
        self._guard_host_writes(pages)
        for hp, frame in zip(pages, ticket.payload):
            self.host_pool[hp] = np.asarray(frame)
        req.state = State.PREEMPTED
        req.slot = -1
        req.parked_at_s = self.clock_s
        self.scheduler.adopt_parked(req)
        self.mig_in_bytes_total += ticket.bytes_total
        self.pending_mig_in_bytes += ticket.bytes_total
        self.n_migrated_in += 1
        self.trace.event("migrate_in", req.rid, self.clock_s,
                         n_pages=ticket.n_pages)
        return True

    # ---------------------------------------------- post-prefill KV handoff --
    def export_handoff(self, rid: int) -> tuple[Request,
                                                MigrationTicket] | None:
        """Serialize a parked post-prefill request for live handoff to a
        decode instance. Mechanically this is ``export_parked_request`` —
        same payload snapshot, same bitwise cursor — but the transfer is
        charged to the PEER tier's own link term instead of a synchronous
        migration stall: the exported pages drain into this instance's next
        iteration's ``peer_s`` (overlapping its next prefill), and the
        importer charges its own side symmetrically after certifying."""
        pages = self.kv.export_parked(rid)
        if pages is None:
            return None
        req = self.scheduler.take_preempted(rid)
        if req is None:
            return None
        if req.parked_at_s is not None:
            req.preempt_stall_s += self.clock_s - req.parked_at_s
            req.parked_at_s = None
        assert self.host_pool is not None
        payload = np.empty((len(pages), *self.host_pool.shape[1:]),
                           self.host_pool.dtype)
        if self.data_plane is not None:
            self.data_plane.peer_export(pages, payload)
        else:
            for i, p in enumerate(pages):
                payload[i] = self.host_pool[p]
        ticket = MigrationTicket(
            rid=rid, n_pages=len(pages), page_bytes=self.kv.page_bytes,
            payload=payload, next_token=req.next_token,
            resume_pos=req.resume_pos, kind="handoff")
        self.kv.free(rid)
        self.kv.note_peer_export(ticket.n_pages)
        self.handoff_out_bytes_total += ticket.bytes_total
        self.n_handoff_out += 1
        self.trace.event("handoff_out", rid, self.clock_s,
                         n_pages=ticket.n_pages)
        return req, ticket

    def import_handoff(self, req: Request, ticket: MigrationTicket) -> bool:
        """Adopt a handed-off post-prefill request: certify the peer
        transfer against the live population's tightest TPOT budget (the
        scheduler's peer-extended feasibility term), claim private host
        frames, land the payload, and park the request into the ordinary
        resume path. False (nothing claimed, exporter must roll back) when
        the transfer cannot be certified or the host tier cannot absorb
        the page set."""
        assert ticket.kind == "handoff", ticket.kind
        assert ticket.page_bytes == self.kv.page_bytes, \
            "handoff between incompatible page geometries"
        active = [ActiveInfo(r, s) for s, r in enumerate(self.slot_req)
                  if r is not None and self.active[s]]
        if not self.scheduler.certify_handoff(ticket.n_pages,
                                              req.tpot_slo_s, active):
            return False
        pages = self.kv.import_parked(req.rid, ticket.n_pages)
        if pages is None:
            return False
        assert self.host_pool is not None
        if self.data_plane is not None:
            self.data_plane.peer_import(ticket.payload, pages)
        else:
            for hp, frame in zip(pages, ticket.payload):
                self.host_pool[hp] = np.asarray(frame)
        req.state = State.PREEMPTED
        req.slot = -1
        req.parked_at_s = self.clock_s
        self.scheduler.adopt_parked(req)
        self.kv.note_peer_import(ticket.n_pages)
        self.handoff_in_bytes_total += ticket.bytes_total
        self.n_handoff_in += 1
        self.trace.event("handoff_in", req.rid, self.clock_s,
                         n_pages=ticket.n_pages)
        return True

    def park_for_handoff(self, rid: int) -> bool:
        """Prefill-role instances: park a freshly prefilled request so its
        KV becomes the host-resident, cursor-snapshotted shape a
        ``MigrationTicket`` exports. Same mechanics (and the same d2h
        write-back charge) as a scheduler-planned preemption, just forced
        at the post-prefill boundary instead of under admission pressure.
        False when the host tier cannot absorb the park — the request then
        simply keeps its slot and decodes locally (graceful fallback)."""
        slot = next((s for s, r in enumerate(self.slot_req)
                     if r is not None and r.rid == rid), None)
        if slot is None or not self.active[slot]:
            return False
        req = self.slot_req[slot]
        others = [r.rid for s, r in enumerate(self.slot_req)
                  if r is not None and self.active[s] and r.rid != rid]
        moves = self.kv.park(rid, others)
        if moves is None:
            return False
        self.swap.note_demotions(len(moves))
        self.scheduler.stats["preemptions"] += 1
        self.scheduler.preempted.append(req)
        if moves and self.kv.park_copy is None:
            assert self.host_pool is not None
            ops.copy_pages_to_host(self.pool,
                                   [m.src_page for m in moves],
                                   self.host_pool,
                                   [m.dst_page for m in moves])
        req.state = State.PREEMPTED
        req.preempt_count += 1
        req.parked_at_s = self.clock_s
        self.trace.event("park", req.rid, self.clock_s, slot=slot)
        req.next_token = int(self.tokens[slot])
        req.resume_pos = int(self.pos[slot])
        req.slot = -1
        self.active[slot] = False
        self.slot_req[slot] = None
        return True

    def rollback_handoff(self, req: Request,
                         ticket: MigrationTicket) -> None:
        """Refused handoff: the destination certified nothing and claimed
        nothing, so the exporter re-adopts the request in place and cancels
        the export accounting — no peer bytes crossed the link in either
        direction, and the conservation audit sees a net zero."""
        pages = self.kv.import_parked(req.rid, ticket.n_pages)
        assert pages is not None, \
            "exporter must be able to re-claim the frames it just freed"
        assert self.host_pool is not None
        self._guard_host_writes(pages)
        for hp, frame in zip(pages, ticket.payload):
            self.host_pool[hp] = np.asarray(frame)
        req.state = State.PREEMPTED
        req.slot = -1
        req.parked_at_s = self.clock_s
        self.scheduler.adopt_parked(req)
        assert self.kv.pending_peer_out_pages >= ticket.n_pages, \
            "rollback after the export already drained into an iteration"
        self.kv.pending_peer_out_pages -= ticket.n_pages
        self.kv.peer_out_pages_total -= ticket.n_pages
        self.handoff_out_bytes_total -= ticket.bytes_total
        self.n_handoff_out -= 1
        self.trace.event("handoff_rollback", req.rid, self.clock_s,
                         n_pages=ticket.n_pages)

    def _disk_page_copy(self, src_tier: str, src_page: int,
                        dst_tier: str, dst_page: int) -> None:
        """NVMe data plane (TieredKVAllocator.disk_copy hook): fired by the
        allocator the moment a host<->disk accounting move lands. Staged
        through the copy-stage engine — executed at once in sync mode,
        queued in planning order otherwise. Byte traffic is charged to the
        disk link's own latency term via the allocator's pending disk
        counters — never to PCIe."""
        assert self.data_plane is not None
        if src_tier == HOST and dst_tier == DISK:
            self.data_plane.stage("h2disk", src_page, dst_page)
        elif src_tier == DISK and dst_tier == HOST:
            self.data_plane.stage("disk2h", src_page, dst_page)
        else:
            raise ValueError(f"disk copy between {src_tier} and {dst_tier}")

    def _direct_page_copy(self, src_tier: str, src_page: int,
                          dst_tier: str, dst_page: int) -> None:
        """Direct disk<->device staging (TieredKVAllocator.direct_copy
        hook): the page bypasses the host bounce buffer entirely, so no
        host-transit bytes are moved — or billed to the PCIe link."""
        assert self.data_plane is not None
        if src_tier == DISK and dst_tier == DEVICE:
            self.data_plane.stage("disk2d", src_page, dst_page)
        elif src_tier == DEVICE and dst_tier == DISK:
            self.data_plane.stage("d2disk", src_page, dst_page)
        else:
            raise ValueError(
                f"direct copy between {src_tier} and {dst_tier}")

    def _park_page_copy(self, src_dev_frame: int,
                        dst_host_page: int) -> None:
        """d2h leg of a park (TieredKVAllocator.park_copy hook, wired with
        the disk tier): staged in planning order so a demotion planned
        later in the SAME pass reads the parked bytes, not the host
        frame's previous content. ``_apply_preemptions`` skips its
        apply-time batch copy when this hook is wired."""
        self.data_plane.stage("d2h", src_dev_frame, dst_host_page)

    def _promote_page_copy(self, src_host_page: int,
                           dst_dev_frame: int) -> None:
        """h2d leg of a disk-staged resume (TieredKVAllocator.promote_copy
        hook): staged in planning order so a host transit frame is read
        before the next NVMe staging reuses it. ``_apply_resumes`` skips
        its apply-time batch copy when this hook is wired — the bytes
        already moved (or sit queued ahead of the reuse)."""
        self.data_plane.stage("h2d", src_host_page, dst_dev_frame)

    def _trace_footer(self) -> dict:
        """Counters snapshot the trace auditor cross-checks whole-trace
        conservation against (allocator + swap-scheduler cumulative totals
        minus what is still pending at export time)."""
        plane = self.data_plane
        return {
            "page_bytes": self.kv.page_bytes,
            "clock_s": self.clock_s,
            "staged_issued_pages_total":
                plane.issued_pages_total if plane else 0,
            "staged_completed_pages_total":
                plane.completed_pages_total if plane else 0,
            "staged_inflight_pages": plane.inflight_pages() if plane else 0,
            "disk_direct_pages_total": self.kv.disk_direct_pages_total,
            "prefetch_pages_total": self.prefetch_pages_total,
            "disk_in_pages_total": self.kv.disk_in_pages_total,
            "disk_out_pages_total": self.kv.disk_out_pages_total,
            "pending_disk_in_pages": self.kv.pending_disk_in_pages,
            "pending_disk_out_pages": self.kv.pending_disk_out_pages,
            "noted_in_pages_total": self.swap.in_pages_noted_total,
            "noted_out_pages_total": self.swap.out_pages_noted_total,
            "pending_in_pages": self.swap._pending_in_pages,
            "pending_out_pages": self.swap._pending_out_pages,
            "promoted_pages_total": self.swap.promoted_pages_total,
            "cow_in_bytes_total": self.cow_in_bytes_total,
            "cow_out_bytes_total": self.cow_out_bytes_total,
            "interval_refusals_total": self.interval_refusals,
            "interval_switches_total": self.interval_switches,
            "idle_wait_total_s": self.idle_wait_total_s,
            "mig_in_bytes_total": self.mig_in_bytes_total,
            "mig_out_bytes_total": self.mig_out_bytes_total,
            "pending_mig_in_bytes": self.pending_mig_in_bytes,
            "pending_mig_out_bytes": self.pending_mig_out_bytes,
            "mig_wait_total_s": self.mig_wait_total_s,
            "pending_mig_wait_s": self.mig_wait_s,
            "n_migrated_in": self.n_migrated_in,
            "n_migrated_out": self.n_migrated_out,
            "peer_in_pages_total": self.kv.peer_in_pages_total,
            "peer_out_pages_total": self.kv.peer_out_pages_total,
            "pending_peer_in_pages": self.kv.pending_peer_in_pages,
            "pending_peer_out_pages": self.kv.pending_peer_out_pages,
            "handoff_in_bytes_total": self.handoff_in_bytes_total,
            "handoff_out_bytes_total": self.handoff_out_bytes_total,
            "n_handoff_in": self.n_handoff_in,
            "n_handoff_out": self.n_handoff_out,
            "n_finished": len(self.finished),
            "n_rejected": len(self.rejected),
            "n_active": sum(1 for r in self.slot_req if r is not None),
            "n_parked": len(self.scheduler.preempted),
        }

    def _modeled_ttft(self, req: Request, host_spill_bytes: float) -> float:
        """Prefill latency: the spilled KV prefix is written back (d2h)
        through the link the weight prefetches share."""
        times = self.times_fn(1, req.prompt_len, "prefill")
        pre_i = max(self.rec["prefill"].lookup(req.ttft_slo_s, 1,
                                               req.prompt_len), 1)
        return iter_time_with_interval_kv(times, min(pre_i, NO_OFFLOAD),
                                          0.0, host_spill_bytes)

    # -------------------------------------------------------------- prefill --
    def _jitted_prefill(self, tokens: np.ndarray, cache_len: int):
        """Run the offload-aware jitted prefill over ``tokens``, shape-
        bucketed to one compiled length (``max_seq``). Suffix padding is
        causally inert — masked softmax terms contribute exact 0.0 and the
        running-max flash combine is a no-op over all-masked chunks — so a
        prefix's KV bits are identical no matter the prompt length it was
        computed under. That makes content-addressed KV reuse (prefix dedup,
        host prefix cache, cross-instance migration) bitwise-sound between
        requests of unequal length, and collapses prefill to a single
        compile per interval instead of one per distinct S."""
        rt = self._rt(self.interval)
        if self.interval not in self._jit_prefill:
            self._jit_prefill[self.interval] = jax.jit(
                rt.prefill, static_argnames=("cache_len",))
        bucket = self.ecfg.max_seq
        s = int(len(tokens))
        padded = np.zeros(bucket, np.int32)
        padded[:s] = np.asarray(tokens, np.int32)
        inputs = {"tokens": jnp.asarray(padded)[None]}
        return self._jit_prefill[self.interval](
            self._params_split[self.interval], inputs, cache_len=bucket,
            last_pos=jnp.int32(s - 1))

    def _prefill_into_slot(self, req: Request, slot: int) -> None:
        req.state = State.PREFILLING
        req.slot = slot
        self.slot_req[slot] = req
        # prefill this request alone (chunked prefill routes through
        # _run_chunks instead; the paper separates phases). The prefill is
        # shape-bucketed to max_seq (see _jitted_prefill); the page scatter
        # slices the merged caches back to the true prompt length.
        logits, caches1, _ = self._jitted_prefill(req.prompt, req.prompt_len)
        req.prefill_pos = req.prompt_len
        self.prefill_tokens_computed += req.prompt_len
        self._scatter_prefill_kv(req, caches1)
        # modeled prefill latency = TTFT (same formula admission checked):
        # only freshly spilled pages cost write-back — dedup'd host pages
        # are already resident
        ttft = self._modeled_ttft(req, self.kv.spill_writeback_bytes_of(
            req.rid))
        req.ttft_s = ttft
        self.trace.event("prefill", req.rid, self.clock_s, slot=slot,
                         dur_s=ttft)
        self.clock_s += ttft

        logits_np = np.asarray(logits[0], np.float32)
        self.prefill_log.append((req, slot, logits_np))
        tok = int(np.argmax(logits_np))
        req.generated.append(tok)
        if req.done:
            # token budget exhausted at prefill (max_new_tokens <= 1): never
            # activate the slot — a decode step would write past the
            # allocated pages (into the shared null frame) and over-generate
            req.state = State.FINISHED
            self.finished.append(req)
            self.slot_req[slot] = None
            self.kv.free(req.rid)
            self.trace.event("finish", req.rid, self.clock_s, slot=slot,
                             at_prefill=True)
            return
        self.tokens[slot] = tok
        self.pos[slot] = req.prompt_len
        self.active[slot] = True
        req.state = State.DECODING

    def _scatter_prefill_kv(self, req: Request, caches1: Any,
                            n_tokens: int | None = None,
                            start_page: int = 0) -> None:
        """Land the prefilled KV in the page pools: device-tier pages go into
        the physical pool via one batched scatter, host-tier (spilled cold
        prefix) pages go straight into the pinned-host buffer. Pages the
        allocator mapped onto existing frames (prefix dedup) already hold
        this exact KV — scattering into them would clobber a sibling's live
        page, so they are skipped (that skip is the dedup bandwidth win).
        A chunked prefill passes ``n_tokens`` (the chunk's end position) and
        ``start_page``: only pages the chunk completed or started are
        written — earlier pages already landed with earlier chunks."""
        if n_tokens is None:
            n_tokens = req.prompt_len
        rt = self._rt(self.interval)
        merged = merge_stacked(caches1, rt.plan)   # per pattern j: [R,1,S,..]
        # global layer order: unit-major, pattern-minor (u * P + j)
        shape = (self.cfg.num_layers, n_tokens, *self.page_shape[3:])
        k_all = np.stack([np.asarray(m["self"]["k"])[:, 0, :n_tokens]
                          for m in merged], axis=1).reshape(shape)
        v_all = np.stack([np.asarray(m["self"]["v"])[:, 0, :n_tokens]
                          for m in merged], axis=1).reshape(shape)
        vals = ops.pack_token_pages(k_all, v_all, self.ecfg.page_size,
                                    dtype=jnp.bfloat16)
        refs = self.kv.refs(req.rid)
        deduped = set(self.kv.dedup_hit_pages(req.rid))
        self._guard_host_writes(
            [refs[i].page for i in range(start_page, min(vals.shape[0],
                                                         len(refs)))
             if i not in deduped and refs[i].tier == HOST])
        dev_frames, dev_vals = [], []
        for i in range(start_page, vals.shape[0]):
            if i in deduped:
                continue
            r = refs[i]
            if r.tier == DEVICE:
                assert r.page < self.dev_cap, "LIFO high-water bound violated"
                dev_frames.append(r.page)
                dev_vals.append(vals[i])
            else:
                # fresh allocations land on device or host only; disk-tier
                # hits were revived host-ward inside alloc (and are in the
                # deduped skip-set anyway)
                assert r.tier == HOST and self.host_pool is not None
                self.host_pool[r.page] = vals[i]
        if dev_frames:
            self.pool = ops.scatter_kv_pages(
                self.pool, jnp.asarray(dev_frames, jnp.int32),
                jnp.asarray(np.stack(dev_vals)))

    # ------------------------------------------------------- chunked prefill --
    def _prefill_seconds(self, tokens: int) -> float:
        """Modeled compute seconds of a prompt prefill up to ``tokens``
        (no-offload stack time; the weight stream already serves the decode
        iteration the chunk piggybacks on)."""
        if tokens <= 0:
            return 0.0
        return self.times_fn(1, tokens, "prefill").t_iter_no_offload_s

    def _run_chunk_incremental(self, ch: PrefillChunk) -> np.ndarray | None:
        """Incremental chunk compute: the chunk's C tokens run through the
        paged chunk-prefill kernel, attending the request's RESIDENT paged
        KV (earlier chunks' pages stay in the pool; host-tier pages stream
        through the slab and dirty write pages stream back) — O(C * prefix)
        work instead of the recompute path's O(end). Returns the chunk's
        last-position logits, or None to fall back to the recompute path
        (unsupported page placement or slab overflow)."""
        req = ch.req
        page = self.ecfg.page_size
        refs = self.kv.refs(req.rid)
        n_pages = -(-ch.end // page)
        if n_pages > len(refs) or n_pages > self.nb:
            return None
        bt = np.full((self.nb,), self.null_frame, np.int32)
        stream_src: list[int] = []
        stream_dst: list[int] = []
        writeback: list[tuple[int, int]] = []   # (host slot, slab frame)
        slab_next = self.slab_base
        for i in range(n_pages):
            r = refs[i]
            if r.tier == DEVICE:
                bt[i] = r.page
            elif r.tier == HOST:
                if slab_next >= self.null_frame:
                    return None           # slab overflow: recompute instead
                stream_src.append(r.page)
                stream_dst.append(slab_next)
                bt[i] = slab_next
                if i >= ch.start // page:   # chunk writes into this page
                    writeback.append((r.page, slab_next))
                slab_next += 1
            else:
                return None               # disk-resident page: recompute
        if stream_src:
            self.pool = ops.copy_pages_from_host(
                self.host_pool, stream_src, self.pool, stream_dst)
        c = ch.end - ch.start
        toks = np.arange(ch.start, ch.end)
        wf = bt[toks // page]
        wo = (toks % page).astype(np.int32)
        if self.interval not in self._jit_chunk:
            rt = self._rt(self.interval)
            self._jit_chunk[self.interval] = jax.jit(
                rt.paged_prefill_chunk, donate_argnums=(3,))
        logits, self.pool = self._jit_chunk[self.interval](
            self._params_split[self.interval],
            jnp.asarray(req.prompt[ch.start:ch.end], jnp.int32),
            jnp.int32(ch.start), self.pool, jnp.asarray(bt),
            jnp.int32(ch.end), jnp.asarray(wf), jnp.asarray(wo))
        if writeback:
            self._guard_host_writes([hp for hp, _ in writeback])
            got = np.asarray(ops.gather_kv_pages(
                self.pool, jnp.asarray([f for _, f in writeback],
                                       jnp.int32)))
            for (hp, _), val in zip(writeback, got):
                self.host_pool[hp] = val
        self.prefill_tokens_computed += c
        return np.asarray(logits[0], np.float32)

    def _run_chunks(self, chunks: list[PrefillChunk]
                    ) -> tuple[float, list[tuple[PrefillChunk, np.ndarray]]]:
        """Compute + scatter this iteration's prefill chunks. By default
        the real compute recomputes the prefix (prefill over
        ``prompt[:end]`` — causal attention makes the chunk's KV
        bit-identical to a one-shot prefill, which is what keeps chunking
        numerically invisible) — quadratic real work across the schedule.
        With ``incremental_prefill`` the chunk kernel attends only the new
        queries against resident paged KV, making real compute match the
        *modeled* chunk cost: the incremental stack time T(end) - T(start),
        charged on top of the decode iteration it rides.
        Returns (modeled chunk seconds, final-chunk logits)."""
        t = 0.0
        finals: list[tuple[PrefillChunk, np.ndarray]] = []
        for ch in chunks:
            req = ch.req
            page = self.ecfg.page_size
            logits_np = None
            if self.ecfg.incremental_prefill:
                logits_np = self._run_chunk_incremental(ch)
            if logits_np is None:
                logits, caches1, _ = self._jitted_prefill(
                    req.prompt[:ch.end], ch.end)
                self._scatter_prefill_kv(req, caches1, n_tokens=ch.end,
                                         start_page=ch.start // page)
                self.prefill_tokens_computed += ch.end
                logits_np = np.asarray(logits[0], np.float32)
            # a chunk that lands on spilled (fresh host-tier) pages writes
            # them over the same link as everything else: charge the d2h
            # bytes like the one-shot path does via _modeled_ttft. Dedup'd
            # host hits are already resident and cost nothing.
            refs = self.kv.refs(req.rid)
            deduped = set(self.kv.dedup_hit_pages(req.rid))
            n_host_written = sum(
                1 for i in range(ch.start // page, -(-ch.end // page))
                if i not in deduped and i < len(refs)
                and refs[i].tier == HOST)
            if n_host_written:
                # noted AFTER the scheduler stamped certified_dt: these
                # bytes surface as kv_out in excess of the plan's certified
                # total, which the trace auditor allows as serialization
                # slack on top of the certified bound
                self.swap.note_demotions(n_host_written)
            req.prefill_pos = ch.end
            inc = max(self._prefill_seconds(ch.end)
                      - self._prefill_seconds(ch.start), 0.0)
            t += inc
            self.trace.event("chunk", req.rid, self.clock_s, slot=ch.slot,
                             dur_s=inc, start=ch.start, end=ch.end,
                             final=ch.final)
            if ch.final:
                finals.append((ch, logits_np))
        return t, finals

    def _finish_chunks(self, chunks: list[PrefillChunk],
                       finals: list[tuple[PrefillChunk, np.ndarray]],
                       dt: float) -> list[int]:
        """Per-chunk TTFT accounting: every in-flight chunked prefill
        absorbs this iteration's latency; a final chunk closes TTFT, emits
        the request's first token, and activates the slot for the next
        decode step. Returns rids finished at prefill (token budget <= 1)."""
        done: list[int] = []
        for ch in chunks:
            ch.req.ttft_accum_s += dt
        for ch, logits_np in finals:
            req = ch.req
            req.ttft_s = req.ttft_accum_s
            self.prefill_log.append((req, ch.slot, logits_np))
            tok = int(np.argmax(logits_np))
            req.generated.append(tok)
            if req.done:
                # token budget exhausted at prefill: never activate the slot
                req.state = State.FINISHED
                self.finished.append(req)
                self.slot_req[ch.slot] = None
                self.kv.free(req.rid)
                done.append(req.rid)
                self.trace.event("finish", req.rid, self.clock_s,
                                 slot=ch.slot, at_prefill=True)
                continue
            self.tokens[ch.slot] = tok
            self.pos[ch.slot] = req.prompt_len
            self.active[ch.slot] = True
            req.state = State.DECODING
        return done

    # ---------------------------------------------------------------- decode --
    def _build_iteration_tables(self) -> tuple:
        """Per-iteration kernel inputs from the allocator refs: block tables
        and context lengths per slot, the new token's write frame/offset, the
        host pages to stream into the slab, and the dirty streamed page (if
        the write lands on a host-resident page) to write back afterwards."""
        b, nb, page = self.ecfg.max_batch, self.nb, self.ecfg.page_size
        bt = np.full((b, nb), self.null_frame, np.int32)
        cl = np.zeros((b,), np.int32)
        wf = np.full((b,), self.null_frame, np.int32)
        wo = np.zeros((b,), np.int32)
        stream_src: list[int] = []      # host pool slots
        stream_dst: list[int] = []      # slab frames
        writeback: list[tuple[int, int]] = []   # (host slot, slab frame)
        slab_of: dict[int, int] = {}    # host slot -> slab frame (dedup:
        slab_next = self.slab_base      # a shared host page streams ONCE)
        for slot in range(b):
            req = self.slot_req[slot]
            if not self.active[slot] or req is None:
                continue
            refs = self.kv.refs(req.rid)
            assert len(refs) <= nb
            for i, r in enumerate(refs):
                if r.tier == DEVICE:
                    assert r.page < self.dev_cap, \
                        "LIFO high-water bound violated"
                    bt[slot, i] = r.page
                else:
                    # only host pages stream through the slab: an ACTIVE
                    # request must never hold disk-tier pages (resume
                    # stages disk->host before the slot re-activates)
                    assert r.tier == HOST, \
                        f"active rid {req.rid} holds a {r.tier} page"
                    if r.page not in slab_of:
                        slab_of[r.page] = slab_next
                        stream_src.append(r.page)
                        stream_dst.append(slab_next)
                        slab_next += 1
                    bt[slot, i] = slab_of[r.page]
            p = int(self.pos[slot])
            cl[slot] = p + 1                    # includes the token written now
            wpi = p // page
            wf[slot] = bt[slot, wpi]
            wo[slot] = p % page
            if wf[slot] >= self.slab_base and wf[slot] != self.null_frame:
                # decode writes into a streamed (host-resident) page: the
                # dirty slab frame must be written back or the token is lost
                writeback.append((refs[wpi].page, int(wf[slot])))
        assert slab_next <= self.null_frame
        return bt, cl, wf, wo, stream_src, stream_dst, writeback

    def _resolve_cow_writes(self) -> tuple[float, float]:
        """Copy-on-write pre-pass: before the decode kernel writes this
        iteration's token KV, every slot whose write page is still shared
        moves onto its pre-claimed private frame (``kv.prepare_write``), and
        the page bytes follow through the data plane. Runs after promotions
        (so the moves see final tiers) and before the block tables are
        built. A sibling's page bytes are never touched — that is the
        property the kernel-level COW tests pin down.

        Returns (h2d_bytes, d2h_bytes) of the CROSS-TIER copies so the
        caller charges them to this iteration's link budget — same-pool
        copies never touch the host link and cost nothing in the SLO
        model."""
        page = self.ecfg.page_size
        moves = []
        for slot in range(self.ecfg.max_batch):
            req = self.slot_req[slot]
            if not self.active[slot] or req is None:
                continue
            moves.extend(self.kv.prepare_write(req.rid,
                                               int(self.pos[slot]) // page))
        if not moves:
            return 0.0, 0.0
        self.cow_events += len(moves)
        self._guard_host_writes([m.dst.page for m in moves
                                 if m.dst.tier == HOST])
        cow_in = cow_out = 0.0
        dd_src: list[int] = []
        dd_dst: list[int] = []
        for m in moves:
            src, dst = m.src, m.dst
            if src.tier == DEVICE and dst.tier == DEVICE:
                dd_src.append(src.page)
                dd_dst.append(dst.page)
            elif src.tier == HOST and dst.tier == HOST:
                self.host_pool[dst.page] = self.host_pool[src.page]
            elif src.tier == HOST:
                self.pool = ops.copy_pages_from_host(
                    self.host_pool, [src.page], self.pool, [dst.page])
                cow_in += self.kv.page_bytes
            else:
                ops.copy_pages_to_host(self.pool, [src.page],
                                       self.host_pool, [dst.page])
                cow_out += self.kv.page_bytes
        if dd_src:
            self.pool = ops.copy_pages_on_device(
                self.pool, jnp.asarray(dd_src, jnp.int32),
                jnp.asarray(dd_dst, jnp.int32))
        return cow_in, cow_out

    def step(self, peers: list["ServingEngine"] | None = None,
             link_bw: float | None = None) -> None:
        """One inference iteration: coordinate -> plan -> apply (preempt /
        resume / admit / chunk) -> decode all active slots -> report the
        outcome to the scheduler."""
        self.prefill_log = []
        self.last_decode = None
        t_start = self.clock_s
        idle_wait = self.idle_wait_s
        self.idle_wait_s = 0.0
        mig_wait = self.mig_wait_s
        mig_in_b = self.pending_mig_in_bytes
        mig_out_b = self.pending_mig_out_bytes
        self.mig_wait_s = 0.0
        self.pending_mig_in_bytes = 0.0
        self.pending_mig_out_bytes = 0.0
        if peers is not None and link_bw is not None:
            engines = [self] + list(peers)
            insts = [e.instance_state() for e in engines]
            # bounded re-plan: an executor may refuse its assignment (host
            # pool cannot absorb the demoted KV) — clamp that instance's
            # ceiling to the interval it actually holds and coordinate
            # again, instead of silently running a plan nobody applied
            for _ in range(len(engines) + 1):
                res = coordinate(insts, link_bw)
                if not res.ok:
                    break
                refused = False
                for eng, inst in zip(engines, insts):
                    if not eng.set_interval(res.intervals[eng.name]):
                        inst.max_interval = min(inst.max_interval,
                                                eng.interval)
                        refused = True
                if not refused:
                    break
        elif self.tuner is not None:
            self._autotune_interval()
        elif self.interval == 0:
            self.set_interval(NO_OFFLOAD)

        fin0 = len(self.finished)
        plan = self._admit()
        # one-shot prefills emit a first token each and may finish their
        # request outright (token budget <= 1): count them in the outcome
        # like the chunked finals are counted
        prefill_tokens = sum(1 for adm in plan.admissions if not adm.chunked)
        prefill_finished = [r.rid for r in self.finished[fin0:]]
        # the applied plan must agree with the executor's resulting state —
        # the typed contract is checked, not decorative
        assert plan.target_interval == self.interval, \
            "plan was built against a stale interval"
        assert plan.decode_slots == [s for s in range(self.ecfg.max_batch)
                                     if self.active[s]], \
            "scheduler decode_slots diverge from executor slot state"
        self.host_kv_peak_pages = max(self.host_kv_peak_pages,
                                      self.kv.host.used_pages)
        self.device_pages_peak = max(self.device_pages_peak,
                                     self.kv.device.used_pages)
        self.disk_kv_peak_pages = max(self.disk_kv_peak_pages,
                                      self.kv.disk.used_pages)
        if self.role == "prefill" and self.scheduler.hold_resumes:
            # disaggregated prefill instance: every freshly prefilled
            # request parks here BEFORE any decode runs — its first token
            # (TTFT) was charged by the prefill; decode belongs to the
            # peer the fleet hands it to at the next boundary. Gated on
            # hold_resumes: once the fleet's drained-flush releases the
            # staging set (no peer ever certified), resumed requests must
            # decode here instead of bouncing resume -> re-park forever
            for slot in range(self.ecfg.max_batch):
                req = self.slot_req[slot]
                if (req is not None and self.active[slot]
                        and req.state is State.DECODING):
                    self.park_for_handoff(req.rid)
        chunk_s, finals = self._run_chunks(plan.chunks)
        if self._active_batch() == 0:
            # no decode this iteration; chunk compute still advances the
            # clock and the chunked requests' TTFT accrual
            if plan.chunks:
                self.clock_s += chunk_s
                done = self._finish_chunks(plan.chunks, finals, chunk_s)
                dt_rec, finished = chunk_s, prefill_finished + done
                self.scheduler.note_outcome(IterationOutcome(
                    dt_s=chunk_s, finished_rids=finished,
                    tokens_emitted=prefill_tokens + len(finals),
                    chunks_run=len(plan.chunks),
                    preemptions=len(plan.preemptions),
                    resumes=len(plan.resumes)))
            else:
                dt_rec, finished = 0.0, prefill_finished
                self.scheduler.note_outcome(IterationOutcome(
                    dt_s=0.0, finished_rids=prefill_finished,
                    tokens_emitted=prefill_tokens,
                    preemptions=len(plan.preemptions),
                    resumes=len(plan.resumes)))
            self._issue_prefetch()
            st_issued, st_completed = (
                self.data_plane.take_iteration_counters()
                if self.data_plane else (0, 0))
            self.trace.add_iteration(IterationRecord(
                index=len(self.trace.iterations), t_start_s=t_start,
                t_end_s=self.clock_s, dt_s=dt_rec, interval=self.interval,
                decode_batch=0, n_chunks=len(plan.chunks),
                admitted=[a.req.rid for a in plan.admissions],
                rejected=[r.rid for r in plan.rejections],
                parked=[p.req.rid for p in plan.preemptions],
                resumed=[r.req.rid for r in plan.resumes],
                finished=finished, chunk_s=dt_rec,
                idle_wait_s=idle_wait, mig_wait_s=mig_wait,
                mig_in_bytes=mig_in_b, mig_out_bytes=mig_out_b,
                certified_dt_s=plan.certified_dt_s,
                staged_issued_pages=st_issued,
                staged_completed_pages=st_completed,
                occupancy=self.kv.occupancy(),
                reserve_pages=self.kv.n_reserve_frames()))
            return
        # KV tier activity of this iteration: promote host pages into freed
        # device frames, stream the rest in for attention, write back any
        # pending demotions (incl. preemption parks) and charge resume
        # promotions. Promotion is never a traffic spike: a promoted page's
        # one-time copy replaces its recurring streamed copy.
        pend_in_b = self.swap.pending_in_bytes()
        pend_out_b = self.swap.pending_out_bytes()
        pdisk_in_pages = self.kv.pending_disk_in_pages
        pdisk_out_pages = self.kv.pending_disk_out_pages
        ppeer_in_pages = self.kv.pending_peer_in_pages
        ppeer_out_pages = self.kv.pending_peer_out_pages
        sp = self.swap.plan_iteration(self._active_rids())
        if sp.promotions:
            assert self.host_pool is not None
            self.pool = ops.copy_pages_from_host(
                self.host_pool, [m.src_page for m in sp.promotions],
                self.pool, [m.dst_page for m in sp.promotions])
        cow_in, cow_out = self._resolve_cow_writes()
        if cow_in or cow_out:
            # a cross-tier COW moved a write page between tiers, changing
            # which pages actually stream through the slab this iteration:
            # re-derive the streamed component from the post-COW refs so
            # the charged bytes equal the gathers the tables will issue,
            # then add the one-off COW copies themselves
            streamed_now = self.swap.streamed_bytes(self._active_rids())
            sp.kv_in_bytes += streamed_now - sp.streamed_bytes
            sp.streamed_bytes = streamed_now
        sp.kv_in_bytes += cow_in
        sp.kv_out_bytes += cow_out
        self.cow_in_bytes_total += cow_in
        self.cow_out_bytes_total += cow_out
        # bytes the scheduler could not have certified at plan time: any
        # excess of actual PCIe traffic over the totals the certified-dt
        # stamp was derived from. This uniformly covers COW copies (and the
        # stream growth they cause), chunk host-spill write-backs, and pages
        # a same-plan one-shot prefill spilled to host that now stream into
        # this very decode.
        uncert_in = max(sp.kv_in_bytes - plan.certified_kv_in_bytes, 0.0)
        uncert_out = max(sp.kv_out_bytes - plan.certified_kv_out_bytes, 0.0)
        self._rt(self.interval)
        bt, cl, wf, wo, stream_src, stream_dst, writeback = \
            self._build_iteration_tables()
        if stream_src:
            self.streamed_pages_peak = max(self.streamed_pages_peak,
                                           len(stream_src))
            self.pool = ops.copy_pages_from_host(self.host_pool, stream_src,
                                                 self.pool, stream_dst)
        tokens_in, pos_in = self.tokens.copy(), self.pos.copy()
        fn = self._jit_decode[self.interval]
        logits, self.pool = fn(
            self._params_split[self.interval], jnp.asarray(tokens_in),
            jnp.asarray(pos_in), self.pool, jnp.asarray(bt), jnp.asarray(cl),
            jnp.asarray(wf), jnp.asarray(wo))
        logits = np.asarray(logits, np.float32)
        if writeback:
            self._guard_host_writes([hs for hs, _ in writeback])
            got = np.asarray(ops.gather_kv_pages(
                self.pool, jnp.asarray([f for _, f in writeback], jnp.int32)))
            for (host_slot, _), val in zip(writeback, got):
                self.host_pool[host_slot] = val
        self.last_decode = {"tokens": tokens_in, "pos": pos_in,
                            "active": self.active.copy(), "logits": logits}

        times = self.times_fn(self._active_batch(), self.ecfg.max_seq,
                              "decode")
        # piggybacked chunk compute rides the same iteration: its stack time
        # adds to the latency every active request pays this step; NVMe
        # traffic (park-to-disk demotions, resume stagings, cache revivals)
        # gets the disk link's own term — it never rides the PCIe budget
        bd = iter_time_breakdown_kv(
            times, self.interval, sp.kv_in_bytes, sp.kv_out_bytes,
            disk_in_bytes=sp.disk_in_bytes,
            disk_out_bytes=sp.disk_out_bytes,
            disk_bw=self.kv.disk_link.bw_bytes_s,
            disk_latency_s=self.kv.disk_link.latency_s,
            peer_in_bytes=sp.peer_in_bytes,
            peer_out_bytes=sp.peer_out_bytes,
            peer_bw=self.kv.peer_link.bw_bytes_s,
            peer_latency_s=self.kv.peer_link.latency_s)
        dt = bd.total_s + chunk_s
        self.clock_s += dt
        decode_reqs = [(slot, self.slot_req[slot])
                       for slot in range(self.ecfg.max_batch)
                       if self.active[slot]
                       and self.slot_req[slot] is not None]

        finished_rids: list[int] = list(prefill_finished)
        tokens_out = prefill_tokens
        for slot in range(self.ecfg.max_batch):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            if req is None:
                continue
            tok = int(np.argmax(logits[slot]))
            req.generated.append(tok)
            req.tpot_s.append(dt)
            tokens_out += 1
            self.tokens[slot] = tok
            self.pos[slot] += 1
            if req.done:
                req.state = State.FINISHED
                self.finished.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
                self.kv.free(req.rid)
                finished_rids.append(req.rid)
                self.trace.event("finish", req.rid, self.clock_s, slot=slot)
        finished_rids += self._finish_chunks(plan.chunks, finals, dt)
        tokens_out += len(finals)
        self.scheduler.note_outcome(IterationOutcome(
            dt_s=dt, finished_rids=finished_rids, tokens_emitted=tokens_out,
            chunks_run=len(plan.chunks), preemptions=len(plan.preemptions),
            resumes=len(plan.resumes)))
        self._issue_prefetch()
        st_issued, st_completed = (self.data_plane.take_iteration_counters()
                                   if self.data_plane else (0, 0))
        self.trace.add_iteration(IterationRecord(
            index=len(self.trace.iterations), t_start_s=t_start,
            t_end_s=self.clock_s, dt_s=dt, interval=self.interval,
            decode_batch=len(decode_reqs), n_chunks=len(plan.chunks),
            admitted=[a.req.rid for a in plan.admissions],
            rejected=[r.rid for r in plan.rejections],
            parked=[p.req.rid for p in plan.preemptions],
            resumed=[r.req.rid for r in plan.resumes],
            finished=finished_rids,
            kv_in_bytes=sp.kv_in_bytes, kv_out_bytes=sp.kv_out_bytes,
            streamed_bytes=sp.streamed_bytes,
            promoted_bytes=len(sp.promotions) * self.kv.page_bytes,
            pending_in_bytes=pend_in_b, pending_out_bytes=pend_out_b,
            cow_in_bytes=cow_in, cow_out_bytes=cow_out,
            uncertified_in_bytes=uncert_in,
            uncertified_out_bytes=uncert_out,
            certified_kv_in_bytes=plan.certified_kv_in_bytes,
            certified_kv_out_bytes=plan.certified_kv_out_bytes,
            disk_in_bytes=sp.disk_in_bytes,
            disk_out_bytes=sp.disk_out_bytes,
            disk_in_pages=pdisk_in_pages, disk_out_pages=pdisk_out_pages,
            peer_in_bytes=sp.peer_in_bytes,
            peer_out_bytes=sp.peer_out_bytes,
            peer_in_pages=ppeer_in_pages, peer_out_pages=ppeer_out_pages,
            compute_s=bd.compute_s, kv_in_s=bd.kv_in_s,
            kv_out_s=bd.kv_out_s, stall_s=bd.stall_s, pcie_s=bd.pcie_s,
            disk_s=bd.disk_s, peer_s=bd.peer_s, chunk_s=chunk_s,
            model_dt_s=bd.total_s,
            idle_wait_s=idle_wait, mig_wait_s=mig_wait,
            mig_in_bytes=mig_in_b, mig_out_bytes=mig_out_b,
            link_bw_bytes_s=link_bandwidth(times),
            certified_dt_s=plan.certified_dt_s,
            staged_issued_pages=st_issued,
            staged_completed_pages=st_completed,
            occupancy=self.kv.occupancy(),
            reserve_pages=self.kv.n_reserve_frames(),
            gauges=[SlotGauge(rid=req.rid, slot=slot,
                              tpot_slo_s=req.tpot_slo_s,
                              headroom_s=req.tpot_slo_s - dt)
                    for slot, req in decode_reqs]))

    def run(self, requests: list[Request], max_iters: int = 10_000,
            peers=None, link_bw=None, submit_all: bool = False) -> dict:
        """Serve ``requests`` to completion on the modeled clock.

        By default the arrival process is honored: a request stays invisible
        to the scheduler until ``clock_s`` reaches its ``arrival_s``, and
        when the engine drains before the next arrival, the idle wait
        advances the clock to it (stamped as ``idle_wait_s`` on the next
        iteration record so the trace still tiles). ``queue_delay_s`` is
        then measured from arrival, not from submission. ``submit_all=True``
        is the compat path: everything submits at the current clock exactly
        as before arrivals were honored (bitwise-identical traces for the
        differential suites; also the default behavior for traces whose
        ``arrival_s`` are all 0)."""
        if submit_all:
            pending: list[Request] = []
            for r in requests:
                self.submit(r)
        else:
            pending = sorted(requests, key=lambda r: r.arrival_s)
        it = 0
        n_pend = 0                     # consumed prefix of ``pending``
        while True:
            while n_pend < len(pending) \
                    and pending[n_pend].arrival_s <= self.clock_s:
                req = pending[n_pend]
                n_pend += 1
                self.submit(req)
                # queueing delay counts from the arrival process, not from
                # the iteration boundary the request became visible at
                req.submitted_s = max(req.arrival_s, 0.0)
            if not (self.scheduler.has_work() or self._active_batch() > 0):
                if n_pend >= len(pending):
                    break
                nxt = pending[n_pend].arrival_s
                if nxt > self.clock_s:          # idle: jump to next arrival
                    self.idle_wait_s += nxt - self.clock_s
                    self.idle_wait_total_s += nxt - self.clock_s
                    self.clock_s = nxt
                continue
            if it >= max_iters:
                break
            self.step(peers=peers, link_bw=link_bw)
            it += 1
        if self.data_plane is not None:
            # run boundary: every staged op must have physically landed
            # before anyone reads the pools or exports the trace footer
            self.data_plane.sync()
        done = [r.metrics() for r in self.finished]
        total_tokens = sum(m["tokens"] for m in done)
        delays = [m["queue_delay_s"] for m in done]
        st = self.scheduler.stats
        stalls = [m["preempt_stall_s"] for m in done]
        return {
            "finished": len(self.finished),
            "rejected": len(self.rejected),
            "tokens": total_tokens,
            "wall_modeled_s": self.clock_s,
            "throughput_tok_s": total_tokens / self.clock_s
            if self.clock_s > 0 else 0.0,
            "slo_ok": all(m["ttft_ok"] and m["tpot_ok"] for m in done),
            "preemptions": st["preemptions"],
            "resumes": st["resumes"],
            "disk_demotions": st["disk_demotions"],
            "disk_stagings": st["disk_stagings"],
            "handoffs_in": self.n_handoff_in,
            "handoffs_out": self.n_handoff_out,
            "prefetch_pages": self.prefetch_pages_total,
            "disk_direct_pages": self.kv.disk_direct_pages_total,
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "preempt_stall_max_s": max(stalls) if stalls else 0.0,
            "chunked_prefill_iters": st["chunked_prefill_iters"],
            "queue_delay_p99_s": summarize_latency(delays)["p99_s"],
            "queue_delay": summarize_latency(delays),
            "ttft": summarize_latency([m["ttft_s"] for m in done]),
            "tpot": summarize_latency([t for r in self.finished
                                       for t in r.tpot_s]),
            "link_bytes": self.trace.totals(),
            # arrival-process accounting: with arrivals honored, the first
            # admission can never precede the first arrival on the modeled
            # clock (fig19's harness claim); idle_wait_s is the drained-
            # engine time run() skipped to the next arrival
            "first_arrival_s": (min(r.arrival_s for r in requests)
                                if requests else None),
            "first_admit_s": min((e.t_s for e in self.trace.events
                                  if e.kind == "admit"), default=None),
            "idle_wait_s": self.idle_wait_total_s,
            "arrivals_honored": not submit_all,
            # interval policy telemetry (coordinator / online tuner)
            "interval_switches": self.interval_switches,
            "interval_refusals": self.interval_refusals,
            "autotune": ({"lifts": self.tuner.lifts,
                          "retreats": self.tuner.retreats,
                          "refusals": self.tuner.refusals}
                         if self.tuner is not None else None),
            "per_request": done,
        }
