"""SLO-aware serving engine with continuous batching and Select-N offloading.

One engine = one model instance (one TP group on real hardware). Per
iteration it: admits queued requests whose SLO is feasible (performance
record + memory bound, §4.2's admission check), prefills them into free
batch slots, runs one decode step for all active slots, and advances a
*modeled* clock (LayerTimes under the current offload plan — token flow is
real JAX compute; SLO timing is the deterministic analytic schedule, which on
a real TPU host would be wall clock).

The offloading interval is re-evaluated every iteration through the per-bus
coordinator when the engine shares a link with peers (§4.5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core.coordinator import (InstanceState, coordinate,
                                    max_interval_for_memory)
from repro.core.hardware import HardwareModel
from repro.core.interval import (LayerTimes, NO_OFFLOAD, OffloadPlan,
                                 iter_time_with_interval_kv)
from repro.core.memory_manager import (OffloadRuntime, split_model_params,
                                       split_stacked)
from repro.core.record import PerformanceRecord
from repro.models.model import Model
from repro.models.transformer import pattern_info
from repro.serving.kv_cache import PageConfig, PagedKVAllocator
from repro.serving.kv_offload import SwapScheduler, TieredKVAllocator
from repro.serving.request import Request, State


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 64
    hbm_budget_bytes: float = 16e9
    page_size: int = 16
    greedy: bool = True          # greedy sampling
    # Two-tier KV offloading (serving.kv_offload): pinned-host page pool
    # budget. 0 disables the host tier — admission then falls back to the
    # device-only behavior (wait for pages).
    host_kv_bytes: float = 0.0


class ServingEngine:
    def __init__(self, name: str, model: Model, hw: HardwareModel,
                 rec_prefill: PerformanceRecord, rec_decode: PerformanceRecord,
                 times_fn: Callable[[int, int, str], LayerTimes],
                 ecfg: EngineConfig = EngineConfig()):
        self.name = name
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.hw = hw
        self.rec = {"prefill": rec_prefill, "decode": rec_decode}
        self.times_fn = times_fn
        self.ecfg = ecfg
        _, self.num_units = pattern_info(self.cfg)
        self.unit_bytes = costs.unit_weight_bytes(self.cfg)

        self.params = model.init(jax.random.PRNGKey(0))
        self.clock_s = 0.0
        self.interval = NO_OFFLOAD
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.rejected: list[Request] = []

        # slot state
        b = ecfg.max_batch
        self.slot_req: list[Request | None] = [None] * b
        self.tokens = np.zeros((b,), np.int32)
        self.pos = np.zeros((b,), np.int32)
        self.active = np.zeros((b,), bool)

        kv_tok = max(costs.kv_cache_bytes(self.cfg, 1, 1,
                                          self.model.virtual_kv), 1)
        weight_free = (ecfg.hbm_budget_bytes
                       - OffloadPlan(self.num_units, NO_OFFLOAD)
                       .device_bytes(self.unit_bytes))
        self.kv = TieredKVAllocator(
            max(int(weight_free), 0), ecfg.host_kv_bytes,
            PageConfig(ecfg.page_size, bytes_per_token=kv_tok))
        self.swap = SwapScheduler(self.kv)
        self.host_kv_peak_pages = 0

        self._runtime: dict[int, OffloadRuntime] = {}
        self._jit_decode: dict[int, Any] = {}
        self._jit_prefill: dict[int, Any] = {}
        self._params_split: dict[int, Any] = {}
        self._caches: Any = None          # split layout for current interval

    # ------------------------------------------------------------------ plan --
    @property
    def allocator(self) -> PagedKVAllocator:
        """Device-tier page pool (back-compat accessor)."""
        return self.kv.device

    def _plan(self, interval: int) -> OffloadPlan:
        return OffloadPlan(self.num_units, interval)

    def set_interval(self, interval: int) -> None:
        """Apply a (possibly new) offloading interval before the next
        iteration (coordinator output). Re-splits params/caches lazily."""
        if interval == self.interval:
            return
        weight_free_new = (self.ecfg.hbm_budget_bytes
                           - self._plan(interval).device_bytes(self.unit_bytes))
        if not self.kv.can_resize_device(max(int(weight_free_new), 0)):
            # Growing the resident set would orphan live KV pages (host pool
            # cannot absorb the overflow): keep the current interval. The
            # coordinator path never gets here — max_interval_for_memory
            # already excludes such intervals.
            return
        old_rt = self._runtime.get(self.interval)
        if self._caches is not None and old_rt is not None:
            from repro.core.memory_manager import merge_model_params
            merged = merge_model_params({"blocks": self._caches},
                                        old_rt.plan)["blocks"]
            self._caches = split_stacked(merged, self._plan(interval))
        self.interval = interval
        # re-account KV budget: resident bytes changed. A shrinking device
        # pool demotes KV pages host-ward; the write-back bytes are charged
        # to the next iteration's link budget by the swap scheduler.
        demoted = self.kv.resize_device(max(int(weight_free_new), 0))
        if demoted:
            self.swap.note_demotions(demoted)

    def _rt(self, interval: int) -> OffloadRuntime:
        if interval not in self._runtime:
            rt = OffloadRuntime(model=self.model, plan=self._plan(interval))
            self._runtime[interval] = rt
            self._params_split[interval] = split_model_params(
                self.params, rt.plan)
            self._jit_decode[interval] = jax.jit(rt.decode_step)
        return self._runtime[interval]

    # ------------------------------------------------------------ admission --
    def _active_rids(self) -> list[int]:
        return [r.rid for r in self.slot_req if r is not None]

    def _min_active_tpot(self) -> float:
        slos = [r.tpot_slo_s for r in self.slot_req if r is not None]
        return min(slos) if slos else float("inf")

    def instance_state(self, idle: bool | None = None) -> InstanceState:
        waiting = self.queue[0] if self.queue else None
        if waiting is not None:
            seq = waiting.prompt_len + waiting.max_new_tokens
            min_i = self.rec["decode"].lookup(waiting.tpot_slo_s,
                                              self._active_batch() + 1, seq)
        else:
            min_i = self.interval if self.interval < NO_OFFLOAD else 1
        times = self.times_fn(max(self._active_batch(), 1),
                              self.ecfg.max_seq, "decode")
        max_i = max_interval_for_memory(
            self.num_units, self.unit_bytes,
            self.ecfg.hbm_budget_bytes
            - self.allocator.used_pages * self.allocator.page_bytes)
        kv_stream = self.swap.streamed_bytes(self._active_rids())
        kv_out = self.swap.pending_out_bytes()
        return InstanceState(
            name=self.name, num_units=self.num_units,
            unit_bytes=self.unit_bytes,
            t_iter_s=iter_time_with_interval_kv(
                times, self.interval if self.interval else NO_OFFLOAD,
                kv_stream, kv_out),
            min_interval=min_i, max_interval=max_i,
            idle=idle if idle is not None else self._active_batch() == 0
            and not self.queue,
            kv_bytes_per_iter=kv_stream + kv_out)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _active_batch(self) -> int:
        return int(self.active.sum())

    def _admit(self) -> None:
        while self.queue:
            req = self.queue[0]
            free_slots = [i for i in range(self.ecfg.max_batch)
                          if not self.active[i]]
            if not free_slots:
                return
            total = req.prompt_len + req.max_new_tokens
            if total > self.ecfg.max_seq:
                req.state = State.REJECTED
                req.reject_reason = "exceeds max_seq"
                self.rejected.append(self.queue.pop(0))
                continue
            # SLO feasibility (paper: pass back to upper scheduler if not)
            min_i = self.rec["decode"].lookup(
                req.tpot_slo_s, self._active_batch() + 1, total)
            max_i = max_interval_for_memory(
                self.num_units, self.unit_bytes,
                self.ecfg.hbm_budget_bytes
                - self.allocator.used_pages * self.allocator.page_bytes)
            if min_i > max_i:
                req.state = State.REJECTED
                req.reject_reason = (f"SLO infeasible: min interval {min_i} > "
                                     f"max {max_i}")
                self.rejected.append(self.queue.pop(0))
                continue
            if self.kv.alloc(req.rid, total, allow_host=False) is None \
                    and not self._spill_admit(req, total):
                return  # wait for memory
            self.queue.pop(0)
            self._prefill_into_slot(req, free_slots[0],
                                    max(min_i, self.interval
                                        if self.interval < NO_OFFLOAD else min_i))

    def _spill_admit(self, req: Request, total: int) -> bool:
        """§4.2 admission, extended for the host KV tier: the device pool is
        full, but the request can be admitted with its cold prefix on host —
        provided the streamed KV traffic keeps every active request's TPOT
        and the new request's TTFT feasible at the current interval. The
        stream rides the same link as weight prefetch, so feasibility is
        evaluated with the combined-traffic iteration time."""
        need = self.kv.device.pages_for(total)
        n_host = need - self.kv.device.free_pages
        if n_host <= 0 or n_host > self.kv.host.free_pages:
            return False                       # no host room: wait
        pb = self.kv.page_bytes
        iv = self.interval if self.interval else NO_OFFLOAD
        streamed_after = (self.swap.streamed_bytes(self._active_rids())
                          + n_host * pb)
        times_d = self.times_fn(self._active_batch() + 1,
                                self.ecfg.max_seq, "decode")
        dt = iter_time_with_interval_kv(times_d, iv, streamed_after,
                                        self.swap.pending_out_bytes())
        tpot_bound = min(self._min_active_tpot(), req.tpot_slo_s)
        if dt > tpot_bound * (1 + 1e-9):
            return False                       # streaming would break TPOT
        if self._modeled_ttft(req, n_host * pb) > req.ttft_slo_s * (1 + 1e-9):
            return False                       # spill write-back breaks TTFT
        refs = self.kv.alloc(req.rid, total, allow_host=True)
        assert refs is not None
        return True

    def _modeled_ttft(self, req: Request, host_spill_bytes: float) -> float:
        """Prefill latency: the spilled KV prefix is written back (d2h)
        through the link the weight prefetches share."""
        times = self.times_fn(1, req.prompt_len, "prefill")
        pre_i = max(self.rec["prefill"].lookup(req.ttft_slo_s, 1,
                                               req.prompt_len), 1)
        return iter_time_with_interval_kv(times, min(pre_i, NO_OFFLOAD),
                                          0.0, host_spill_bytes)

    # -------------------------------------------------------------- prefill --
    def _prefill_into_slot(self, req: Request, slot: int, interval: int
                           ) -> None:
        req.state = State.PREFILLING
        req.slot = slot
        self.slot_req[slot] = req
        rt = self._rt(self.interval)
        if self.interval not in self._jit_prefill:
            self._jit_prefill[self.interval] = jax.jit(
                rt.prefill, static_argnames=("cache_len",))
        # prefill this request alone (chunked-prefill piggybacking is an
        # engine-level extension; the paper separates phases)
        inputs = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        logits, caches1, _ = self._jit_prefill[self.interval](
            self._params_split[self.interval], inputs,
            cache_len=self.ecfg.max_seq)
        # modeled prefill latency = TTFT (same formula admission checked)
        ttft = self._modeled_ttft(req, self.kv.host_bytes_of(req.rid))
        req.ttft_s = ttft
        self.clock_s += ttft

        tok = int(np.argmax(np.asarray(logits[0])))
        req.generated.append(tok)
        self.tokens[slot] = tok
        self.pos[slot] = req.prompt_len
        self.active[slot] = True
        req.state = State.DECODING
        self._insert_cache(caches1, slot)

    def _ensure_params(self, interval: int) -> int:
        self._rt(interval)
        return interval

    def _insert_cache(self, caches1: Any, slot: int) -> None:
        if self._caches is None:
            rt = self._rt(self.interval)
            spec = rt.cache_spec_split(self.ecfg.max_batch, self.ecfg.max_seq)
            from repro.models import spec as S
            self._caches = S.initialize(spec, jax.random.PRNGKey(1))
            self._caches = jax.tree.map(lambda x: x * 0, self._caches)

        def ins(c, n):
            # c: [..., B, ...] stacked sections share layout with n at B=1
            axis = _batch_axis(c.shape, n.shape)
            idx = [slice(None)] * c.ndim
            idx[axis] = slice(slot, slot + 1)
            return c.at[tuple(idx)].set(n)

        # Empty placement sections come back as None from prefill (nothing
        # cached there); the engine keeps its zero-size arrays for those.
        for k in ("resident", "offloaded", "tail"):
            if caches1.get(k) is None:
                continue
            self._caches[k] = jax.tree.map(ins, self._caches[k], caches1[k])

    # ---------------------------------------------------------------- decode --
    def step(self, peers: list["ServingEngine"] | None = None,
             link_bw: float | None = None) -> None:
        """One inference iteration: coordinate -> admit -> decode all slots."""
        if peers is not None and link_bw is not None:
            insts = [self.instance_state()] + [p.instance_state()
                                               for p in peers]
            res = coordinate(insts, link_bw)
            if res.ok:
                self.set_interval(res.intervals[self.name])
                for p in peers:
                    p.set_interval(res.intervals[p.name])
        elif self.interval == 0:
            self.set_interval(NO_OFFLOAD)

        self._admit()
        self.host_kv_peak_pages = max(self.host_kv_peak_pages,
                                      self.kv.host.used_pages)
        if self._active_batch() == 0:
            return
        # KV tier activity of this iteration: promote host pages into freed
        # device frames, stream the rest in for attention, write back any
        # pending demotions. Promotion is never a traffic spike: a promoted
        # page's one-time copy replaces its recurring streamed copy.
        plan = self.swap.plan_iteration(self._active_rids())
        rt = self._rt(self.interval)
        fn = self._jit_decode[self.interval]
        logits, self._caches = fn(
            self._params_split[self.interval],
            jnp.asarray(self.tokens), jnp.asarray(self.pos), self._caches)
        logits = np.asarray(logits, np.float32)

        times = self.times_fn(self._active_batch(), self.ecfg.max_seq,
                              "decode")
        dt = iter_time_with_interval_kv(times, self.interval,
                                        plan.kv_in_bytes, plan.kv_out_bytes)
        self.clock_s += dt

        for slot in range(self.ecfg.max_batch):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            if req is None:
                continue
            tok = int(np.argmax(logits[slot]))
            req.generated.append(tok)
            req.tpot_s.append(dt)
            self.tokens[slot] = tok
            self.pos[slot] += 1
            if req.done:
                req.state = State.FINISHED
                self.finished.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
                self.kv.free(req.rid)

    def run(self, requests: list[Request], max_iters: int = 10_000,
            peers=None, link_bw=None) -> dict:
        for r in requests:
            self.submit(r)
        it = 0
        while (self.queue or self._active_batch() > 0) and it < max_iters:
            self.step(peers=peers, link_bw=link_bw)
            it += 1
        done = [r.metrics() for r in self.finished]
        total_tokens = sum(m["tokens"] for m in done)
        return {
            "finished": len(self.finished),
            "rejected": len(self.rejected),
            "tokens": total_tokens,
            "wall_modeled_s": self.clock_s,
            "throughput_tok_s": total_tokens / self.clock_s
            if self.clock_s > 0 else 0.0,
            "slo_ok": all(m["ttft_ok"] and m["tpot_ok"] for m in done),
            "per_request": done,
        }


def _batch_axis(cshape: tuple, nshape: tuple) -> int:
    """Locate the batch axis: first axis where shapes differ."""
    for a, (cs, ns) in enumerate(zip(cshape, nshape)):
        if cs != ns:
            return a
    return 0
