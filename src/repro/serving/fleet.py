"""Multi-instance serving fleet: KV-affinity routing + cross-instance
preemption.

A ``Fleet`` owns N independent ``ServingEngine`` instances — each with its
own allocator, data plane, tuner and trace — and a ``Router`` that places
every arriving request ONCE, at its arrival instant, on the instance whose
state scores best:

  * **claimed prefix hits** — the router hashes the prompt ONE time with the
    same page-chained rolling hash the prefix index uses
    (``prefix_page_keys``) and probes every instance's index with that one
    key list (``TieredKVAllocator.claimed_prefix_hits``). An instance that
    already holds the prompt's leading pages serves them by refcount bump
    instead of fresh prefill + spill traffic — the whole point of affinity.
  * **queue depth / predicted queueing delay** — waiting + parked requests
    over the instance's current packing capacity
    (``engine._batch_capacity``), scaled by its modeled iteration time; an
    instance whose predicted delay already breaks the request's TTFT SLO is
    only chosen when no instance is clean.
  * **link pressure** — the fleet-wide link-budget owner's per-instance
    share of the host link (``FleetLinkBudget.pressure``): affinity never
    steers more traffic onto an instance already saturating the bus the
    coordinator arbitrates.

The fleet's step loop is event-driven on the modeled clocks: the engine
whose clock lags steps next, and arrivals interleave at their exact
instants (each instance keeps the arrival-honoring ``idle_wait_s``
discipline of ``ServingEngine.run``, so every per-instance trace still
tiles and audits). With a shared ``link_bw``, every step runs the §4.5
arbitration across the WHOLE fleet — the bus coordinator promoted to
fleet-wide link-budget owner.

Cross-instance preemption: when an instance is overloaded (requests parked
AND more waiting) while a peer has strictly less load and host room, the
oldest parked request's KV serializes into a ``MigrationTicket`` (host
frames in token order + the ``next_token``/``resume_pos`` cursor snapshot),
transfers over a modeled peer ``LinkSpec``, and resumes bitwise-exactly on
the peer through its ordinary resume path. The transfer's modeled seconds
and payload bytes are charged to BOTH instances' iteration clocks/records
and conserved by the trace auditor (invariant I11) — plus the fleet-level
cross-check here (``Fleet.audit``): total bytes exported == total bytes
imported across the fleet.

Disaggregated prefill/decode: engines constructed with ``role="prefill"``
or ``role="decode"`` split the fleet. The router binds prompts to prefill
instances only; a prefill instance parks every freshly-prefilled request
(TTFT charged on its side, ``hold_resumes`` keeps local decode away), and
after every fleet step ``_maybe_handoff`` drains the staging set peer-ward:
the least-loaded decode instance whose scheduler CERTIFIES the transfer
(host room + the peer-extended feasibility term against the live
population's tightest TPOT) adopts the ticket through the PEER tier. The
payload's bytes ride the peer link's own concurrent channel (``peer_s`` in
both endpoints' next iteration records — invariant I12), so the transfer
overlaps the exporter's next prefill instead of stalling it; a refused
import rolls back into the frames the export just freed. Routes re-bind
per iteration boundary (``_rescore_queued``): a request still waiting in
one queue moves to a peer that now strictly wins, e.g. one that drained
since the arrival instant. Because shape-bucketed prefill makes KV pages
placement-independent, greedy tokens are bitwise identical across the
disaggregated fleet, the symmetric affinity fleet, and one pooled
instance — the differential suite pins exactly that.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.coordinator import FleetLinkBudget
from repro.core.interval import NO_OFFLOAD
from repro.serving.engine import ServingEngine
from repro.serving.kv_offload import LinkSpec, prefix_page_keys
from repro.serving.request import Request
from repro.serving.telemetry import summarize_latency

# NVLink/NIC-class peer interconnect for migration tickets: distinct from
# (and faster than) the host PCIe link the coordinator arbitrates
DEFAULT_PEER_LINK = LinkSpec(bw_bytes_s=16e9, latency_s=1e-5)

ROUTER_POLICIES = ("affinity", "round_robin")


@dataclasses.dataclass
class RouteDecision:
    """Why one arrival landed where it did (kept for tests/debugging)."""
    rid: int
    instance: int
    hits: list[int]                # claimed prefix hits per instance
    delays: list[float]            # predicted queueing delay per instance
    loads: list[float]             # occupancy + link-pressure per instance


class Router:
    """Stateless-per-request placement policy over the fleet's engines.

    ``affinity`` scores every instance by (prefix hits, occupancy + link
    pressure, predicted delay) and admits to the argmax — SLO-clean
    instances strictly beat dirty ones, more claimed prefix pages beat
    fewer, then the least loaded wins. ``round_robin`` is the baseline the
    differential compares byte traffic against."""

    def __init__(self, policy: str = "affinity",
                 budget: FleetLinkBudget | None = None):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r} "
                             f"(have {ROUTER_POLICIES})")
        self.policy = policy
        self.budget = budget
        self._rr = 0
        self.decisions: list[RouteDecision] = []

    def scores(self, req: Request, engines: list[ServingEngine]
               ) -> tuple[list[int], list[float], list[float], list[tuple]]:
        """Per-instance (hits, delays, loads, score tuples) for one request
        — the comparable quantities both the arrival-time route and the
        per-boundary re-score rank by."""
        # hash the prompt ONCE; probe every instance's index with the same
        # key list (all instances of a fleet share one dedup scope — same
        # model config and page geometry)
        keys = prefix_page_keys(engines[0].kv.scope, req.prompt,
                                engines[0].kv.pcfg.page_size)
        hits, delays, loads, scores = [], [], [], []
        for eng in engines:
            iv = eng.interval if eng.interval else NO_OFFLOAD
            h = eng.kv.claimed_prefix_hits(keys)
            depth = len(eng.queue) + len(eng.scheduler.preempted)
            cap = max(eng._batch_capacity(iv), 1)
            # every waiting request needs ~one iteration slot-batch ahead
            # of this one; parked requests resume with priority, so they
            # queue ahead too
            delay_s = depth / cap * eng.instance_state().t_iter_s
            load = ((depth + eng._active_batch())
                    / max(eng.ecfg.max_batch, 1))
            if self.budget is not None:
                load += self.budget.pressure(eng.instance_state(), iv)
            ok = delay_s <= req.ttft_slo_s * (1 + 1e-9)
            hits.append(h)
            delays.append(delay_s)
            loads.append(load)
            scores.append((ok, h, -load, -delay_s))
        return hits, delays, loads, scores

    def route(self, req: Request, engines: list[ServingEngine]) -> int:
        if self.policy == "round_robin":
            i = self._rr % len(engines)
            self._rr += 1
            return i
        hits, delays, loads, scores = self.scores(req, engines)
        best = max(range(len(engines)), key=lambda i: scores[i])
        self.decisions.append(RouteDecision(req.rid, best, hits, delays,
                                            loads))
        return best


class Fleet:
    """N independent engines + a router + (optionally) the fleet-wide link
    budget and the cross-instance preemption policy."""

    def __init__(self, engines: list[ServingEngine],
                 policy: str = "affinity",
                 link_bw: float | None = None,
                 peer_link: LinkSpec = DEFAULT_PEER_LINK,
                 migrate: bool = True,
                 rescore: bool = True):
        assert engines, "a fleet needs at least one instance"
        self.engines = engines
        self.budget = FleetLinkBudget(link_bw) if link_bw else None
        self.router = Router(policy, self.budget)
        self.peer_link = peer_link
        self.migrate = migrate
        self.rescore = rescore
        self.migrations: list[dict] = []
        # role-typed instances: any non-"mixed" role makes the fleet
        # disaggregated — prompts route to prefill instances, finished
        # prefills hand off peer-ward, decode instances own the TPOT side
        self.prefill_engines = [e for e in engines if e.role == "prefill"]
        self.decode_engines = [e for e in engines if e.role == "decode"]
        self.disagg = bool(self.prefill_engines or self.decode_engines)
        if self.disagg and not (self.prefill_engines
                                and self.decode_engines):
            raise ValueError("a disaggregated fleet needs at least one "
                             "prefill and one decode instance")
        # per-link handoff ledger: one entry per accepted ticket, keyed by
        # (src, dst) in audit — the cross-instance half of invariant I12
        self.handoffs: list[dict] = []
        self.reroutes: list[dict] = []

    # ------------------------------------------------------------- serving --
    def _routable(self) -> list[ServingEngine]:
        """Engines fresh prompts may route to: prefill instances in a
        disaggregated fleet (decode instances only receive handoffs),
        everyone otherwise."""
        return self.prefill_engines if self.disagg else self.engines

    def _place(self, req: Request, eng: ServingEngine) -> None:
        if eng.clock_s < req.arrival_s:
            # the chosen instance drained before this arrival: jump its
            # clock exactly like the single-engine arrival-honoring loop
            dt = req.arrival_s - eng.clock_s
            eng.idle_wait_s += dt
            eng.idle_wait_total_s += dt
            eng.clock_s = req.arrival_s
        eng.scheduler.submit(req)

    def _submit(self, req: Request) -> None:
        routable = self._routable()
        eng = routable[self.router.route(req, routable)]
        self._place(req, eng)
        req.submitted_s = max(req.arrival_s, 0.0)

    def _rescore_queued(self) -> None:
        """Routes bind per-boundary, not per-arrival: a request still
        WAITING in one instance's queue (no KV claimed — withdrawing it
        rolls back nothing) re-scores against the routable set after every
        fleet step and moves when another instance now strictly wins, e.g.
        a peer that drained since the arrival instant."""
        if not self.rescore or self.router.policy != "affinity":
            return
        routable = self._routable()
        if len(routable) < 2:
            return
        for eng in routable:
            for req in list(eng.queue):
                cur = routable.index(eng)
                _, _, _, scores = self.router.scores(req, routable)
                best = max(range(len(routable)), key=lambda i: scores[i])
                if best == cur or not scores[best] > scores[cur]:
                    continue
                got = eng.scheduler.withdraw(req.rid)
                if got is None:
                    continue
                self._place(got, routable[best])
                self.reroutes.append({
                    "rid": req.rid, "src": eng.name,
                    "dst": routable[best].name})

    def _step(self, eng: ServingEngine) -> None:
        if self.budget is not None:
            eng.step(peers=[e for e in self.engines if e is not eng],
                     link_bw=self.budget.link_bw)
        else:
            eng.step()

    def _busy(self, eng: ServingEngine) -> bool:
        """Does stepping this engine make progress? For a prefill-role
        instance the parked set is the handoff staging area, not local
        work: with ``hold_resumes`` set, a step that only holds parked
        requests is a no-op whose clock never advances, so counting it as
        busy would spin the min-clock event loop forever."""
        if eng.role == "prefill" and eng.scheduler.hold_resumes:
            return bool(eng.queue) or eng._active_batch() > 0 \
                or bool(eng.scheduler._prefilling)
        return eng.scheduler.has_work() or eng._active_batch() > 0

    def run(self, requests: list[Request], max_iters: int = 100_000,
            submit_all: bool = False) -> dict:
        """Serve ``requests`` across the fleet on the modeled clocks.

        Event-driven: the next event is whichever comes first of (a) the
        next arrival (routed and submitted at its exact instant) and (b)
        the lagging busy engine's next iteration. ``submit_all=True``
        routes everything up front (burst-compat path)."""
        pending = sorted(requests, key=lambda r: r.arrival_s)
        n_pend = 0
        if submit_all:
            for req in pending:
                self._submit(req)
            n_pend = len(pending)
        iters = 0
        while iters < max_iters:
            busy = [e for e in self.engines if self._busy(e)]
            t_step = min((e.clock_s for e in busy), default=math.inf)
            t_arr = (pending[n_pend].arrival_s if n_pend < len(pending)
                     else math.inf)
            if t_arr <= t_step:
                if t_arr == math.inf:
                    # drained of arrivals and no busy engine — but a
                    # prefill instance may still hold parked handoffs the
                    # decode side refused earlier; push them through now
                    # (empty decode populations certify via the
                    # starvation guard) before declaring the fleet done
                    if self.disagg and self._flush_handoffs():
                        continue
                    break
                req = pending[n_pend]
                n_pend += 1
                self._submit(req)
                continue
            eng = min(busy, key=lambda e: (e.clock_s,
                                           self.engines.index(e)))
            self._step(eng)
            iters += 1
            if self.disagg:
                # handoffs are the only cross-instance movement in a
                # disaggregated fleet: the emergency migration path would
                # raid the prefill staging set with a synchronous,
                # uncertified transfer
                self._maybe_handoff()
            elif self.migrate and len(self.engines) > 1:
                self._maybe_migrate(eng)
            self._rescore_queued()
        for eng in self.engines:
            if eng.data_plane is not None:
                eng.data_plane.sync()
        return self.summary()

    # ----------------------------------------------------------- migration --
    def _load(self, eng: ServingEngine) -> int:
        return (len(eng.queue) + len(eng.scheduler.preempted)
                + eng._active_batch())

    def _maybe_migrate(self, src: ServingEngine) -> None:
        """Cross-instance preemption policy, evaluated after ``src`` steps:
        when src is overloaded (a parked request is being starved by
        waiting admissions) and a peer has strictly less load plus the host
        room to adopt, the OLDEST parked request migrates there. Capacity
        is checked before anything moves, so a failed import can only come
        from reclaim falling short — rolled back into the frames the export
        just freed."""
        if not (src.scheduler.preempted and src.queue):
            return
        cand = src.scheduler.preempted[0]
        pages = src.kv.export_parked(cand.rid)    # read-only exportability
        if pages is None:
            return
        peers = [e for e in self.engines if e is not src
                 and e.host_pool is not None]
        if not peers:
            return
        dst = min(peers, key=self._load)
        if self._load(dst) + 1 >= self._load(src):
            return                         # no strict win: don't thrash
        if (dst.kv.host.free_pages + dst.kv.reclaimable_host_pages()
                < len(pages)):
            return                         # peer cannot host the ticket
        out = src.export_parked_request(cand.rid)
        assert out is not None             # exportability checked above
        req, ticket = out
        if not dst.import_parked_request(req, ticket):
            # reclaim fell short of the precheck: re-import into the frames
            # the export just freed (guaranteed room), books stay conserved
            assert src.import_parked_request(req, ticket), \
                "rollback import into just-freed frames failed"
            return
        # the transfer rides the modeled peer link and charges BOTH
        # instances' clocks — src serializes out, dst lands it; the pending
        # seconds stamp each side's next iteration record (audited: I4/I11)
        t = self.peer_link.latency_s
        if self.peer_link.bw_bytes_s > 0:
            t += ticket.bytes_total / self.peer_link.bw_bytes_s
        for eng in (src, dst):
            eng.clock_s += t
            eng.mig_wait_s += t
            eng.mig_wait_total_s += t
        self.migrations.append({
            "rid": req.rid, "src": src.name, "dst": dst.name,
            "n_pages": ticket.n_pages, "bytes": ticket.bytes_total,
            "transfer_s": t})

    # ------------------------------------------------------------- handoff --
    def _pick_decode(self, req: Request,
                     n_pages: int) -> ServingEngine | None:
        """Least-loaded decode instance whose scheduler certifies the
        handoff (host room + peer-extended feasibility against the live
        population's tightest TPOT), or None — certify-before-offer, so a
        refusal costs nothing."""
        cands = [e for e in self.decode_engines if e.host_pool is not None]
        for dst in sorted(cands, key=self._load):
            if dst.scheduler.certify_handoff(n_pages, req.tpot_slo_s,
                                             dst._view().active):
                return dst
        return None

    def _maybe_handoff(self) -> int:
        """Live post-prefill KV handoff, evaluated after every fleet step:
        each prefill instance's parked set (its handoff staging area —
        ``hold_resumes`` keeps local resume away from it) drains peer-ward
        to whichever certified decode instance is least loaded. The
        payload's bytes ride the PEER tier's own link term (``peer_s`` in
        both endpoints' next iteration records — the transfer overlaps the
        exporter's next prefill), so unlike the emergency migration path
        nothing stalls synchronously. A refused import (certification can
        shift between the precheck and the claim) rolls back into the
        frames the export just freed."""
        moved = 0
        for src in self.prefill_engines:
            for req in list(src.scheduler.preempted):
                pages = src.kv.export_parked(req.rid)   # read-only probe
                if pages is None:
                    continue                  # not (yet) host-exportable
                dst = self._pick_decode(req, len(pages))
                if dst is None:
                    continue
                out = src.export_handoff(req.rid)
                if out is None:
                    continue
                got, ticket = out
                if dst.clock_s < src.clock_s:
                    # causality: the decode side cannot resume KV that has
                    # not been exported yet — an idle importer waits for
                    # the export instant (same discipline as arrivals)
                    dt = src.clock_s - dst.clock_s
                    dst.idle_wait_s += dt
                    dst.idle_wait_total_s += dt
                    dst.clock_s = src.clock_s
                if not dst.import_handoff(got, ticket):
                    src.rollback_handoff(got, ticket)
                    continue
                moved += 1
                self.handoffs.append({
                    "rid": got.rid, "src": src.name, "dst": dst.name,
                    "n_pages": ticket.n_pages,
                    "bytes": ticket.bytes_total})
        return moved

    def _flush_handoffs(self) -> bool:
        """Drained-fleet backstop: no arrivals left and no busy engine,
        but prefill instances still hold parked requests. First retry the
        ordinary handoff path (an empty decode population certifies via
        the starvation guard whenever host room exists); if nothing can
        move — the decode tier genuinely cannot absorb the stranded set —
        degrade gracefully by releasing ``hold_resumes`` so the stranded
        prefill instance decodes locally (the resume path is
        placement-independent, so tokens stay bitwise)."""
        if self._maybe_handoff() > 0:
            return True
        changed = False
        for eng in self.prefill_engines:
            if eng.scheduler.preempted and eng.scheduler.hold_resumes:
                eng.scheduler.hold_resumes = False
                changed = True
        return changed

    # --------------------------------------------------------------- audit --
    def audit(self) -> tuple[bool, list[str]]:
        """Per-instance trace audits (I1-I11) plus the fleet-level
        migration conservation cross-check: every byte one instance
        exported, exactly one instance imported."""
        violations: list[str] = []
        for eng in self.engines:
            rep = eng.trace.audit()
            violations += [f"{eng.name}: {v}" for v in rep.violations]
        out_b = sum(e.mig_out_bytes_total for e in self.engines)
        in_b = sum(e.mig_in_bytes_total for e in self.engines)
        if out_b != in_b:
            violations.append(f"fleet: migrated-out bytes {out_b:.0f} != "
                              f"migrated-in bytes {in_b:.0f}")
        n_out = sum(e.n_migrated_out for e in self.engines)
        n_in = sum(e.n_migrated_in for e in self.engines)
        if n_out != n_in:
            violations.append(f"fleet: {n_out} tickets exported != "
                              f"{n_in} adopted")
        tik = sum(m["bytes"] for m in self.migrations)
        if tik != out_b:
            violations.append(f"fleet: ticket log {tik:.0f}B != exported "
                              f"{out_b:.0f}B")
        # handoff conservation — the cross-instance half of invariant I12:
        # bytes exported == bytes imported, fleet-wide and per link
        ho_out = sum(e.handoff_out_bytes_total for e in self.engines)
        ho_in = sum(e.handoff_in_bytes_total for e in self.engines)
        if ho_out != ho_in:
            violations.append(f"fleet: handoff-out bytes {ho_out:.0f} != "
                              f"handoff-in bytes {ho_in:.0f}")
        n_ho_out = sum(e.n_handoff_out for e in self.engines)
        n_ho_in = sum(e.n_handoff_in for e in self.engines)
        if n_ho_out != n_ho_in:
            violations.append(f"fleet: {n_ho_out} handoff tickets exported "
                              f"!= {n_ho_in} adopted")
        # per-endpoint: the ledger's per-instance byte totals must match
        # each endpoint's own counters (no link moved bytes the ledger
        # didn't see, and vice versa)
        led_out: dict[str, float] = {}
        led_in: dict[str, float] = {}
        for h in self.handoffs:
            led_out[h["src"]] = led_out.get(h["src"], 0.0) + h["bytes"]
            led_in[h["dst"]] = led_in.get(h["dst"], 0.0) + h["bytes"]
        for eng in self.engines:
            if led_out.get(eng.name, 0.0) != eng.handoff_out_bytes_total:
                violations.append(
                    f"fleet: ledger says {eng.name} exported "
                    f"{led_out.get(eng.name, 0.0):.0f}B but it booked "
                    f"{eng.handoff_out_bytes_total:.0f}B")
            if led_in.get(eng.name, 0.0) != eng.handoff_in_bytes_total:
                violations.append(
                    f"fleet: ledger says {eng.name} imported "
                    f"{led_in.get(eng.name, 0.0):.0f}B but it booked "
                    f"{eng.handoff_in_bytes_total:.0f}B")
        return not violations, violations

    # ------------------------------------------------------------- summary --
    def summary(self) -> dict:
        finished = [r for e in self.engines for r in e.finished]
        done = [r.metrics() for r in finished]
        total_tokens = sum(m["tokens"] for m in done)
        wall = max(e.clock_s for e in self.engines)
        link = {}
        for eng in self.engines:
            for k, v in eng.trace.totals().items():
                link[k] = link.get(k, 0.0) + v
        return {
            "instances": len(self.engines),
            "router": self.router.policy,
            "finished": len(finished),
            "rejected": sum(len(e.rejected) for e in self.engines),
            "tokens": total_tokens,
            "wall_modeled_s": wall,
            "throughput_tok_s": total_tokens / wall if wall > 0 else 0.0,
            "slo_ok": all(m["ttft_ok"] and m["tpot_ok"] for m in done),
            "disagg": self.disagg,
            "migrations": len(self.migrations),
            "migrated_bytes": sum(m["bytes"] for m in self.migrations),
            "handoffs": len(self.handoffs),
            "handoff_bytes": sum(h["bytes"] for h in self.handoffs),
            "reroutes": len(self.reroutes),
            "preemptions": sum(e.scheduler.stats["preemptions"]
                               for e in self.engines),
            "resumes": sum(e.scheduler.stats["resumes"]
                           for e in self.engines),
            "queue_delay": summarize_latency([m["queue_delay_s"]
                                              for m in done]),
            "ttft": summarize_latency([m["ttft_s"] for m in done]),
            "tpot": summarize_latency([t for r in finished
                                       for t in r.tpot_s]),
            "link_bytes": link,
            "per_instance": {
                e.name: {
                    "role": e.role,
                    "finished": len(e.finished),
                    "rejected": len(e.rejected),
                    "clock_s": e.clock_s,
                    "preemptions": e.scheduler.stats["preemptions"],
                    "migrations_out": e.n_migrated_out,
                    "migrations_in": e.n_migrated_in,
                    "handoffs_out": e.n_handoff_out,
                    "handoffs_in": e.n_handoff_in,
                    "link_bytes": e.trace.totals(),
                } for e in self.engines},
            "per_request": done,
        }
