"""Paged KV-cache accounting + page pool.

The allocator owns the HBM page budget: pages not claimed by resident weights
are available for KV. This is the mechanism behind the paper's Fig. 14 —
smaller offloading interval => fewer resident weight bytes => more pages =>
larger max allocatable length. Execution-side, the page pool backs the Pallas
paged decode kernel (block tables per request): the serving engine's jitted
decode computes directly through these frames — the accounting pool and the
compute pool are one object (see serving.engine).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PageConfig:
    page_size: int = 16          # tokens per page
    bytes_per_token: int = 0     # whole-model KV bytes for one token


class PagedKVAllocator:
    """Page pool with per-page refcounts: a frame may be referenced by more
    than one request (cross-request prefix dedup / copy-on-write sharing).
    ``alloc_pages`` hands out private frames (refcount 1); ``share_pages``
    adds another owner to a live frame; a frame returns to the free list only
    when its last reference drops."""

    def __init__(self, total_bytes: int, pcfg: PageConfig):
        assert pcfg.bytes_per_token > 0
        self.pcfg = pcfg
        self.page_bytes = pcfg.page_size * pcfg.bytes_per_token
        self.total_pages = max(int(total_bytes // self.page_bytes), 0)
        self._free = list(range(self.total_pages - 1, -1, -1))
        self._by_req: dict[int, list[int]] = {}
        self._rc: dict[int, int] = {}
        self.used_peak = 0

    # ---- queries -------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Unique frames in use (a shared frame counts once)."""
        return self.total_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._rc.get(page, 0)

    def max_allocatable_tokens(self) -> int:
        """Paper Fig. 14's 'max length' metric."""
        return self.free_pages * self.pcfg.page_size

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.pcfg.page_size)

    def pages_of(self, rid: int) -> list[int]:
        return list(self._by_req.get(rid, []))

    # ---- allocation ----------------------------------------------------------
    def alloc_pages(self, rid: int, n: int) -> list[int] | None:
        """Claim ``n`` specific pages for ``rid`` (n == 0 is a valid no-op)."""
        if n > self.free_pages:
            return None
        pages = [self._free.pop() for _ in range(n)]
        if pages:
            self._by_req.setdefault(rid, []).extend(pages)
            for p in pages:
                self._rc[p] = 1
        self.used_peak = max(self.used_peak, self.used_pages)
        return pages

    def share_pages(self, rid: int, pages: list[int]) -> None:
        """Add ``rid`` as another owner of live frames (prefix dedup):
        refcount += 1, no new frame is claimed. Raises if a page is free."""
        for p in pages:
            if self._rc.get(p, 0) < 1:
                raise ValueError(f"cannot share free page {p}")
            self._rc[p] += 1
        if pages:
            self._by_req.setdefault(rid, []).extend(pages)

    def release_pages(self, rid: int, pages: list[int]) -> list[int]:
        """Drop ``rid``'s reference to specific pages; a frame returns to the
        free list only when its last reference drops (returned list). Raises
        if a page is not owned by ``rid`` — the free list must never hold
        duplicates."""
        owned = self._by_req.get(rid, [])
        freed: list[int] = []
        for p in pages:
            owned.remove(p)      # ValueError on foreign/double release
            self._rc[p] -= 1
            if self._rc[p] == 0:
                del self._rc[p]
                self._free.append(p)
                freed.append(p)
        if not owned:
            self._by_req.pop(rid, None)
        return freed

    def alloc(self, rid: int, tokens: int) -> list[int] | None:
        return self.alloc_pages(rid, self.pages_for(tokens))

    def extend(self, rid: int, new_total_tokens: int) -> bool:
        have = len(self._by_req.get(rid, []))
        need = self.pages_for(new_total_tokens) - have
        if need <= 0:
            return True
        return self.alloc_pages(rid, need) is not None

    def free(self, rid: int) -> list[int]:
        """Drop every reference ``rid`` holds; double-free is a no-op.
        Returns the frames whose last reference dropped (now free) — the
        tiered allocator uses this to evict dead prefix-index entries."""
        freed: list[int] = []
        for p in self._by_req.pop(rid, []):
            self._rc[p] -= 1
            if self._rc[p] == 0:
                del self._rc[p]
                self._free.append(p)
                freed.append(p)
        return freed

    def check_invariants(self) -> None:
        """Free list and held frames partition [0, total_pages); every held
        frame's refcount equals its reference multiplicity across requests."""
        free = self._free
        assert len(set(free)) == len(free), "duplicate pages in free list"
        held = [p for pages in self._by_req.values() for p in pages]
        counts: dict[int, int] = {}
        for p in held:
            counts[p] = counts.get(p, 0) + 1
        assert counts == self._rc, "refcounts out of sync with references"
        assert all(c >= 1 for c in counts.values())
        assert not set(free) & set(counts), "page both free and owned"
        assert len(free) + len(counts) == self.total_pages

    def block_table(self, rid: int, max_pages: int) -> np.ndarray:
        """Padded block table row for the paged decode kernel. Raises when the
        request holds more pages than ``max_pages`` — silent truncation would
        make the kernel attend through the wrong frames."""
        return padded_block_table(self._by_req.get(rid, []), max_pages, rid)


def padded_block_table(pages: list[int], max_pages: int, rid: int
                       ) -> np.ndarray:
    """Zero-padded [max_pages] int32 table row; raises instead of truncating
    (shared by the device allocator and the tiered allocator)."""
    if len(pages) > max_pages:
        raise ValueError(
            f"request {rid} holds {len(pages)} pages > max_pages="
            f"{max_pages}: block table would truncate the context")
    out = np.zeros((max_pages,), np.int32)
    out[: len(pages)] = pages
    return out
