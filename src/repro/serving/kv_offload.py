"""N-tier SLO-aware KV-cache offloading with cross-request dedup.

The paper offloads model *state*; the seed engine only tiered weights — KV
pages never left HBM, so max context/batch stayed HBM-bound however small
the offloading interval got (Fig. 14 saturates). This subsystem extends the
paged KV allocator with an ordered hierarchy of page pools below HBM and,
on top of the page refcounts, LMCache-style cross-request prefix sharing.
The hierarchy is ``DEVICE`` -> ``HOST`` -> ``DISK`` (``TIER_ORDER``):
frames migrate only between adjacent tiers, every tier is a
``PagedKVAllocator`` with the same page geometry, and each inter-tier link
carries a ``LinkSpec`` (bandwidth + latency) so the SLO math can charge the
right channel — host<->device traffic rides the PCIe copy stream the weight
prefetches use, host<->disk traffic rides the NVMe link and must never be
billed to (or hidden from) the TPOT-critical PCIe budget.

  * ``HostKVPool``      — host-side page pool, same page geometry as the
                          device pool, with an optional numpy backing buffer
                          (host memory on every backend; the pinned staging
                          area on a real TPU host).
  * ``DiskKVPool``      — NVMe-tier page pool: buffer-backed by default, or
                          file-backed (``np.memmap``) when a backing path is
                          given. Holds parked/preempted state and aged-out
                          prefix-cache frames; never read by the decode
                          kernel directly — disk pages stage through host.
  * ``TieredKVAllocator`` — per-request block tables spanning the tiers.
                          Pages are ordered oldest-first; the lower tiers
                          hold the *front* (cold prefix) so the decode write
                          path always lands on device frames. Page migration
                          (``swap_out`` / ``swap_in`` / ``demote_to_disk``)
                          rewrites refs and reports (src, dst) frame pairs
                          for the data plane
                          (``kernels.ops.copy_pages_to_host/from_host``);
                          host<->disk moves additionally fire the
                          synchronous ``disk_copy`` hook so the bytes are
                          saved before a vacated frame can be reused.
  * ``PrefixIndex``     — content-addressed map from (page position, rolling
                          hash over the token ids, model-config scope) to the
                          physical frame holding that page's KV. A request
                          whose prompt shares a prefix with a live or
                          host-parked request maps its block-table entries
                          onto the same frames (refcount += 1) instead of
                          recomputing + re-storing them. With
                          ``host_prefix_cache_pages > 0`` indexed host
                          frames outlive their last owner under a synthetic
                          cache owner (LRU-bounded, reclaimed on demand), so
                          a re-submitted prefix still dedups.
  * ``park`` / ``resume`` — preempt-to-host: one whole-request migration of
                          a victim's device-resident KV to the host tier
                          (frame-wise and dedup-aware — frames an active
                          sibling still references stay put), and the
                          promotion back when the scheduler un-parks it.
  * ``SwapScheduler``   — per-iteration planner: promotes host pages into
                          freed device frames, streams the still-host-resident
                          KV of active requests in for attention, and charges
                          every byte to the same link budget as weight
                          prefetch (``interval.iter_time_with_interval_kv``,
                          ``coordinator.InstanceState.kv_bytes_per_iter``).

Sharing + copy-on-write protocol (refcounts live in ``PagedKVAllocator``):

  * Only pages covering the *prompt* are content-indexed: full pages keyed by
    (index, chain digest), the trailing partial page additionally by its
    token count. Tail (decode) pages are always private.
  * A sharer that will decode (total tokens > prompt length) pre-claims a
    private COW *reserve* frame at admission time, so the copy-on-write at
    its first decode write can never fail or race a later admission for a
    frame. Reserves are PER PAGE: the admission-time reserve covers the
    partial prompt page, and forked beams (``fork`` + ``add_reserve``)
    claim one reserve per shared page each sharer may write, so N live
    writers of one shared tail diverge safely. ``prepare_write`` swaps the
    page's reserve into the block table and returns the data-plane copy;
    the shared frame is left untouched for its siblings.
  * The request that *registered* a page (its origin) may keep appending in
    place even while the page is shared: a sharer's context never extends
    past the `k` prompt tokens the index key describes until the sharer
    itself writes — and its first write moves it onto its reserve first.
    Positions >= k therefore stay invisible to every sibling (attention
    masks by context length), so the in-place append is safe.
  * Migration is frame-wise: demoting/promoting/remapping a shared frame
    moves it ONCE (one ``Migration``, one physical copy, one charge against
    the link budget) and rewrites the refs of every owner. The frame is
    released — and its index entry evicted — only when the last reference
    drops.

Latency semantics (kept SLO-exact, property-tested against the event
simulator): swap-in gates layer-0 compute; write-back is issued next and
queues the weight prefetches behind it; weight transfers then follow the
Fig. 7 group-start schedule. No byte is double-counted: streamed pages do
not change residency, promoted/demoted pages move exactly once, and a page
shared by several active requests streams once per iteration, not once per
owner.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.serving.kv_cache import (PageConfig, PagedKVAllocator,
                                    padded_block_table)

DEVICE = "device"
HOST = "host"
DISK = "disk"
PEER = "peer"

# Role-typed tier registry: every tier declares what it is FOR, not where
# it sits in a fixed ladder. Local roles form the ordered spill hierarchy
# (compute -> staging -> spill: frames migrate only between adjacent local
# tiers — device <-> host over PCIe, host <-> disk over NVMe). The PEER
# tier's role is different in kind: its far side is another instance's
# host pool, reached over its own ``LinkSpec`` — pages never "migrate
# adjacent" into it, they are exported/imported whole-request as
# ``MigrationTicket`` payloads, and its traffic is charged to the peer
# link's own latency term (``interval.peer_transfer_seconds``), never to
# PCIe or NVMe.
ROLE_COMPUTE = "compute"   # the tier the decode kernel indexes (HBM)
ROLE_STAGING = "staging"   # pinned-host pool below HBM (PCIe)
ROLE_SPILL = "spill"       # cold storage at the ladder's end (NVMe)
ROLE_PEER = "peer"         # a remote instance's staging tier (peer link)

TIER_ROLES: dict[str, str] = {
    DEVICE: ROLE_COMPUTE,
    HOST: ROLE_STAGING,
    DISK: ROLE_SPILL,
    PEER: ROLE_PEER,
}


def tier_role(tier: str) -> str:
    return TIER_ROLES[tier]


def local_tiers() -> tuple[str, ...]:
    """The ordered local ladder (tiers that own frames in THIS instance's
    pools), derived from the role registry. Adding a local tier means
    registering its role here — every migration / invariant / reclaim path
    below iterates this instead of naming pools."""
    return tuple(t for t, role in TIER_ROLES.items() if role != ROLE_PEER)


# Backwards-compatible ladder view: the fixed (DEVICE, HOST, DISK) tuple is
# now DERIVED from the role registry instead of hand-ordered.
TIER_ORDER = local_tiers()


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One inter-tier link of the hierarchy: sustained bandwidth plus a
    fixed per-batch issue latency. ``bw_bytes_s == 0`` means "modeled
    elsewhere" — the device<->host link's bandwidth is implied by the
    measured ``LayerTimes`` the SLO algebra already carries."""
    bw_bytes_s: float = 0.0
    latency_s: float = 0.0

# Synthetic owner of keep-alive prefix-cache frames: host pages whose last
# real owner freed but whose content stays indexed (bounded LRU), so a
# re-submitted shared prefix still dedups. Real request ids are >= 0.
CACHE_RID = -1


@dataclasses.dataclass(frozen=True)
class PageRef:
    tier: str
    page: int


class HostKVPool(PagedKVAllocator):
    """Host-memory page pool mirroring the device pool geometry."""

    def make_pool_buffer(self, page_shape: tuple, dtype=np.float32
                         ) -> np.ndarray:
        """Backing store for real page contents (numpy = host memory)."""
        return np.zeros((self.total_pages, *page_shape), dtype)


class DiskKVPool(HostKVPool):
    """NVMe-tier page pool. Accounting is identical to the host pool; the
    backing buffer is a plain numpy array (a RAM stand-in for NVMe on dev
    boxes) or an ``np.memmap`` over ``backing_path`` (a real file — what a
    production host points at its NVMe mount)."""

    def __init__(self, total_bytes: int, pcfg: PageConfig,
                 backing_path: str | None = None):
        super().__init__(total_bytes, pcfg)
        self.backing_path = backing_path

    def make_pool_buffer(self, page_shape: tuple, dtype=np.float32
                         ) -> np.ndarray:
        if self.backing_path is None:
            return super().make_pool_buffer(page_shape, dtype)
        return np.memmap(self.backing_path, dtype=dtype, mode="w+",
                         shape=(self.total_pages, *page_shape))


@dataclasses.dataclass
class Migration:
    """One page move; src/dst are frame ids in the respective pools."""
    rid: int
    src_tier: str
    src_page: int
    dst_page: int
    dst_tier: str = HOST


@dataclasses.dataclass
class MigrationTicket:
    """Serialized form of one request's KV crossing an instance boundary —
    the ordinary transport of the PEER tier, not just the emergency one:
    the host-resident page payload in token order plus the decode-cursor
    snapshot (``next_token`` / ``resume_pos``) that makes the resume
    bitwise-exact on the destination. The payload is a COPY — the source
    frees its frames after exporting, the destination claims fresh private
    host frames and writes the payload in.

    Two kinds ride the same ticket shape, with different clock charging:

      * ``"evacuation"`` — cross-instance preemption of a parked request
        under overload (PR 9). The fleet charges the transfer seconds
        synchronously to BOTH instances' clocks (``mig_wait_s``).
      * ``"handoff"`` — live post-prefill KV handoff in a disaggregated
        prefill/decode fleet: the prefill side exports through the async
        copy-stage engine (transfer overlaps its next prefill) and each
        side drains the ticket's pages into its next iteration's
        ``peer_s`` channel term (``interval.peer_transfer_seconds``), so
        the decode scheduler certifies TPOT-plus-transfer the same way it
        certifies NVMe traffic.
    """
    rid: int
    n_pages: int
    page_bytes: int
    payload: object                  # [n_pages, *page_shape] array or None
    next_token: int
    resume_pos: int
    kind: str = "evacuation"         # "evacuation" | "handoff"

    @property
    def bytes_total(self) -> int:
        return self.n_pages * self.page_bytes


@dataclasses.dataclass
class CowMove:
    """Copy-on-write: ``rid`` leaves the shared ``src`` frame for its private
    ``dst`` frame; the data plane must copy the page bytes src -> dst before
    the next write lands."""
    rid: int
    src: PageRef
    dst: PageRef


@dataclasses.dataclass
class ResizeResult:
    """Data-plane instructions for a device-pool resize.

    ``demotions`` are device->host moves (src_page is the OLD device frame,
    dst_page the host slot); ``remap`` lists (old_frame, new_frame) pairs for
    pages that stay on device but land in a different frame of the rebuilt
    pool. A caller holding a real page buffer must copy demotions out first
    (old frames are still intact) and then permute the surviving frames.
    Shared frames appear exactly once in either list.
    """
    demotions: list[Migration]
    remap: list[tuple[int, int]]

    @property
    def num_demoted(self) -> int:
        return len(self.demotions)


# ---------------------------------------------------------------------------
# Content-addressed prefix index
# ---------------------------------------------------------------------------


def prefix_page_keys(scope: str, tokens, page_size: int
                     ) -> list[tuple[int, str, int]]:
    """Content keys for every page covering ``tokens``: a rolling hash
    chained page-by-page (so a key commits to the WHOLE prefix up to and
    including its page, not just its own tokens), scoped by ``scope`` (model
    config + page geometry — two models never share frames). Returns
    (page_index, digest, tokens_in_page) per page; the last entry may be
    partial."""
    keys: list[tuple[int, str, int]] = []
    h = hashlib.sha1(scope.encode()).digest()
    toks = np.asarray(tokens, np.int64)
    n = int(toks.shape[0])
    for start in range(0, n, page_size):
        chunk = toks[start:start + page_size]
        h = hashlib.sha1(h + chunk.tobytes()).digest()
        keys.append((start // page_size, h.hex(), int(chunk.shape[0])))
    return keys


class PrefixIndex:
    """key <-> physical frame map, kept in lock-step with page migration:
    entries follow their frame across tiers and die with the frame's last
    reference."""

    def __init__(self):
        self._by_key: dict[tuple, PageRef] = {}
        self._by_frame: dict[PageRef, tuple] = {}

    def get(self, key: tuple) -> PageRef | None:
        return self._by_key.get(key)

    def put(self, key: tuple, ref: PageRef) -> None:
        assert key not in self._by_key and ref not in self._by_frame
        self._by_key[key] = ref
        self._by_frame[ref] = key

    def move(self, old: PageRef, new: PageRef) -> None:
        """The frame holding an indexed page migrated (swap/resize)."""
        key = self._by_frame.pop(old, None)
        if key is not None:
            self._by_key[key] = new
            self._by_frame[new] = key

    def remap_frames(self, tier: str, remap: list[tuple[int, int]]) -> None:
        """Apply a whole-pool frame permutation (device resize). Two-phase:
        old and new frame ids overlap, so pairwise ``move`` calls would
        alias — a moved entry could clobber one not yet moved."""
        moved: list[tuple[tuple, PageRef]] = []
        for old, new in remap:
            key = self._by_frame.pop(PageRef(tier, old), None)
            if key is not None:
                moved.append((key, PageRef(tier, new)))
        for key, ref in moved:
            self._by_key[key] = ref
            self._by_frame[ref] = key

    def evict(self, ref: PageRef) -> None:
        """The frame died (last reference dropped): forget its content."""
        key = self._by_frame.pop(ref, None)
        if key is not None:
            del self._by_key[key]

    def has_frame(self, ref: PageRef) -> bool:
        return ref in self._by_frame

    def __len__(self) -> int:
        return len(self._by_key)


@dataclasses.dataclass
class DedupPreview:
    """What ``alloc`` would share for a given prompt (admission planning).
    Carries the computed rolling-hash ``keys`` so a caller that previews and
    then allocates (``alloc(..., preview=)``) hashes the prompt once, not
    three times per admission attempt."""
    hit_refs: list[PageRef]
    hit_indices: list[int]
    need_reserve: bool
    keys: list[tuple[int, str, int]] = dataclasses.field(
        default_factory=list)

    @property
    def n_hits(self) -> int:
        return len(self.hit_refs)

    def host_hit_pages(self) -> set[int]:
        return {r.page for r in self.hit_refs if r.tier == HOST}

    def disk_hit_pages(self) -> set[int]:
        """Disk-resident (pure prefix-cache) frames the allocation would
        revive: each needs a host frame and one NVMe read to stage."""
        return {r.page for r in self.hit_refs if r.tier == DISK}


class TieredKVAllocator:
    """Paged KV accounting across device HBM + pinned host memory.

    The device pool is the one the paged decode kernel indexes through block
    tables; the host pool absorbs the cold prefix of requests whose KV does
    not fit on device. Per-request refs are kept in token order. With
    ``enable_dedup`` the prompt pages are content-addressed through the
    ``PrefixIndex`` and shared across requests (see module docstring for the
    COW protocol).
    """

    def __init__(self, device_bytes: float, host_bytes: float,
                 pcfg: PageConfig, scope: str = "",
                 enable_dedup: bool = False,
                 host_prefix_cache_pages: int = 0,
                 disk_bytes: float = 0.0,
                 disk_link: LinkSpec = LinkSpec(),
                 disk_backing_path: str | None = None,
                 peer_link: LinkSpec = LinkSpec()):
        self.pcfg = pcfg
        self.device = PagedKVAllocator(max(int(device_bytes), 0), pcfg)
        self.host = HostKVPool(max(int(host_bytes), 0), pcfg)
        self.disk = DiskKVPool(max(int(disk_bytes), 0), pcfg,
                               backing_path=disk_backing_path)
        # local-ladder view (role registry order): every tier-generic path
        # below goes through this map instead of naming a pool. The PEER
        # tier deliberately has no entry — its frames live in another
        # instance's host pool, reached through tickets, never indexed here.
        self.pools: dict[str, PagedKVAllocator] = {
            DEVICE: self.device, HOST: self.host, DISK: self.disk}
        assert set(self.pools) == set(local_tiers())
        self.disk_link = disk_link
        # the PEER tier's link: bandwidth/latency to another instance's
        # host pool (NIC / NVLink). Handoff traffic performed since the
        # swap scheduler last planned is charged to this link's own
        # latency term (interval.peer_transfer_seconds), never to PCIe or
        # NVMe. The emergency evacuation path (fleet cross-instance
        # preemption) does NOT ride these counters — it charges transfer
        # seconds synchronously to both clocks (mig_wait_s).
        self.peer_link = peer_link
        self.pending_peer_in_pages = 0    # handoff imports (peer -> host)
        self.pending_peer_out_pages = 0   # handoff exports (host -> peer)
        self.peer_in_pages_total = 0
        self.peer_out_pages_total = 0
        # data-plane hook for host<->disk moves: called as
        # disk_copy(src_tier, src_page, dst_tier, dst_page) the moment the
        # accounting move lands, while the vacated frame's bytes are still
        # intact. The engine wires this into its copy-stage engine
        # (serving/data_plane.py), which either executes the op immediately
        # (sync mode) or queues it in planning order and drains at the next
        # iteration boundary — either way execution order is a linear
        # extension of planning order, which is what the hazard notes below
        # rely on. Pure accounting users leave it None.
        self.disk_copy = None
        # hook for ``resume``'s host->device promotion legs, called as
        # promote_copy(src_host_page, dst_device_frame). Required whenever
        # disk_copy is wired: resume staging chains several disk pages
        # through one host transit frame, so an apply-time promotion copy
        # would read a frame the NEXT staging already overwrote — the
        # promotion must read its bytes in planning order.
        self.promote_copy = None
        # hook for ``park``'s device->host legs, called as
        # park_copy(src_device_frame, dst_host_frame). Also required with a
        # disk tier: a park and a demotion of the parked pages can land in
        # ONE planning pass, so a deferred park copy would let the NVMe
        # hook read a host frame whose bytes had not arrived yet.
        self.park_copy = None
        # hook for the direct disk->device staging path that bypasses the
        # host bounce buffer when a device frame is free, called as
        # direct_copy(src_tier, src_page, dst_tier, dst_page). When wired,
        # ``resume`` stages pass-through pages straight onto the device:
        # the NVMe read is still charged, but the host-transit PCIe
        # promotion charge disappears (the scheduler only notes HOST-src
        # promotions). Leave None to force every page through the host.
        self.direct_copy = None
        # NVMe traffic performed since the swap scheduler last planned:
        # charged to the disk link's own latency term, never to PCIe
        self.pending_disk_in_pages = 0    # disk -> host staging reads
        self.pending_disk_out_pages = 0   # host -> disk demotion writes
        self.disk_in_pages_total = 0
        self.disk_out_pages_total = 0
        self.disk_direct_pages_total = 0  # of disk_in: direct disk->device
        self._refs: dict[int, list[PageRef]] = {}
        self.scope = scope
        self.enable_dedup = enable_dedup
        self.index = PrefixIndex()
        self._dedup_hits: dict[int, list[int]] = {}   # rid -> hit page idxs
        self._fresh_host: dict[int, int] = {}         # rid -> fresh host pages
        # per-page COW reserves: rid -> {page_idx -> private spare frame}.
        # The admission-time reserve covers the partial prompt page; forked
        # beams add one per shared page they may write (``add_reserve``).
        self._reserves: dict[int, dict[int, PageRef]] = {}
        self.dedup_pages_reused = 0                   # cumulative hit count
        self.cow_copies = 0                           # cumulative COW moves
        # prefix-cache keep-alive: up to this many host frames survive their
        # last owner under CACHE_RID (LRU; 0 disables). A cached frame keeps
        # its index entry, so a later identical prefix still dedups; cache
        # frames are reclaimed on demand when the host pool runs dry.
        self.host_prefix_cache_pages = host_prefix_cache_pages
        self._cache_lru: dict[int, None] = {}  # host frame -> None (ordered)
        # cache frames that retired to disk under host pressure (all pure —
        # refcount 1 under CACHE_RID: a dedup hit revives them host-ward
        # before any request maps them)
        self._disk_cache: dict[int, None] = {}
        self.cache_hits = 0                    # dedup hits on cached frames

    # ---- queries -------------------------------------------------------------
    @property
    def page_bytes(self) -> int:
        return self.device.page_bytes

    def pool_of(self, tier: str) -> PagedKVAllocator:
        return self.pools[tier]

    def refs(self, rid: int) -> list[PageRef]:
        return list(self._refs.get(rid, []))

    def tier_pages_of(self, rid: int, tier: str) -> list[int]:
        return [r.page for r in self._refs.get(rid, []) if r.tier == tier]

    def device_pages_of(self, rid: int) -> list[int]:
        return self.tier_pages_of(rid, DEVICE)

    def host_pages_of(self, rid: int) -> list[int]:
        return self.tier_pages_of(rid, HOST)

    def disk_pages_of(self, rid: int) -> list[int]:
        return self.tier_pages_of(rid, DISK)

    def host_bytes_of(self, rid: int) -> int:
        return len(self.host_pages_of(rid)) * self.page_bytes

    def occupancy(self) -> dict:
        """Per-tier frame occupancy snapshot for the telemetry plane:
        used/total pages per pool plus the cache frames parked in each
        (cache frames are counted inside used_pages — they hold live
        refcounts under CACHE_RID)."""
        occ = {tier: {"used_pages": pool.used_pages,
                      "total_pages": pool.total_pages,
                      "cache_pages": 0}
               for tier, pool in self.pools.items()}
        occ[HOST]["cache_pages"] = len(self._cache_lru)
        occ[DISK]["cache_pages"] = len(self._disk_cache)
        return occ

    def spill_writeback_bytes_of(self, rid: int) -> int:
        """Host bytes prefill must actually write back for ``rid``: freshly
        claimed host frames only — dedup'd host pages are already resident,
        so they cost stream traffic but no spill write-back."""
        return self._fresh_host.get(rid, 0) * self.page_bytes

    def dedup_hit_pages(self, rid: int) -> list[int]:
        """Page indices of ``rid`` that were mapped onto existing frames at
        alloc time (prefill must NOT scatter KV into these)."""
        return list(self._dedup_hits.get(rid, []))

    def reserve_of(self, rid: int) -> PageRef | None:
        """The single reserve of an unforked sharer (compat view): the
        first per-page reserve held, or None."""
        rmap = self._reserves.get(rid)
        return next(iter(rmap.values())) if rmap else None

    def reserves_of(self, rid: int) -> dict[int, PageRef]:
        """page_idx -> private COW reserve frame held by ``rid``."""
        return dict(self._reserves.get(rid, {}))

    def n_reserve_frames(self) -> int:
        """Total claimed COW reserve frames across all requests."""
        return sum(len(m) for m in self._reserves.values())

    def refcount(self, ref: PageRef) -> int:
        return self.pool_of(ref.tier).refcount(ref.page)

    def max_allocatable_tokens(self, include_host: bool = True) -> int:
        """Fig. 14's metric, lifted by the host tier."""
        pages = self.device.free_pages
        if include_host:
            pages += self.host.free_pages
        return pages * self.pcfg.page_size

    # ---- dedup probing -------------------------------------------------------
    def _prompt_keys(self, prompt) -> list[tuple[int, str, int]]:
        return prefix_page_keys(self.scope, prompt, self.pcfg.page_size)

    def dedup_preview(self, prompt, tokens: int) -> DedupPreview:
        """Which prompt pages ``alloc(rid, tokens, prompt=...)`` would share.
        Hits are the contiguous leading run of index matches (prefix
        semantics); ``need_reserve`` is True when the trailing partial prompt
        page is a hit AND the request will decode into it (tokens >
        prompt length), which pre-claims one private frame for the COW.
        Disk-resident entries count as hits only while they are pure cache
        frames (revivable by staging one NVMe read through a host frame);
        a disk frame a parked request still owns ends the hit run — staging
        it would drag the whole parked set's sharing along."""
        if not self.enable_dedup or prompt is None or len(prompt) == 0:
            return DedupPreview([], [], False)
        keys = self._prompt_keys(prompt)
        hits: list[PageRef] = []
        idxs: list[int] = []
        need_reserve = False
        for (idx, digest, ntok) in keys:
            ref = self.index.get((idx, digest, ntok))
            if ref is None:
                break
            if ref.tier == DISK and ref.page not in self._disk_cache:
                break
            hits.append(ref)
            idxs.append(idx)
            if ntok < self.pcfg.page_size and tokens > len(prompt):
                need_reserve = True
        return DedupPreview(hits, idxs, need_reserve, keys)

    def claimed_prefix_hits(self, keys) -> int:
        """Contiguous leading prompt pages this allocator could serve from
        its prefix index right now — the fleet router's affinity score.
        Same hit-run semantics as ``dedup_preview`` (a disk frame a parked
        request still owns ends the run), but over pre-hashed ``keys``
        (``prefix_page_keys`` output) so the router hashes an arriving
        prompt ONCE and probes every instance's index with one key list."""
        if not self.enable_dedup:
            return 0
        n = 0
        for key in keys:
            ref = self.index.get(key)
            if ref is None:
                break
            if ref.tier == DISK and ref.page not in self._disk_cache:
                break
            n += 1
        return n

    # ---- allocation ----------------------------------------------------------
    def alloc(self, rid: int, tokens: int, allow_host: bool = True,
              prompt=None, preview: DedupPreview | None = None
              ) -> list[PageRef] | None:
        """Reserve the whole allocation up front, device-preferred; fresh
        frames fill the non-shared positions host-first (the cold front) and
        device-last, so decode writes land on device frames whenever the
        device pool can hold the tail (when it cannot — e.g. a full-prefix
        dedup hit with an exhausted device pool — the write path falls back
        to the streamed-page + dirty-write-back route). With ``prompt``
        given and dedup enabled, prompt pages already present in the prefix
        index are shared (refcount += 1) instead of claiming fresh frames,
        and fresh prompt pages are registered in the index — the caller must
        land their KV before the next ``alloc`` (the engine prefills
        synchronously after admitting). A caller that already ran
        ``dedup_preview`` this scheduling step (no allocator mutation in
        between) passes it as ``preview`` to skip re-hashing the prompt.
        ``allow_host=False`` refuses any allocation that would claim a new
        host frame OR reference an existing host-resident shared page (both
        put traffic on the link that admission must re-check). None if the
        allocation cannot be satisfied (nothing is claimed on failure)."""
        assert prompt is None or len(prompt) <= tokens, \
            "allocation must cover the whole prompt"
        need = self.device.pages_for(tokens)
        pv = preview if preview is not None \
            else self.dedup_preview(prompt, tokens)
        n_fresh = need - pv.n_hits + (1 if pv.need_reserve else 0)
        n_host = max(n_fresh - self.device.free_pages, 0)
        disk_hits = pv.disk_hit_pages()
        if not allow_host and (n_host > 0 or pv.host_hit_pages()
                               or disk_hits):
            return None
        # disk-resident cache hits are revived through fresh host frames
        # (one NVMe read each), so they claim host capacity like a spill
        if n_host + len(disk_hits) > self.host.free_pages:
            # keep-alive cache frames are reclaimable capacity — but never
            # the ones this very allocation is about to share
            self._reclaim_host(n_host + len(disk_hits)
                               - self.host.free_pages,
                               keep=pv.host_hit_pages(),
                               keep_disk=disk_hits)
        if n_host + len(disk_hits) > self.host.free_pages:
            return None
        revived = {p: self._revive_cached_from_disk(p) for p in disk_hits}
        hit_refs = [PageRef(HOST, revived[r.page]) if r.tier == DISK else r
                    for r in pv.hit_refs]
        hp = self.host.alloc_pages(rid, n_host)
        dp = self.device.alloc_pages(rid, n_fresh - n_host)
        assert hp is not None and dp is not None
        if pv.need_reserve:
            # the reserve prefers a device frame (the COW target is the
            # decode write page); it is claimed in the pool but not in
            # refs, keyed by the partial prompt page it protects
            self._reserves[rid] = {
                pv.hit_indices[-1]: (PageRef(DEVICE, dp.pop()) if dp
                                     else PageRef(HOST, hp.pop()))}
        for ref in hit_refs:
            self.pool_of(ref.tier).share_pages(rid, [ref.page])
            if ref.tier == HOST and ref.page in self._cache_lru:
                # keep-alive hit: refresh recency (the cache keeps its claim,
                # so the frame re-enters the cache when this owner frees)
                self._cache_lru.pop(ref.page)
                self._cache_lru[ref.page] = None
                self.cache_hits += 1
        self.dedup_pages_reused += pv.n_hits
        # position-wise refs: hits keep their page index, fresh pages fill
        # the rest host-first (cold prefix on host)
        fresh = iter([PageRef(HOST, p) for p in hp]
                     + [PageRef(DEVICE, p) for p in dp])
        hitmap = dict(zip(pv.hit_indices, hit_refs))
        refs = [hitmap.get(i) or next(fresh) for i in range(need)]
        if refs:
            self._refs.setdefault(rid, []).extend(refs)
        if pv.hit_indices:
            self._dedup_hits[rid] = list(pv.hit_indices)
        self._fresh_host[rid] = len(hp)
        for key in pv.keys:
            if key[0] not in hitmap and self.index.get(key) is None:
                self.index.put(key, refs[key[0]])
        return refs

    def extend(self, rid: int, new_total_tokens: int,
               allow_host: bool = True, on_demote=None, active_rids=()
               ) -> list[Migration] | None:
        """Grow ``rid`` to ``new_total_tokens``. New (tail) pages must be
        device frames; if the device pool is exhausted, the request's own
        oldest cold device page is demoted to host to vacate a frame —
        frames an ``active_rids`` sibling still references spill last (see
        ``swap_out``) — which the very next tail allocation may recycle. A
        data plane holding real page buffers must therefore copy demoted
        pages out *synchronously* via ``on_demote(migration)``, which fires
        while the vacated frame is still unclaimed; the returned list is
        for traffic accounting only. None if the growth cannot be satisfied
        (nothing is changed then beyond already-performed demotions)."""
        have = len(self._refs.get(rid, []))
        need = self.device.pages_for(new_total_tokens) - have
        if need <= 0:
            return []
        migrations: list[Migration] = []
        added: list[int] = []

        def rollback():
            # undo this call's tail allocations so the refs list still
            # matches the request's token count (demotions stay: the data
            # plane may already have copied them)
            for p in reversed(added):
                self.device.release_pages(rid, [p])
                ref = self._refs[rid].pop()
                assert ref.tier == DEVICE and ref.page == p
            return None

        for _ in range(need):
            if self.device.free_pages == 0:
                if not allow_host:
                    return rollback()
                moved = self.swap_out(rid, 1, active_rids)
                if not moved:
                    return rollback()
                if on_demote is not None:
                    for m in moved:
                        on_demote(m)
                migrations.extend(moved)
            dp = self.device.alloc_pages(rid, 1)
            assert dp is not None
            self._refs.setdefault(rid, []).append(PageRef(DEVICE, dp[0]))
            added.append(dp[0])
        return migrations

    def free(self, rid: int) -> None:
        """Drop every reference ``rid`` holds (refs + COW reserve). Shared
        frames survive for their remaining owners; frames whose last
        reference dropped leave the prefix index with them — except indexed
        host frames when the keep-alive prefix cache is on, which survive
        under ``CACHE_RID`` (LRU-bounded) so a re-submitted prefix dedups."""
        adopted = False
        if self.host_prefix_cache_pages > 0:
            for ref in self._refs.get(rid, []):
                if (ref.tier == HOST and self.host.refcount(ref.page) == 1
                        and self.index.has_frame(ref)
                        and ref.page not in self._cache_lru):
                    self.host.share_pages(CACHE_RID, [ref.page])
                    self._cache_lru[ref.page] = None
                    adopted = True
        for tier in TIER_ORDER:
            for p in self.pool_of(tier).free(rid):
                self.index.evict(PageRef(tier, p))
        self._refs.pop(rid, None)
        self._dedup_hits.pop(rid, None)
        self._fresh_host.pop(rid, None)
        self._reserves.pop(rid, None)
        if adopted:
            # trim AFTER rid's own claims are gone: adopted frames are
            # refcount-1 (pure cache) only now, so the LRU bound can evict
            self._trim_cache()

    # ---- keep-alive prefix cache ---------------------------------------------
    def cached_pages(self) -> list[int]:
        """Host frames alive only as prefix-cache entries (LRU order,
        oldest first). Frames also held by a live request are listed too —
        they cost no extra capacity and re-enter pure-cache state when the
        owner frees."""
        return list(self._cache_lru)

    def reclaimable_host_pages(self) -> int:
        return sum(1 for p in self._cache_lru if self.host.refcount(p) == 1)

    def reclaimable_disk_pages(self) -> int:
        """Disk frames alive only as prefix-cache entries (always pure —
        a dedup hit revives them host-ward before any request maps them)."""
        return len(self._disk_cache)

    def _evict_cached(self, page: int) -> None:
        del self._cache_lru[page]
        freed = self.host.release_pages(CACHE_RID, [page])
        for p in freed:
            self.index.evict(PageRef(HOST, p))

    def _evict_cached_disk(self, page: int) -> None:
        del self._disk_cache[page]
        for p in self.disk.release_pages(CACHE_RID, [page]):
            self.index.evict(PageRef(DISK, p))

    def _reclaim_disk(self, n_pages: int, keep: set[int] | None = None
                      ) -> int:
        """Evict up to ``n_pages`` disk-tier prefix-cache frames, oldest
        first (the end of the hierarchy: below disk there is nowhere left
        to demote to)."""
        freed = 0
        for p in list(self._disk_cache):
            if freed >= n_pages:
                break
            if keep and p in keep:
                continue
            self._evict_cached_disk(p)
            freed += 1
        return freed

    def _demote_cached_to_disk(self, page: int,
                               keep_disk: set[int] | None = None) -> bool:
        """Retire one pure host-cache frame to the disk tier (NVMe write,
        index entry follows) instead of evicting its content outright."""
        if self.disk.total_pages == 0:
            return False
        if self.disk.free_pages == 0 and self._reclaim_disk(1, keep_disk) == 0:
            return False
        dp = self.disk.alloc_pages(CACHE_RID, 1)
        assert dp is not None
        del self._cache_lru[page]
        self.host.release_pages(CACHE_RID, [page])
        self._fire_disk_copy(HOST, page, DISK, dp[0])
        self.pending_disk_out_pages += 1
        self.disk_out_pages_total += 1
        self.index.move(PageRef(HOST, page), PageRef(DISK, dp[0]))
        self._disk_cache[dp[0]] = None
        return True

    def _revive_cached_from_disk(self, page: int) -> int:
        """Stage a disk-resident cache frame back into a host frame (one
        NVMe read) so a dedup hit on it can be shared. Host capacity must
        have been checked by the caller."""
        hp = self.host.alloc_pages(CACHE_RID, 1)
        assert hp is not None, "revival without host room"
        del self._disk_cache[page]
        self.disk.release_pages(CACHE_RID, [page])
        self._fire_disk_copy(DISK, page, HOST, hp[0])
        self.pending_disk_in_pages += 1
        self.disk_in_pages_total += 1
        self.index.move(PageRef(DISK, page), PageRef(HOST, hp[0]))
        self._cache_lru[hp[0]] = None
        return hp[0]

    def _trim_cache(self) -> None:
        over = len(self._cache_lru) - self.host_prefix_cache_pages
        for p in list(self._cache_lru):
            if over <= 0:
                break
            if self.host.refcount(p) == 1:   # only pure-cache frames leave
                # aged out of the host LRU bound: retire to disk when a
                # disk tier exists, evict only at the end of the hierarchy
                if not self._demote_cached_to_disk(p):
                    self._evict_cached(p)
                over -= 1

    def _reclaim_host(self, n_pages: int, keep: set[int] | None = None,
                      keep_disk: set[int] | None = None) -> int:
        """Free up to ``n_pages`` host frames by retiring prefix-cache
        entries, oldest first — demoted to the disk tier when one exists
        (content survives, rides the NVMe link), evicted otherwise. Frames
        with a live owner free no capacity and are skipped; ``keep``
        protects host frames the caller is about to share, ``keep_disk``
        protects disk frames it is about to revive."""
        freed = 0
        for p in list(self._cache_lru):
            if freed >= n_pages:
                break
            if keep and p in keep:
                continue
            if self.host.refcount(p) == 1:
                if not self._demote_cached_to_disk(p, keep_disk):
                    self._evict_cached(p)
                freed += 1
        return freed

    def _fire_disk_copy(self, src_tier: str, src_page: int,
                        dst_tier: str, dst_page: int) -> None:
        if self.disk_copy is not None:
            self.disk_copy(src_tier, src_page, dst_tier, dst_page)

    # ---- copy-on-write -------------------------------------------------------
    def prepare_write(self, rid: int, page_idx: int) -> list[CowMove]:
        """Called before ``rid`` writes into its page ``page_idx`` (the
        decode write position's page). Resolves sharing so the write cannot
        corrupt a sibling:

          * private page (refcount 1): write in place; a now-stale COW
            reserve for this page (every sibling left or finished) is
            released.
          * shared page, ``rid`` holds a reserve for it (admission dedup or
            ``add_reserve``): swap the reserve into the block table — the
            returned ``CowMove`` tells the data plane to copy the page
            bytes first.
          * shared page, no reserve: ``rid`` is the page's origin; appending
            in place is safe (sibling contexts never reach the appended
            positions before their own COW — see module docstring).
        """
        refs = self._refs.get(rid, [])
        assert 0 <= page_idx < len(refs)
        ref = refs[page_idx]
        pool = self.pool_of(ref.tier)
        if pool.refcount(ref.page) <= 1:
            self._drop_reserve(rid, page_idx)
            return []
        new_ref = self._reserves.get(rid, {}).pop(page_idx, None)
        if new_ref is None:
            return []                          # origin: in-place append
        if not self._reserves[rid]:
            del self._reserves[rid]
        pool.release_pages(rid, [ref.page])    # rc > 1: frame survives
        refs[page_idx] = new_ref
        self.cow_copies += 1
        return [CowMove(rid, ref, new_ref)]

    def _drop_reserve(self, rid: int, page_idx: int) -> None:
        rmap = self._reserves.get(rid)
        if rmap is None:
            return
        res = rmap.pop(page_idx, None)
        if not rmap:
            del self._reserves[rid]
        if res is None:
            return
        self.pool_of(res.tier).release_pages(rid, [res.page])

    # ---- forked beams --------------------------------------------------------
    def fork(self, src_rid: int, dst_rid: int) -> list[PageRef] | None:
        """Make ``dst_rid`` a full sharer of ``src_rid``'s block table:
        every frame's refcount += 1, position-wise (multiplicity kept), so
        N beams decode from one shared context without copying a byte. The
        fork inherits NO reserves and no dedup/fresh-host bookkeeping — it
        writes no prefill; callers pre-claim per-page COW reserves with
        ``add_reserve`` on every shared page the beam may write before it
        diverges. None when ``src_rid`` has no refs or ``dst_rid`` is
        already live."""
        if dst_rid in self._refs or src_rid not in self._refs:
            return None
        refs = list(self._refs[src_rid])
        for r in refs:
            self.pool_of(r.tier).share_pages(dst_rid, [r.page])
        self._refs[dst_rid] = list(refs)
        return list(refs)

    def add_reserve(self, rid: int, page_idx: int) -> PageRef | None:
        """Pre-claim a private COW reserve for an ARBITRARY shared page of
        ``rid`` (forked beams: each sharer of a shared tail page needs its
        own spare frame so its first divergent write can never fail or
        race a later admission). Device-preferred, host fallback — same
        policy as the admission-time reserve. Returns the reserve (the
        existing one if this page is already covered); None when the page
        is private (no reserve needed) or neither pool has a free frame
        (nothing is claimed then)."""
        refs = self._refs.get(rid, [])
        assert 0 <= page_idx < len(refs)
        ref = refs[page_idx]
        if self.pool_of(ref.tier).refcount(ref.page) <= 1:
            return None
        rmap = self._reserves.setdefault(rid, {})
        if page_idx in rmap:
            return rmap[page_idx]
        dp = self.device.alloc_pages(rid, 1)
        if dp is not None:
            res = PageRef(DEVICE, dp[0])
        else:
            hp = self.host.alloc_pages(rid, 1)
            if hp is None:
                if not rmap:
                    del self._reserves[rid]
                return None
            res = PageRef(HOST, hp[0])
        rmap[page_idx] = res
        return res

    # ---- migration -----------------------------------------------------------
    def _owners_of(self, ref: PageRef) -> list[tuple[int, list[int]]]:
        """(rid, ref positions) for every request referencing ``ref``."""
        out = []
        for rid, refs in self._refs.items():
            idxs = [i for i, r in enumerate(refs) if r == ref]
            if idxs:
                out.append((rid, idxs))
        return out

    def _move_frame(self, ref: PageRef, new_ref: PageRef) -> None:
        """Rewrite every owner's refs after a frame migration; the pools'
        ownership must already have been transferred by the caller."""
        for rid, refs in self._refs.items():
            for i, r in enumerate(refs):
                if r == ref:
                    refs[i] = new_ref
        for rmap in self._reserves.values():
            for idx, r in rmap.items():
                if r == ref:
                    rmap[idx] = new_ref
        self.index.move(ref, new_ref)

    def _transfer_frame(self, ref: PageRef, dst_pool, dst_tier: str
                        ) -> int | None:
        """Move one frame — with EVERY owner's reference — to ``dst_pool``.
        Returns the new frame id, or None when the destination is full."""
        src_pool = self.pool_of(ref.tier)
        holders: list[int] = []        # one entry per reference held
        for rid, idxs in self._owners_of(ref):
            holders.extend([rid] * len(idxs))
        holders.extend(rid for rid, rmap in self._reserves.items()
                       for r in rmap.values() if r == ref)
        assert holders, "transferring an unreferenced frame"
        dp = dst_pool.alloc_pages(holders[0], 1)
        if dp is None:
            return None
        if ref.tier == HOST and ref.page in self._cache_lru:
            # the frame (and its index entry) leaves the host tier; the
            # keep-alive LRU only spans the host tier, so its claim drops
            del self._cache_lru[ref.page]
            self.host.release_pages(CACHE_RID, [ref.page])
        elif ref.tier == DISK and ref.page in self._disk_cache:
            del self._disk_cache[ref.page]
            self.disk.release_pages(CACHE_RID, [ref.page])
        for rid in holders[1:]:
            dst_pool.share_pages(rid, [dp[0]])
        for rid in holders:
            src_pool.release_pages(rid, [ref.page])
        self._move_frame(ref, PageRef(dst_tier, dp[0]))
        return dp[0]

    def hot_pages(self, active_rids, tier: str,
                  exclude_rid: int | None = None) -> set[int]:
        """Frames on ``tier`` a still-active request references (block
        table or COW reserve). Demoting one frees no net capacity for
        long: the active owner streams (host) or re-promotes (device) the
        page — every per-tier "don't touch the siblings' frames" rule
        below and in the scheduler derives from this one set."""
        hot: set[int] = set()
        for arid in active_rids:
            if arid == exclude_rid:
                continue
            hot.update(r.page for r in self._refs.get(arid, [])
                       if r.tier == tier)
            hot.update(r.page for r in self._reserves.get(arid, {}).values()
                       if r.tier == tier)
        return hot

    def swap_out(self, rid: int, n_pages: int, active_rids=()
                 ) -> list[Migration]:
        """Demote ``rid``'s ``n_pages`` device pages to host, oldest first
        — but frames a still-active sibling references go LAST: demoting a
        hot shared frame moves it for every owner, so the sibling would
        stream it back over the link every subsequent iteration. Unshared
        (or sibling-cold) frames spill first; shared hot frames only when
        nothing else remains. A shared frame moves once, for every owner.
        Returns the moves actually performed (host pool may fill up)."""
        hot = self.hot_pages(active_rids, DEVICE, rid)
        refs = self._refs.get(rid, [])
        order = ([r for r in refs if r.tier == DEVICE and r.page not in hot]
                 + [r for r in refs if r.tier == DEVICE and r.page in hot])
        moves: list[Migration] = []
        for ref in order:
            if len(moves) >= n_pages:
                break
            if ref not in self._refs.get(rid, []):
                continue
            if self.host.free_pages == 0:
                self._reclaim_host(1)
            hp = self._transfer_frame(ref, self.host, HOST)
            if hp is None:
                break
            moves.append(Migration(rid, DEVICE, ref.page, hp, HOST))
        return moves

    def swap_in(self, rid: int, n_pages: int) -> list[Migration]:
        """Promote ``rid``'s ``n_pages`` oldest host pages back to device
        (shared frames move once, for every owner)."""
        moves: list[Migration] = []
        refs = self._refs.get(rid, [])
        for ref in list(refs):
            if len(moves) >= n_pages:
                break
            if ref.tier != HOST or ref not in refs:
                continue
            dp = self._transfer_frame(ref, self.device, DEVICE)
            if dp is None:
                break
            moves.append(Migration(rid, HOST, ref.page, dp, DEVICE))
        return moves

    def demote_to_disk(self, rid: int, n_pages: int, active_rids=(),
                       keep=(), keep_disk: set[int] | None = None
                       ) -> list[Migration]:
        """Demote ``rid``'s ``n_pages`` oldest host pages to the disk tier
        (NVMe writes, fired synchronously through ``disk_copy``). Frames a
        still-ACTIVE sibling references are skipped entirely — an active
        request streams its host pages every iteration and the engine never
        reads the disk pool directly; frames shared only with other parked
        requests move once for all owners. The COW reserve rides along.
        ``keep`` protects extra host frames (a caller's dedup-preview hits:
        moving them would invalidate the preview it is about to allocate
        with); ``keep_disk`` protects disk-cache frames from the reclaim
        this demotion may trigger, for the same reason."""
        skip = self.hot_pages(active_rids, HOST, rid) | set(keep)
        cands = list(self._refs.get(rid, []))
        cands.extend(self._reserves.get(rid, {}).values())
        moves: list[Migration] = []
        seen: set[int] = set()
        for ref in cands:
            if len(moves) >= n_pages:
                break
            if ref.tier != HOST or ref.page in skip or ref.page in seen:
                continue
            seen.add(ref.page)
            if self.disk.free_pages == 0:
                self._reclaim_disk(1, keep_disk)
            src = ref.page
            dp = self._transfer_frame(ref, self.disk, DISK)
            if dp is None:
                break
            self._fire_disk_copy(HOST, src, DISK, dp)
            self.pending_disk_out_pages += 1
            self.disk_out_pages_total += 1
            moves.append(Migration(rid, HOST, src, dp, DISK))
        return moves

    # ---- preempt-to-host (whole-request park/resume) -------------------------
    def _park_targets(self, rid: int, active_rids=()) -> list[PageRef]:
        """Device frames ``park`` would migrate: every device frame ``rid``
        references (block table + COW reserve) EXCEPT frames a still-active
        request also references — moving those frees no capacity (the
        sibling keeps the claim) and would force the sibling to stream a
        page it attends through every iteration. Frame-wise: a frame
        referenced at several positions appears once."""
        keep = self.hot_pages(active_rids, DEVICE, rid)
        cands = list(self._refs.get(rid, []))
        cands.extend(self._reserves.get(rid, {}).values())
        uniq: list[PageRef] = []
        seen: set[int] = set()
        for r in cands:
            if r.tier == DEVICE and r.page not in keep and r.page not in seen:
                seen.add(r.page)
                uniq.append(r)
        return uniq

    def park_preview(self, rid: int, active_rids=()) -> tuple[int, int]:
        """(device frames ``park(rid)`` would free, host frames it still
        NEEDS once prefix-cache reclaim is counted) — the scheduler's
        feasibility precheck, no mutation. ``park`` reclaims keep-alive
        cache frames via ``_reclaim_host`` before giving up, so a preview
        reporting the raw target count would refuse parks the real call
        absorbs: the second element nets out ``reclaimable_host_pages()``
        and is the number to compare against ``host.free_pages``."""
        n = len(self._park_targets(rid, active_rids))
        return n, max(n - self.reclaimable_host_pages(), 0)

    def park(self, rid: int, active_rids=()) -> list[Migration] | None:
        """Preempt-to-host: migrate the request's ENTIRE device-resident KV
        (block-table frames + COW reserve) to the host tier in one
        whole-request move. Shared prefix frames move once for all owners —
        and not at all while an active sibling still references them (they
        free nothing and would cost the sibling streaming traffic). Returns
        the migrations for the data plane, or None (nothing moved) when the
        host pool cannot absorb the parked set even after reclaiming
        prefix-cache frames."""
        targets = self._park_targets(rid, active_rids)
        if len(targets) > self.host.free_pages:
            self._reclaim_host(len(targets) - self.host.free_pages)
        if len(targets) > self.host.free_pages:
            return None
        moves: list[Migration] = []
        for ref in targets:
            hp = self._transfer_frame(ref, self.host, HOST)
            assert hp is not None          # capacity checked up front
            if self.park_copy is not None:
                # synchronous d2h leg: the parked bytes must be resident
                # before a same-pass demotion can retire them to disk
                self.park_copy(ref.page, hp)
            moves.append(Migration(rid, DEVICE, ref.page, hp, HOST))
        return moves

    def _disk_refs_of(self, rid: int) -> list[PageRef]:
        """Unique disk-tier frames ``rid`` references (block table + COW
        reserve), oldest first."""
        cands = list(self._refs.get(rid, []))
        cands.extend(self._reserves.get(rid, {}).values())
        out: list[PageRef] = []
        seen: set[int] = set()
        for r in cands:
            if r.tier == DISK and r.page not in seen:
                seen.add(r.page)
                out.append(r)
        return out

    def unspill_from_disk(self, rid: int) -> int:
        """Stage every disk page of ``rid`` back into host frames (the
        exact reverse of ``demote_to_disk``, NVMe reads through the same
        hooks). Defensive path for a park that fell through AFTER its
        victim's spill was already retired: an ACTIVE request must never
        be left holding disk-tier pages (the decode path cannot read
        them). The host frames the demotion just vacated are still free —
        nothing claimed them between the two calls — so this cannot run
        out of room."""
        n = 0
        for ref in self._disk_refs_of(rid):
            src = ref.page
            hp = self._transfer_frame(ref, self.host, HOST)
            assert hp is not None, "unspill without host room"
            self._fire_disk_copy(DISK, src, HOST, hp)
            self.pending_disk_in_pages += 1
            self.disk_in_pages_total += 1
            n += 1
        return n

    def parked_disk_pages(self, rid: int) -> int:
        """Unique disk frames ``resume(rid)`` would stage back: block-table
        entries AND the COW reserve — what the scheduler must charge as
        NVMe reads (``disk_pages_of`` alone misses the reserve)."""
        return len(self._disk_refs_of(rid))

    def prefetch_from_disk(self, rid: int, max_pages: int) -> int:
        """Stage up to ``max_pages`` of a PARKED request's disk pages into
        FREE host frames ahead of its predicted resume. Opportunistic:
        never reclaims cache frames or evicts anything — it only soaks up
        idle host capacity so the eventual ``resume`` finds the pages
        already host-resident. Charged as NVMe reads through the pending
        disk counters like any staging. Returns the pages staged."""
        n = 0
        for ref in self._disk_refs_of(rid):
            if n >= max_pages or self.host.free_pages == 0:
                break
            src = ref.page
            hp = self._transfer_frame(ref, self.host, HOST)
            if hp is None:
                break
            self._fire_disk_copy(DISK, src, HOST, hp)
            self.pending_disk_in_pages += 1
            self.disk_in_pages_total += 1
            n += 1
        return n

    def resume_staging_shortfall(self, rid: int) -> int:
        """Host frames ``resume`` is short of for staging ``rid``'s disk
        pages back, even after its own host pages promote device-ward and
        prefix-cache frames are reclaimed. Staging INTERLEAVES with
        promotion (stage one page into a host frame, promote it onward,
        reuse the frame), so pages passing through to the device need only
        ONE transit frame; only pages that must STAY host-resident (no
        device frame left) hold a frame each. The scheduler demotes OTHER
        parked requests to cover exactly this shortfall."""
        n_disk = len(self._disk_refs_of(rid))
        if n_disk == 0:
            return 0
        promote = min(len(self.host_pages_of(rid)), self.device.free_pages)
        dev_after = self.device.free_pages - promote
        host_after = (self.host.free_pages + promote
                      + self.reclaimable_host_pages())
        stay = max(n_disk - dev_after, 0)   # pages the device cannot take
        if self.direct_copy is not None:
            # pass-through pages go disk->device directly — no transit
            # frame; only the pages that must stay host-resident need one
            return max(stay - host_after, 0)
        return max(max(stay, 1) - host_after, 0)

    def resume(self, rid: int) -> list[Migration] | None:
        """Un-park. Host-resident pages promote into free device frames
        first (oldest first; shared frames move once, for every owner) —
        this also vacates host frames. Disk-resident pages (a long-parked
        request demoted under host pressure) are then staged disk->host
        one at a time — the decode path can stream host pages through the
        slab but never reads the disk pool — each promoting onward while
        device frames remain, so a chain of pages can pass through a host
        pool smaller than the disk set. Whatever stays host-resident
        streams through the slab until the swap scheduler promotes the
        rest. Returns None (nothing moved) when the host tier cannot
        absorb the staging even after the promotions and prefix-cache
        reclaim; otherwise the host->device promotions (NVMe staging reads
        are charged through the pending disk counters)."""
        if self.resume_staging_shortfall(rid) > 0:
            return None

        def promote(n: int) -> list[Migration]:
            ms = self.swap_in(rid, n)
            if self.promote_copy is not None:
                for m in ms:
                    self.promote_copy(m.src_page, m.dst_page)
            return ms

        moves = promote(len(self.host_pages_of(rid)))
        for ref in self._disk_refs_of(rid):
            src = ref.page
            if self.direct_copy is not None and self.device.free_pages > 0:
                # direct path: the page lands on the device without a host
                # bounce — the NVMe read is charged, the PCIe promotion is
                # not (the saved host-transit bytes leave the link charge)
                dframe = self._transfer_frame(ref, self.device, DEVICE)
                assert dframe is not None
                self.direct_copy(DISK, src, DEVICE, dframe)
                self.pending_disk_in_pages += 1
                self.disk_in_pages_total += 1
                self.disk_direct_pages_total += 1
                moves.append(Migration(rid, DISK, src, dframe, DEVICE))
                continue
            if self.host.free_pages == 0:
                self._reclaim_host(1)
            hp = self._transfer_frame(ref, self.host, HOST)
            assert hp is not None          # shortfall checked up front
            self._fire_disk_copy(DISK, src, HOST, hp)
            self.pending_disk_in_pages += 1
            self.disk_in_pages_total += 1
            if self.device.free_pages > 0:
                moves.extend(promote(1))
        # sweep any remaining host pages into still-free device frames
        if self.device.free_pages > 0:
            moves.extend(promote(len(self.host_pages_of(rid))))
        return moves

    # ---- cross-instance migration (PEER tier) --------------------------------
    def note_peer_export(self, n_pages: int) -> None:
        """Charge a live handoff EXPORT (host -> peer) to the peer link:
        the pages drain into the next ``SwapPlan.peer_out_bytes`` and from
        there into the iteration's ``peer_s`` channel term. The emergency
        evacuation path never calls this — it bills ``mig_wait_s``."""
        self.pending_peer_out_pages += n_pages
        self.peer_out_pages_total += n_pages

    def note_peer_import(self, n_pages: int) -> None:
        """Charge a live handoff IMPORT (peer -> host): the decode side's
        TPOT-plus-transfer certification drains these into ``peer_s``."""
        self.pending_peer_in_pages += n_pages
        self.peer_in_pages_total += n_pages

    def export_parked(self, rid: int) -> list[int] | None:
        """Host frame ids of a fully host-parked request, in token order —
        the payload a ``MigrationTicket`` serializes for cross-instance
        preemption. None (nothing exported) unless EVERY block-table ref
        is host-resident and no COW reserve is held: a partially
        disk-demoted or reserve-holding park stays put (the fleet migrates
        only the bitwise-safe shape). Shared frames are fine — the payload
        is a copy, and the source-side ``free(rid)`` afterwards just drops
        this owner's refcount, leaving the frame to its siblings."""
        refs = self._refs.get(rid)
        if not refs or self._reserves.get(rid):
            return None
        if any(r.tier != HOST for r in refs):
            return None
        return [r.page for r in refs]

    def import_parked(self, rid: int, n_pages: int) -> list[int] | None:
        """Claim ``n_pages`` PRIVATE host frames for a request migrating
        in from a peer instance and install them as its block table (token
        order; the caller writes the ticket payload into them). The frames
        are not prefix-index-registered — this instance never hashed that
        KV — and the request resumes and frees like any locally parked
        one. None (nothing claimed) when the host tier cannot absorb the
        set even after prefix-cache reclaim."""
        assert rid not in self._refs, "import over a live rid"
        if n_pages > self.host.free_pages:
            self._reclaim_host(n_pages - self.host.free_pages)
        hp = self.host.alloc_pages(rid, n_pages)
        if hp is None:
            return None
        self._refs[rid] = [PageRef(HOST, p) for p in hp]
        return hp

    def can_resize_device(self, new_total_bytes: float) -> bool:
        """Would ``resize_device`` succeed? False when the shrink's overflow
        exceeds free host capacity (resize_device would raise). Shared
        frames count once — ``used_pages`` is unique frames; keep-alive
        cache frames count as reclaimable capacity."""
        new_pages = max(int(new_total_bytes), 0) // self.page_bytes
        return (self.device.used_pages - new_pages
                <= self.host.free_pages + self.reclaimable_host_pages())

    def resize_device(self, new_total_bytes: float) -> ResizeResult:
        """Rebuild the device pool for a new byte budget (the offloading
        interval changed the resident weight set). Existing device frames
        are re-assigned to fresh frames; overflow demotes host-ward, largest
        holders first, one move per unique frame however many requests share
        it. Returns the demotions and the old->new frame remap so a caller
        holding the physical page buffer can mirror the move (serving.engine
        copies demoted frames to the host pool and permutes the surviving
        frames in place).
        """
        if not self.can_resize_device(new_total_bytes):
            # validated up front so failure never leaves partial state
            raise RuntimeError("device KV overflow exceeds host capacity")
        new_total = max(int(new_total_bytes), 0) // self.page_bytes
        overflow = self.device.used_pages - new_total
        if overflow > self.host.free_pages:
            self._reclaim_host(overflow - self.host.free_pages)
        demotions: list[Migration] = []
        # shed overflow: take from the requests holding the most device
        # pages, their oldest (front) frames first. Counts are maintained
        # incrementally (a shared frame's transfer drops every owner's
        # count) — rebuilding them per demoted frame would make a large
        # shrink quadratic in pool size.
        counts = {rid: len(self.device_pages_of(rid)) for rid in self._refs}
        while self.device.used_pages > new_total:
            holders = {r: c for r, c in counts.items() if c > 0}
            if holders:
                rid = max(holders, key=holders.get)
                ref = next(r for r in self._refs[rid] if r.tier == DEVICE)
            else:
                # only COW reserves left on device
                rid, ref = next((r, v) for r, rmap in self._reserves.items()
                                for v in rmap.values() if v.tier == DEVICE)
            owners = self._owners_of(ref)
            hp = self._transfer_frame(ref, self.host, HOST)
            assert hp is not None            # entry check guarantees room
            for orid, idxs in owners:
                counts[orid] -= len(idxs)
            demotions.append(Migration(rid, DEVICE, ref.page, hp, HOST))
        # re-assign surviving device frames to fresh frames in a new pool
        new_dev = PagedKVAllocator(max(int(new_total_bytes), 0), self.pcfg)
        frame_new: dict[int, int] = {}
        remap: list[tuple[int, int]] = []

        def assign(rid: int, old: int) -> int:
            if old not in frame_new:
                dp = new_dev.alloc_pages(rid, 1)
                assert dp is not None
                frame_new[old] = dp[0]
                remap.append((old, dp[0]))
            else:
                new_dev.share_pages(rid, [frame_new[old]])
            return frame_new[old]

        for rid, refs in self._refs.items():
            for i, r in enumerate(refs):
                if r.tier == DEVICE:
                    refs[i] = PageRef(DEVICE, assign(rid, r.page))
        for rid, rmap in self._reserves.items():
            for idx, r in list(rmap.items()):
                if r.tier == DEVICE:
                    rmap[idx] = PageRef(DEVICE, assign(rid, r.page))
        # the index follows its frames to their new ids (two-phase: old and
        # new frame ids overlap)
        self.index.remap_frames(DEVICE, remap)
        self.device = new_dev
        self.pools[DEVICE] = new_dev
        return ResizeResult(demotions=demotions, remap=remap)

    # ---- block tables --------------------------------------------------------
    def device_block_table(self, rid: int, max_pages: int) -> np.ndarray:
        """Block table for the paged decode kernel. Valid only when the
        request is fully device-resident (swap_in first). Raises when the
        request holds more pages than ``max_pages`` (truncation would drop
        context pages silently)."""
        refs = self._refs.get(rid, [])
        assert all(r.tier == DEVICE for r in refs), \
            "host-resident pages: swap_in before building the kernel table"
        return padded_block_table([r.page for r in refs], max_pages, rid)

    def check_invariants(self) -> None:
        for pool in self.pools.values():
            pool.check_invariants()
        rids = set(self._refs) | set(self._reserves)
        for rid in rids:
            refs = self._refs.get(rid, [])
            by_tier = {t: [r.page for r in refs if r.tier == t]
                       for t in TIER_ORDER}
            for res in self._reserves.get(rid, {}).values():
                by_tier[res.tier].append(res.page)
            for tier in TIER_ORDER:
                assert sorted(by_tier[tier]) == \
                    sorted(self.pool_of(tier).pages_of(rid)), \
                    f"{tier} refs out of sync with pool for rid {rid}"
        for rid, rmap in self._reserves.items():
            assert rmap, "empty reserve map left behind"
            for res in rmap.values():
                # a COW reserve is a claimed, private, spare frame
                assert self.refcount(res) == 1, "reserve frame is shared"
                assert all(res != r for r in self._refs.get(rid, [])), \
                    "reserve frame already mapped in the block table"
        for key, ref in self.index._by_key.items():
            assert self.index._by_frame.get(ref) == key
            assert self.refcount(ref) >= 1, "index entry on a dead frame"
        for ref, key in self.index._by_frame.items():
            assert self.index._by_key.get(key) == ref
        # keep-alive cache: CACHE_RID's claims are exactly the per-tier
        # LRU sets, and every cached frame still answers a prefix lookup
        assert sorted(self._cache_lru) == sorted(
            self.host.pages_of(CACHE_RID)), "cache LRU out of sync with pool"
        assert sorted(self._disk_cache) == sorted(
            self.disk.pages_of(CACHE_RID)), \
            "disk cache out of sync with pool"
        for p in self._cache_lru:
            assert self.index.has_frame(PageRef(HOST, p)), \
                "cached frame lost its index entry"
        for p in self._disk_cache:
            assert self.index.has_frame(PageRef(DISK, p)), \
                "disk-cached frame lost its index entry"
            assert self.disk.refcount(p) == 1, \
                "disk cache frame gained a live owner without revival"


# ---------------------------------------------------------------------------
# Per-iteration swap planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SwapPlan:
    """Link traffic of one inference iteration's KV tier activity. PCIe
    (kv_in/kv_out) and NVMe (disk_in/disk_out) are separate channels: the
    SLO model charges each to its own term, never disk bytes to the
    TPOT-critical PCIe budget."""
    kv_in_bytes: float = 0.0      # host->device: promotions + streamed KV
    kv_out_bytes: float = 0.0     # device->host: demotions / spill write-back
    streamed_bytes: float = 0.0   # recurring share of kv_in (no residency change)
    disk_in_bytes: float = 0.0    # disk->host staging reads (NVMe)
    disk_out_bytes: float = 0.0   # host->disk demotion writes (NVMe)
    peer_in_bytes: float = 0.0    # handoff imports from a peer (peer link)
    peer_out_bytes: float = 0.0   # handoff exports to a peer (peer link)
    promotions: list[Migration] = dataclasses.field(default_factory=list)


class SwapScheduler:
    """Decides, per iteration, which pages move between tiers.

    Policy: freed device frames are back-filled by promoting the oldest host
    pages of active requests (cheapest first: the request with the fewest
    host pages clears its streaming debt soonest — re-selected after every
    promotion, because a shared-frame swap_in rewrites sibling counts);
    whatever stays on host is streamed in for attention each iteration.
    Demotions queued by interval changes or tail growth are charged as
    write-back traffic; NVMe moves the allocator performed since the last
    plan (park-to-disk, cache retirement/revival, resume staging) are
    drained into the plan's ``disk_in/out_bytes`` — the disk link's own
    term, never the PCIe budget. All byte accounting is frame-wise: a host
    page shared by several active requests streams ONCE per iteration and a
    shared demotion writes back ONCE — charging per owner would double-bill
    the link the SLO math budgets (``iter_time_with_interval_kv``).
    """

    def __init__(self, kv: TieredKVAllocator):
        self.kv = kv
        self._pending_out_pages = 0
        self._pending_in_pages = 0
        # cumulative counters for the trace auditor: every page ever noted
        # or promoted, so "bytes charged to the clock" can be cross-checked
        # against "bytes the allocator actually moved" over a whole trace
        self.in_pages_noted_total = 0
        self.out_pages_noted_total = 0
        self.promoted_pages_total = 0

    def note_demotions(self, n_pages: int) -> None:
        """Register demotions performed by resize/extend/park since last
        plan (callers pass unique frame moves — one per ``Migration``)."""
        self._pending_out_pages += n_pages
        self.out_pages_noted_total += n_pages

    def note_promotions(self, n_pages: int) -> None:
        """Register promotions already performed by the data plane (resume)
        whose copy bytes must be charged to the next iteration's link."""
        self._pending_in_pages += n_pages
        self.in_pages_noted_total += n_pages

    def pending_out_bytes(self) -> float:
        """Write-back traffic already queued for the next iteration."""
        return self._pending_out_pages * self.kv.page_bytes

    def pending_in_bytes(self) -> float:
        """Promotion traffic (resume copies) charged to the next iteration."""
        return self._pending_in_pages * self.kv.page_bytes

    def pending_disk_in_bytes(self) -> float:
        """NVMe staging reads (disk->host) performed since the last plan —
        the allocator counts them at the moment the copy fires."""
        return self.kv.pending_disk_in_pages * self.kv.page_bytes

    def pending_disk_out_bytes(self) -> float:
        """NVMe demotion writes (host->disk) performed since the last plan."""
        return self.kv.pending_disk_out_pages * self.kv.page_bytes

    def pending_peer_in_bytes(self) -> float:
        """Handoff imports (peer->host) performed since the last plan —
        charged to the peer link's own term, never PCIe or NVMe."""
        return self.kv.pending_peer_in_pages * self.kv.page_bytes

    def pending_peer_out_bytes(self) -> float:
        """Handoff exports (host->peer) performed since the last plan."""
        return self.kv.pending_peer_out_pages * self.kv.page_bytes

    def streamed_host_pages(self, active_rids: list[int]) -> set[int]:
        """UNIQUE host frames the active requests attend through."""
        return {p for r in active_rids for p in self.kv.host_pages_of(r)}

    def streamed_bytes(self, active_rids: list[int]) -> float:
        return float(len(self.streamed_host_pages(active_rids))
                     * self.kv.page_bytes)

    def plan_iteration(self, active_rids: list[int]) -> SwapPlan:
        plan = SwapPlan()
        plan.kv_out_bytes = self._pending_out_pages * self.kv.page_bytes
        self._pending_out_pages = 0
        plan.kv_in_bytes = self._pending_in_pages * self.kv.page_bytes
        self._pending_in_pages = 0
        plan.disk_in_bytes = self.pending_disk_in_bytes()
        plan.disk_out_bytes = self.pending_disk_out_bytes()
        self.kv.pending_disk_in_pages = 0
        self.kv.pending_disk_out_pages = 0
        plan.peer_in_bytes = self.pending_peer_in_bytes()
        plan.peer_out_bytes = self.pending_peer_out_bytes()
        self.kv.pending_peer_in_pages = 0
        self.kv.pending_peer_out_pages = 0
        # promote into free device frames, cheapest request first (a shared
        # frame promotes once: the first owner's swap_in rewrites them all).
        # The cheapest request is RE-selected after every promotion: a
        # shared-frame swap_in rewrites sibling refs too, so host-page
        # counts taken before the move go stale mid-loop — a one-shot
        # up-front sort could promote a request that is no longer the one
        # clearing its streaming debt soonest.
        while self.kv.device.free_pages > 0:
            cands = [r for r in active_rids if self.kv.host_pages_of(r)]
            if not cands:
                break
            rid = min(cands, key=lambda r: len(self.kv.host_pages_of(r)))
            moves = self.kv.swap_in(rid, self.kv.device.free_pages)
            if not moves:
                break
            plan.promotions.extend(moves)
            plan.kv_in_bytes += len(moves) * self.kv.page_bytes
            self.promoted_pages_total += len(moves)
        plan.streamed_bytes = self.streamed_bytes(active_rids)
        plan.kv_in_bytes += plan.streamed_bytes
        return plan
