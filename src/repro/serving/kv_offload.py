"""Two-tier SLO-aware KV-cache host offloading.

The paper offloads model *state*; the seed engine only tiered weights — KV
pages never left HBM, so max context/batch stayed HBM-bound however small
the offloading interval got (Fig. 14 saturates). This subsystem extends the
paged KV allocator with a pinned-host tier:

  * ``HostKVPool``      — host-side page pool, same page geometry as the
                          device pool, with an optional numpy backing buffer
                          (host memory on every backend; the pinned staging
                          area on a real TPU host).
  * ``TieredKVAllocator`` — per-request block tables spanning both tiers.
                          Pages are ordered oldest-first; the host tier holds
                          the *front* (cold prefix) so the decode write path
                          always lands on device frames. Page migration
                          (``swap_out`` / ``swap_in``) rewrites refs and
                          reports (src, dst) frame pairs for the data plane
                          (``kernels.ops.copy_pages_to_host/from_host``).
  * ``SwapScheduler``   — per-iteration planner: promotes host pages into
                          freed device frames, streams the still-host-resident
                          KV of active requests in for attention, and charges
                          every byte to the same link budget as weight
                          prefetch (``interval.iter_time_with_interval_kv``,
                          ``coordinator.InstanceState.kv_bytes_per_iter``).

Latency semantics (kept SLO-exact, property-tested against the event
simulator): swap-in gates layer-0 compute; write-back is issued next and
queues the weight prefetches behind it; weight transfers then follow the
Fig. 7 group-start schedule. No byte is double-counted: streamed pages do
not change residency, promoted/demoted pages move exactly once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.kv_cache import (PageConfig, PagedKVAllocator,
                                    padded_block_table)

DEVICE = "device"
HOST = "host"


@dataclasses.dataclass(frozen=True)
class PageRef:
    tier: str
    page: int


class HostKVPool(PagedKVAllocator):
    """Host-memory page pool mirroring the device pool geometry."""

    def make_pool_buffer(self, page_shape: tuple, dtype=np.float32
                         ) -> np.ndarray:
        """Backing store for real page contents (numpy = host memory)."""
        return np.zeros((self.total_pages, *page_shape), dtype)


@dataclasses.dataclass
class Migration:
    """One page move; src/dst are frame ids in the respective pools."""
    rid: int
    src_tier: str
    src_page: int
    dst_page: int


@dataclasses.dataclass
class ResizeResult:
    """Data-plane instructions for a device-pool resize.

    ``demotions`` are device->host moves (src_page is the OLD device frame,
    dst_page the host slot); ``remap`` lists (old_frame, new_frame) pairs for
    pages that stay on device but land in a different frame of the rebuilt
    pool. A caller holding a real page buffer must copy demotions out first
    (old frames are still intact) and then permute the surviving frames.
    """
    demotions: list[Migration]
    remap: list[tuple[int, int]]

    @property
    def num_demoted(self) -> int:
        return len(self.demotions)


class TieredKVAllocator:
    """Paged KV accounting across device HBM + pinned host memory.

    The device pool is the one the paged decode kernel indexes through block
    tables; the host pool absorbs the cold prefix of requests whose KV does
    not fit on device. Per-request refs are kept in token order.
    """

    def __init__(self, device_bytes: float, host_bytes: float,
                 pcfg: PageConfig):
        self.pcfg = pcfg
        self.device = PagedKVAllocator(max(int(device_bytes), 0), pcfg)
        self.host = HostKVPool(max(int(host_bytes), 0), pcfg)
        self._refs: dict[int, list[PageRef]] = {}

    # ---- queries -------------------------------------------------------------
    @property
    def page_bytes(self) -> int:
        return self.device.page_bytes

    def refs(self, rid: int) -> list[PageRef]:
        return list(self._refs.get(rid, []))

    def device_pages_of(self, rid: int) -> list[int]:
        return [r.page for r in self._refs.get(rid, []) if r.tier == DEVICE]

    def host_pages_of(self, rid: int) -> list[int]:
        return [r.page for r in self._refs.get(rid, []) if r.tier == HOST]

    def host_bytes_of(self, rid: int) -> int:
        return len(self.host_pages_of(rid)) * self.page_bytes

    def max_allocatable_tokens(self, include_host: bool = True) -> int:
        """Fig. 14's metric, lifted by the host tier."""
        pages = self.device.free_pages
        if include_host:
            pages += self.host.free_pages
        return pages * self.pcfg.page_size

    # ---- allocation ----------------------------------------------------------
    def alloc(self, rid: int, tokens: int, allow_host: bool = True
              ) -> list[PageRef] | None:
        """Reserve the whole allocation up front, device-preferred; overflow
        spills to the host tier at the *front* (oldest positions) so decode
        writes always hit device frames. None if the two tiers cannot hold
        it (nothing is claimed on failure)."""
        need = self.device.pages_for(tokens)
        n_host = max(need - self.device.free_pages, 0)
        if n_host > 0 and not allow_host:
            return None
        if n_host > self.host.free_pages:
            return None
        hp = self.host.alloc_pages(rid, n_host)
        dp = self.device.alloc_pages(rid, need - n_host)
        assert hp is not None and dp is not None
        refs = [PageRef(HOST, p) for p in hp] + [PageRef(DEVICE, p)
                                                 for p in dp]
        if refs:
            self._refs.setdefault(rid, []).extend(refs)
        return refs

    def extend(self, rid: int, new_total_tokens: int,
               allow_host: bool = True, on_demote=None
               ) -> list[Migration] | None:
        """Grow ``rid`` to ``new_total_tokens``. New (tail) pages must be
        device frames; if the device pool is exhausted, the request's own
        oldest device page is demoted to host to vacate a frame — which the
        very next tail allocation may recycle. A data plane holding real
        page buffers must therefore copy demoted pages out *synchronously*
        via ``on_demote(migration)``, which fires while the vacated frame is
        still unclaimed; the returned list is for traffic accounting only.
        None if the growth cannot be satisfied (nothing is changed then
        beyond already-performed demotions)."""
        have = len(self._refs.get(rid, []))
        need = self.device.pages_for(new_total_tokens) - have
        if need <= 0:
            return []
        migrations: list[Migration] = []
        added: list[int] = []

        def rollback():
            # undo this call's tail allocations so the refs list still
            # matches the request's token count (demotions stay: the data
            # plane may already have copied them)
            for p in reversed(added):
                self.device.release_pages(rid, [p])
                ref = self._refs[rid].pop()
                assert ref.tier == DEVICE and ref.page == p
            return None

        for _ in range(need):
            if self.device.free_pages == 0:
                if not allow_host:
                    return rollback()
                moved = self.swap_out(rid, 1)
                if not moved:
                    return rollback()
                if on_demote is not None:
                    for m in moved:
                        on_demote(m)
                migrations.extend(moved)
            dp = self.device.alloc_pages(rid, 1)
            assert dp is not None
            self._refs.setdefault(rid, []).append(PageRef(DEVICE, dp[0]))
            added.append(dp[0])
        return migrations

    def free(self, rid: int) -> None:
        self.device.free(rid)
        self.host.free(rid)
        self._refs.pop(rid, None)

    # ---- migration -----------------------------------------------------------
    def swap_out(self, rid: int, n_pages: int) -> list[Migration]:
        """Demote ``rid``'s ``n_pages`` oldest device pages to host. Returns
        the moves actually performed (host pool may fill up)."""
        moves: list[Migration] = []
        refs = self._refs.get(rid, [])
        for idx, ref in enumerate(refs):
            if len(moves) >= n_pages:
                break
            if ref.tier != DEVICE:
                continue
            hp = self.host.alloc_pages(rid, 1)
            if hp is None:
                break
            self.device.release_pages(rid, [ref.page])
            refs[idx] = PageRef(HOST, hp[0])
            moves.append(Migration(rid, DEVICE, ref.page, hp[0]))
        return moves

    def swap_in(self, rid: int, n_pages: int) -> list[Migration]:
        """Promote ``rid``'s ``n_pages`` oldest host pages back to device."""
        moves: list[Migration] = []
        refs = self._refs.get(rid, [])
        for idx, ref in enumerate(refs):
            if len(moves) >= n_pages:
                break
            if ref.tier != HOST:
                continue
            dp = self.device.alloc_pages(rid, 1)
            if dp is None:
                break
            self.host.release_pages(rid, [ref.page])
            refs[idx] = PageRef(DEVICE, dp[0])
            moves.append(Migration(rid, HOST, ref.page, dp[0]))
        return moves

    def can_resize_device(self, new_total_bytes: float) -> bool:
        """Would ``resize_device`` succeed? False when the shrink's overflow
        exceeds free host capacity (resize_device would raise)."""
        new_pages = max(int(new_total_bytes), 0) // self.page_bytes
        used = sum(len(self.device_pages_of(rid)) for rid in self._refs)
        return used - new_pages <= self.host.free_pages

    def resize_device(self, new_total_bytes: float) -> ResizeResult:
        """Rebuild the device pool for a new byte budget (the offloading
        interval changed the resident weight set). Existing device pages are
        re-assigned to fresh frames; overflow demotes host-ward, largest
        holders first. Returns the demotions and the old->new frame remap so
        a caller holding the physical page buffer can mirror the move
        (serving.engine copies demoted frames to the host pool and permutes
        the surviving frames in place).
        """
        if not self.can_resize_device(new_total_bytes):
            # validated up front so failure never leaves partial state
            raise RuntimeError("device KV overflow exceeds host capacity")
        old_used = {rid: len(self.device_pages_of(rid)) for rid in self._refs}
        new_dev = PagedKVAllocator(max(int(new_total_bytes), 0), self.pcfg)
        demand = sum(old_used.values())
        demotions: list[Migration] = []
        # shed overflow: take from the requests holding the most device pages
        while demand > new_dev.total_pages:
            over = demand - new_dev.total_pages
            rid = max(old_used, key=old_used.get)
            take = min(over, old_used[rid])
            hp = self.host.alloc_pages(rid, take)
            assert hp is not None and take > 0   # entry check guarantees room
            refs = self._refs[rid]
            moved = 0
            for idx, ref in enumerate(refs):
                if moved >= take:
                    break
                if ref.tier == DEVICE:
                    demotions.append(Migration(rid, DEVICE, ref.page,
                                               hp[moved]))
                    refs[idx] = PageRef(HOST, hp[moved])
                    moved += 1
            old_used[rid] -= take
            demand -= take
        # re-assign surviving device pages to fresh frames
        remap: list[tuple[int, int]] = []
        for rid, count in old_used.items():
            dp = new_dev.alloc_pages(rid, count)
            assert dp is not None
            it = iter(dp)
            refs = self._refs[rid]
            for idx, ref in enumerate(refs):
                if ref.tier == DEVICE:
                    new_frame = next(it)
                    remap.append((ref.page, new_frame))
                    refs[idx] = PageRef(DEVICE, new_frame)
        self.device = new_dev
        return ResizeResult(demotions=demotions, remap=remap)

    # ---- block tables --------------------------------------------------------
    def device_block_table(self, rid: int, max_pages: int) -> np.ndarray:
        """Block table for the paged decode kernel. Valid only when the
        request is fully device-resident (swap_in first). Raises when the
        request holds more pages than ``max_pages`` (truncation would drop
        context pages silently)."""
        refs = self._refs.get(rid, [])
        assert all(r.tier == DEVICE for r in refs), \
            "host-resident pages: swap_in before building the kernel table"
        return padded_block_table([r.page for r in refs], max_pages, rid)

    def check_invariants(self) -> None:
        self.device.check_invariants()
        self.host.check_invariants()
        for rid, refs in self._refs.items():
            dev = sorted(p for r in refs if r.tier == DEVICE
                         for p in [r.page])
            host = sorted(p for r in refs if r.tier == HOST
                          for p in [r.page])
            assert dev == sorted(self.device.pages_of(rid))
            assert host == sorted(self.host.pages_of(rid))


# ---------------------------------------------------------------------------
# Per-iteration swap planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SwapPlan:
    """Link traffic of one inference iteration's KV tier activity."""
    kv_in_bytes: float = 0.0      # host->device: promotions + streamed KV
    kv_out_bytes: float = 0.0     # device->host: demotions / spill write-back
    streamed_bytes: float = 0.0   # recurring share of kv_in (no residency change)
    promotions: list[Migration] = dataclasses.field(default_factory=list)


class SwapScheduler:
    """Decides, per iteration, which pages move between tiers.

    Policy: freed device frames are back-filled by promoting the oldest host
    pages of active requests (cheapest first: the request with the fewest
    host pages clears its streaming debt soonest); whatever stays on host is
    streamed in for attention each iteration. Demotions queued by interval
    changes or tail growth are charged as write-back traffic.
    """

    def __init__(self, kv: TieredKVAllocator):
        self.kv = kv
        self._pending_out_pages = 0

    def note_demotions(self, n_pages: int) -> None:
        """Register demotions performed by resize/extend since last plan."""
        self._pending_out_pages += n_pages

    def pending_out_bytes(self) -> float:
        """Write-back traffic already queued for the next iteration."""
        return self._pending_out_pages * self.kv.page_bytes

    def streamed_bytes(self, active_rids: list[int]) -> float:
        return float(sum(self.kv.host_bytes_of(r) for r in active_rids))

    def plan_iteration(self, active_rids: list[int]) -> SwapPlan:
        plan = SwapPlan()
        plan.kv_out_bytes = self._pending_out_pages * self.kv.page_bytes
        self._pending_out_pages = 0
        # promote into free device frames, cheapest request first
        order = sorted((r for r in active_rids if self.kv.host_pages_of(r)),
                       key=lambda r: len(self.kv.host_pages_of(r)))
        for rid in order:
            if self.kv.device.free_pages == 0:
                break
            moves = self.kv.swap_in(rid, self.kv.device.free_pages)
            plan.promotions.extend(moves)
            plan.kv_in_bytes += len(moves) * self.kv.page_bytes
        plan.streamed_bytes = self.streamed_bytes(active_rids)
        plan.kv_in_bytes += plan.streamed_bytes
        return plan
