"""Serving request lifecycle."""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class State(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int
    ttft_slo_s: float
    tpot_slo_s: float
    arrival_s: float = 0.0
    state: State = State.QUEUED
    # runtime
    slot: int = -1
    generated: list[int] = dataclasses.field(default_factory=list)
    ttft_s: float | None = None
    tpot_s: list[float] = dataclasses.field(default_factory=list)
    reject_reason: str = ""

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def metrics(self) -> dict:
        tpot = float(np.mean(self.tpot_s)) if self.tpot_s else 0.0
        return {
            "rid": self.rid,
            "ttft_s": self.ttft_s,
            "tpot_mean_s": tpot,
            "tpot_p99_s": float(np.quantile(self.tpot_s, 0.99))
            if self.tpot_s else 0.0,
            "ttft_ok": self.ttft_s is not None and self.ttft_s
            <= self.ttft_slo_s * (1 + 1e-9),
            "tpot_ok": all(t <= self.tpot_slo_s * (1 + 1e-9)
                           for t in self.tpot_s),
            "tokens": len(self.generated),
        }
