"""Serving request lifecycle."""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class State(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    # Preempt-to-host: the scheduler parked this request's entire KV on the
    # host tier to vacate device frames (and its streaming traffic) for a
    # blocked admission; it resumes decoding — token-exactly — once capacity
    # and the TPOT budget allow.
    PREEMPTED = "preempted"
    FINISHED = "finished"
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int
    ttft_slo_s: float
    tpot_slo_s: float
    arrival_s: float = 0.0
    # multi-tenant traces: sessions of the same tenant share a per-tenant
    # system prefix, so same-tenant prompts carry identical leading
    # ``prefix_page_keys`` — the fleet router's affinity signal
    tenant: int = 0
    state: State = State.QUEUED
    # runtime
    slot: int = -1
    generated: list[int] = dataclasses.field(default_factory=list)
    ttft_s: float | None = None
    tpot_s: list[float] = dataclasses.field(default_factory=list)
    reject_reason: str = ""
    # chunked prefill: tokens of the prompt whose KV has been computed and
    # scattered so far; TTFT accrues per chunk into ttft_accum_s until the
    # final chunk lands (prefill_pos == prompt_len) and sets ttft_s.
    prefill_pos: int = 0
    ttft_accum_s: float = 0.0
    # preempt-to-host resume snapshot: the sampled-but-not-yet-decoded token
    # and the write position, restored verbatim when the request is resumed.
    next_token: int = -1
    resume_pos: int = 0
    preempt_count: int = 0
    # modeled clock spent parked (inter-token stall the per-iteration TPOT
    # samples deliberately do NOT include — reported separately so a parked
    # request's starvation is visible, not hidden inside a passing tpot_ok)
    preempt_stall_s: float = 0.0
    parked_at_s: float | None = None
    # queueing-delay accounting (modeled clock)
    submitted_s: float | None = None
    admitted_s: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def queue_delay_s(self) -> float | None:
        if self.submitted_s is None or self.admitted_s is None:
            return None
        return self.admitted_s - self.submitted_s

    @property
    def ttft_e2e_s(self) -> float | None:
        """End-to-end first-token latency on the modeled clock: queueing
        delay (from the arrival process — ``engine.run`` stamps
        ``submitted_s = arrival_s`` when honoring arrivals) plus the prefill
        latency ``ttft_s``. The SLO check stays on ``ttft_s`` (the bound the
        scheduler certifies at admission); this is the user-visible number
        the sustained-load bench reports alongside it."""
        if self.queue_delay_s is None or self.ttft_s is None:
            return None
        return self.queue_delay_s + self.ttft_s

    def metrics(self) -> dict:
        tpot = float(np.mean(self.tpot_s)) if self.tpot_s else 0.0
        return {
            "rid": self.rid,
            "ttft_s": self.ttft_s,
            "tpot_mean_s": tpot,
            "tpot_p99_s": float(np.quantile(self.tpot_s, 0.99))
            if self.tpot_s else 0.0,
            "ttft_ok": self.ttft_s is not None and self.ttft_s
            <= self.ttft_slo_s * (1 + 1e-9),
            "tpot_ok": all(t <= self.tpot_slo_s * (1 + 1e-9)
                           for t in self.tpot_s),
            "tokens": len(self.generated),
            "preempts": self.preempt_count,
            "preempt_stall_s": self.preempt_stall_s,
            "queue_delay_s": self.queue_delay_s,
            "ttft_e2e_s": self.ttft_e2e_s,
        }
