"""Scheduling policy for the serving engine: per-iteration plans.

The engine used to fuse policy and execution — ``_admit``/``_spill_admit``/
``_prefill_into_slot``/``step`` all mutated shared slot state, so scheduling
policies (preempt-to-host, chunked prefill) could not land without touching
the data plane. This module is the policy half of that split:

  * ``Scheduler`` owns the request queue, the preempted set, and slot
    assignment. Once per engine iteration it emits an ``IterationPlan`` —
    admissions, prefill chunks, preemptions, resumes, decode slots — from a
    ``SchedulerView`` snapshot of executor state.
  * ``ServingEngine`` (serving.engine) is the executor: it applies the plan
    (page copies, prefill compute + scatter, the paged decode kernel, the
    modeled clock) and reports an ``IterationOutcome`` back via
    ``note_outcome``.

Division of labour, vLLM-style: the scheduler owns the *accounting plane* —
it calls ``TieredKVAllocator.alloc/park/resume`` during planning so each
decision sees the pool state its predecessors left (admission N+1 must see
admission N's pages, an admission after a preemption must see the freed
frames). The executor owns the *data plane*: every physical page byte moves
when the plan is applied, in plan order (park write-backs land before any
freed frame is re-written).

Policies shipped on the contract:

  * **FIFO with whole-queue scan** (default): a memory-infeasible request no
    longer head-of-line blocks the queue — later requests that fit are
    admitted this iteration; the skipped request retries next iteration.
    SLO-infeasible and over-length requests are still rejected outright.
  * **Preempt-to-host** (``SchedulerConfig.preemption``): when a queued
    request cannot be admitted even via host spill, an active victim's
    entire KV is parked on the host tier (``TieredKVAllocator.park`` —
    frame-wise, so shared prefix pages a live sibling still uses don't
    move) and the request takes its place. Parked requests resume — token
    exactly — with priority over new admissions, once a slot is free and
    their streaming/promotion traffic fits the TPOT budget; resume copy
    bytes are charged to the link like any other KV traffic.
  * **Chunked prefill** (``SchedulerConfig.prefill_chunk_tokens``): long
    prompts prefill in page-aligned chunks piggybacked on decode iterations
    instead of stalling the batch; TTFT accrues per chunk.
  * **Disk-tier demotion** (three-tier allocator with ``disk_bytes > 0``):
    under host pressure, instead of refusing a park (or evicting prefix
    cache), the HOST pages of long-parked preempted requests — oldest park
    first, never frames an active sibling streams — retire to the NVMe
    tier (``TieredKVAllocator.demote_to_disk``), and resume stages them
    disk->host->device. NVMe traffic is charged to the disk link's own
    term of ``iter_time_with_interval_kv`` in every feasibility check; it
    never rides the TPOT-critical PCIe budget unmodeled.

With both policies off, the plans preserve the fused engine's admission
semantics up to two deliberate, always-on fixes shipped with the split —
the whole-queue FIFO scan (no head-of-line starvation) and the TPOT
cross-check of existing link traffic on the device admission path. On the
existing differential traces (loose SLOs, homogeneous queues) both fixes
are no-ops, and the suite locksteps the scheduler-driven engine against
the frozen dense reference on the PR-2/PR-3 traces unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.interval import NO_OFFLOAD, iter_time_with_interval_kv
from repro.serving.kv_offload import (HOST, Migration, SwapScheduler,
                                      TieredKVAllocator)
from repro.serving.request import Request, State


@dataclasses.dataclass
class SchedulerConfig:
    # Preempt-to-host: park an active victim's whole KV on the host tier to
    # unblock an admission the wait-only policy would stall on.
    preemption: bool = False
    # Chunked prefill: > 0 enables; rounded up to a page multiple so chunk
    # boundaries align with KV pages. 0 = one-shot prefill at admission
    # (the legacy path the differential suite locksteps).
    prefill_chunk_tokens: int = 0
    # Queue policy. "fifo" is the only built-in: arrival order with a
    # whole-queue scan (memory-infeasible requests are skipped, not blocking).
    policy: str = "fifo"


@dataclasses.dataclass
class ActiveInfo:
    """One decoding slot as the scheduler sees it."""
    req: Request
    slot: int

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def tpot_slo_s(self) -> float:
        return self.req.tpot_slo_s

    @property
    def remaining(self) -> int:
        return self.req.max_new_tokens - len(self.req.generated)


@dataclasses.dataclass
class SchedulerView:
    """Read-only snapshot of executor state for one planning pass."""
    interval: int
    free_slots: list[int]          # slots with no request installed
    active: list[ActiveInfo]       # decoding slots (not prefilling ones)


@dataclasses.dataclass
class PlannedAdmission:
    req: Request
    slot: int
    # KV accounting already performed by the scheduler (alloc); the executor
    # runs prefill compute + scatter. chunked=True defers the compute to
    # PrefillChunk entries instead of a one-shot prefill.
    chunked: bool = False
    # TTFT the admission check certified (ttft_model under the spill
    # write-back claimed at alloc time); None for chunked admissions, whose
    # TTFT accrues across the iterations their chunks ride. The trace
    # auditor checks observed TTFT against this bound.
    certified_ttft_s: float | None = None


@dataclasses.dataclass
class PrefillChunk:
    """Prefill tokens [start, end) of ``req`` this iteration, piggybacked on
    the decode step. ``start`` is page-aligned; the final chunk ends at the
    prompt length and emits the request's first token."""
    req: Request
    slot: int
    start: int
    end: int

    @property
    def final(self) -> bool:
        return self.end >= self.req.prompt_len


@dataclasses.dataclass
class PlannedPreemption:
    req: Request
    slot: int
    migrations: list[Migration]    # accounting moves already applied


@dataclasses.dataclass
class PlannedResume:
    req: Request
    slot: int
    migrations: list[Migration]    # host->device promotions already applied


@dataclasses.dataclass
class IterationPlan:
    """What the executor must apply this iteration, in PLANNING order:
    resumes first (their promotion copies must read host slots before a
    later-planned park reuses them), then preemption write-backs (they
    vacate device frames admissions may re-write), then admissions, prefill
    chunks, and the decode step."""
    target_interval: int
    preemptions: list[PlannedPreemption] = dataclasses.field(
        default_factory=list)
    resumes: list[PlannedResume] = dataclasses.field(default_factory=list)
    admissions: list[PlannedAdmission] = dataclasses.field(
        default_factory=list)
    chunks: list[PrefillChunk] = dataclasses.field(default_factory=list)
    rejections: list[Request] = dataclasses.field(default_factory=list)
    decode_slots: list[int] = dataclasses.field(default_factory=list)
    # Upper bound on this iteration's decode latency, computed at plan time
    # from the traffic the plan left pending (streamed + promotion debt in,
    # write-back debt out, NVMe pendings, chunk piggyback seconds). The
    # executor's dt can only come in at or under this, modulo bytes that
    # provably arrive after planning (COW copies, chunk host-spill
    # write-backs, pages a same-plan one-shot prefill spilled to host) —
    # the trace auditor enforces exactly that bound. None when the plan
    # has no decode slots.
    certified_dt_s: float | None = None
    # The PCIe byte totals certified_dt_s was derived from. The executor
    # charges any excess of its actual traffic over these as uncertified
    # slack, so the auditor can hold dt to certified + excess/link_bw.
    certified_kv_in_bytes: float = 0.0
    certified_kv_out_bytes: float = 0.0


@dataclasses.dataclass
class IterationOutcome:
    """The executor's report after applying a plan."""
    dt_s: float                    # modeled iteration latency (0 if idle)
    finished_rids: list[int] = dataclasses.field(default_factory=list)
    tokens_emitted: int = 0
    chunks_run: int = 0
    preemptions: int = 0
    resumes: int = 0


class Scheduler:
    """Queue + slot-assignment policy over a ``TieredKVAllocator``.

    Constructed with the executor's accounting handles and SLO models:
    ``rec_decode.lookup`` (performance record §4.4), ``times_fn`` (analytic
    layer times), ``ttft_model`` (modeled prefill latency incl. spill
    write-back), and ``max_interval_fn`` (memory-bounded interval ceiling
    under current KV usage). All are plain callables so policy unit tests
    can stub them without building an engine.
    """

    def __init__(self, kv: TieredKVAllocator, swap: SwapScheduler,
                 max_batch: int, max_seq: int,
                 rec_decode, times_fn: Callable,
                 ttft_model: Callable[[Request, float], float],
                 max_interval_fn: Callable[[], int],
                 scfg: SchedulerConfig = SchedulerConfig(),
                 prefill_seconds: Callable[[int], float] | None = None):
        self.kv = kv
        self.swap = swap
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.rec_decode = rec_decode
        self.times_fn = times_fn
        self.ttft_model = ttft_model
        self.max_interval_fn = max_interval_fn
        # the executor's chunk cost model (engine._prefill_seconds) is
        # injected so the seconds the scheduler certifies in TPOT checks
        # are exactly the seconds the executor charges to the clock; the
        # fallback (standalone/unit-test construction) applies the same
        # no-offload stack-time formula
        self.prefill_seconds = prefill_seconds or (
            lambda tokens: self.times_fn(1, tokens, "prefill")
            .t_iter_no_offload_s if tokens > 0 else 0.0)
        self.cfg = scfg
        if scfg.prefill_chunk_tokens > 0:
            page = kv.pcfg.page_size
            self.chunk_tokens = -(-scfg.prefill_chunk_tokens // page) * page
        else:
            self.chunk_tokens = 0
        self.queue: list[Request] = []
        self.preempted: list[Request] = []
        self._prefilling: list[Request] = []   # chunked prefills in flight
        self.stats = {"iterations": 0, "tokens": 0, "preemptions": 0,
                      "resumes": 0, "chunked_prefill_iters": 0,
                      "disk_demotions": 0, "disk_stagings": 0,
                      "migrations_out": 0, "migrations_in": 0}
        self._iv = NO_OFFLOAD                  # interval of the current plan
        self.last_dt_s = 0.0                   # last nonzero observed dt
        # disaggregated prefill role: parked requests are held for peer
        # handoff instead of resuming locally (the fleet exports them at
        # the next boundary; clearing the flag restores the ordinary
        # priority-resume path as a graceful fallback)
        self.hold_resumes = False

    # ------------------------------------------------------------- queue I/O --
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue or self.preempted or self._prefilling)

    # ------------------------------------------------- cross-instance moves --
    def take_preempted(self, rid: int) -> Request | None:
        """Remove a parked request from this scheduler's preempted set (the
        fleet is exporting it to a peer instance). Returns the request, or
        None if ``rid`` is not parked here."""
        for req in self.preempted:
            if req.rid == rid:
                self.preempted.remove(req)
                self.stats["migrations_out"] += 1
                return req
        return None

    def adopt_parked(self, req: Request) -> None:
        """Adopt a request migrated in from a peer instance. It joins the
        preempted set — parked, host-resident — and resumes through the
        ordinary ``_plan_resumes`` priority path, token-exactly, from the
        ``next_token``/``resume_pos`` snapshot it carried over."""
        self.preempted.append(req)
        self.stats["migrations_in"] += 1

    def withdraw(self, rid: int) -> Request | None:
        """Remove a still-QUEUED request (never admitted — no KV claimed,
        nothing to roll back) so the fleet router can re-bind its route to
        a peer at an iteration boundary. Returns the request, or None if
        ``rid`` is not waiting in this scheduler's queue."""
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                return req
        return None

    def certify_handoff(self, n_pages: int, tpot_slo_s: float,
                        active: list[ActiveInfo]) -> bool:
        """Would adopting a live post-prefill handoff of ``n_pages`` keep
        every TPOT budget on THIS (decode) side? The import's bytes ride
        the peer link and drain into the next iteration's ``peer_s`` term
        — certified here exactly the way NVMe staging is certified in
        ``_resume_feasible``: host room first (free + prefix-cache reclaim
        + disk-demotable capacity), then the modeled iteration time with
        the prospective peer-in pages folded in, against the tightest TPOT
        among the active set and the arriving request. The fleet offers a
        handoff ticket only after this returns True — a refusal leaves the
        request parked on the prefill side (nothing moves)."""
        room = (self.kv.host.free_pages + self.kv.reclaimable_host_pages()
                + self._demotable_to_disk([a.rid for a in active]))
        if n_pages > room:
            return False
        if not active:
            # starvation guard, as in _resume_feasible: an idle decode
            # instance always absorbs the handoff — the transfer is its
            # only work
            return True
        kv_in_now = (self.swap.streamed_bytes([a.rid for a in active])
                     + self.swap.pending_in_bytes())
        dt = self._iter_dt(len(active), kv_in_now,
                           self.swap.pending_out_bytes(),
                           self._chunk_overhead_s(),
                           extra_peer_in_pages=n_pages)
        bound = min([a.tpot_slo_s for a in active] + [tpot_slo_s])
        return dt <= bound * (1 + 1e-9)

    # -------------------------------------------------------------- planning --
    def plan(self, view: SchedulerView) -> IterationPlan:
        self._iv = view.interval if view.interval else NO_OFFLOAD
        plan = IterationPlan(target_interval=view.interval)
        free_slots = sorted(view.free_slots)
        active = list(view.active)

        self._plan_resumes(plan, active, free_slots)
        self._plan_admissions(plan, active, free_slots)
        self._plan_chunks(plan)

        # non-chunked admissions were appended to `active` as they were
        # planned (they decode this same iteration, like the fused engine)
        plan.decode_slots = sorted(a.slot for a in active)
        if active:
            # certify the decode latency this plan implies: promotions +
            # residual streaming together are exactly one pass over the
            # active requests' host pages however the swap scheduler splits
            # it, so streamed-now + pending promotion debt upper-bounds the
            # executor's kv_in (post-plan frees only shrink it)
            rids = [a.rid for a in active]
            plan.certified_kv_in_bytes = (self.swap.streamed_bytes(rids)
                                          + self.swap.pending_in_bytes())
            plan.certified_kv_out_bytes = self.swap.pending_out_bytes()
            plan.certified_dt_s = self._iter_dt(
                len(active), plan.certified_kv_in_bytes,
                plan.certified_kv_out_bytes, self._chunk_overhead_s())
        return plan

    def note_outcome(self, outcome: IterationOutcome) -> None:
        self.stats["iterations"] += 1
        self.stats["tokens"] += outcome.tokens_emitted
        self.stats["preemptions"] += outcome.preemptions
        self.stats["resumes"] += outcome.resumes
        self.stats["chunked_prefill_iters"] += int(outcome.chunks_run > 0)
        if outcome.dt_s > 0:
            self.last_dt_s = outcome.dt_s

    # ------------------------------------------------------------- disk tier --
    def _iter_dt(self, n_active: int, kv_in: float, kv_out: float,
                 chunk_s: float = 0.0, extra_disk_in_pages: int = 0,
                 extra_disk_out_pages: int = 0,
                 extra_peer_in_pages: int = 0,
                 extra_peer_out_pages: int = 0) -> float:
        """Modeled next-iteration latency under the given PCIe KV traffic
        PLUS the disk link's own term — NVMe bytes already pending at the
        allocator and any prospective staging/demotion pages the caller is
        about to cause — PLUS the peer link's term for handoff traffic
        (pending imports/exports and any prospective handoff the caller is
        certifying). Disk and peer traffic never ride the PCIe budget, but
        a feasibility check that ignored either would certify TPOTs that
        channel's queue then breaks."""
        link = self.kv.disk_link
        plink = self.kv.peer_link
        pb = self.kv.page_bytes
        times = self.times_fn(n_active, self.max_seq, "decode")
        return iter_time_with_interval_kv(
            times, self._iv, kv_in, kv_out,
            disk_in_bytes=self.swap.pending_disk_in_bytes()
            + extra_disk_in_pages * pb,
            disk_out_bytes=self.swap.pending_disk_out_bytes()
            + extra_disk_out_pages * pb,
            disk_bw=link.bw_bytes_s,
            disk_latency_s=link.latency_s,
            peer_in_bytes=self.swap.pending_peer_in_bytes()
            + extra_peer_in_pages * pb,
            peer_out_bytes=self.swap.pending_peer_out_bytes()
            + extra_peer_out_pages * pb,
            peer_bw=plink.bw_bytes_s,
            peer_latency_s=plink.latency_s) + chunk_s

    def _demotable_to_disk(self, active_rids: list[int],
                           exclude_rid: int | None = None,
                           include_rids=(), pinned=()) -> int:
        """Host frames the disk tier could absorb right now: unique HOST
        frames of parked requests (oldest park first) — plus those of
        ``include_rids`` (a victim about to be parked: once it parks, its
        spilled pages are cold too) — that no active sibling references
        and that are not ``pinned`` (a dedup preview's hit frames:
        ``_free_host_via_disk`` will refuse to move them, so counting
        them would certify capacity that cannot be freed), capped by
        free + reclaimable disk capacity. Zero without a disk tier."""
        if self.kv.disk.total_pages == 0:
            return 0
        hot = self.kv.hot_pages(active_rids, HOST) | set(pinned)
        frames: set[int] = set()
        rids = [r.rid for r in self.preempted if r.rid != exclude_rid]
        rids += [r for r in include_rids if r not in rids]
        for rid in rids:
            frames.update(p for p in self.kv.host_pages_of(rid)
                          if p not in hot)
            frames.update(res.page
                          for res in self.kv.reserves_of(rid).values()
                          if res.tier == HOST and res.page not in hot)
        room = self.kv.disk.free_pages + self.kv.reclaimable_disk_pages()
        return min(len(frames), room)

    def _free_host_via_disk(self, n_pages: int, active_rids: list[int],
                            exclude_rid: int | None = None,
                            also_rids=(), keep=(),
                            keep_disk: set[int] | None = None,
                            youngest_first: bool = False) -> int:
        """Make host room by demoting parked preempted requests' host
        pages to the disk tier — the policy that replaces "refuse the park
        / evict the cache" under host pressure. Park/admission pressure
        takes the LONGEST-parked first (oldest pays the NVMe round trip:
        it resumes last anyway); a resume staging takes the YOUNGEST first
        (oldest work wins the host tier — demoting the next-to-resume
        would bounce its pages straight back). ``also_rids`` go last (a
        victim whose park is being arranged: its spilled pages were hot a
        moment ago); ``keep``/``keep_disk`` protect a caller's
        dedup-preview frames from moving under the allocation they
        certify. Returns the pages actually freed; NVMe write-back bytes
        are accumulated at the allocator and charged to the disk term of
        the next iteration."""
        freed = 0
        parked = [r.rid for r in self.preempted if r.rid != exclude_rid]
        if youngest_first:
            parked.reverse()
        rids = parked + [r for r in also_rids if r not in parked]
        for rid in rids:
            if freed >= n_pages:
                break
            moves = self.kv.demote_to_disk(rid, n_pages - freed,
                                           active_rids, keep=keep,
                                           keep_disk=keep_disk)
            freed += len(moves)
            self.stats["disk_demotions"] += len(moves)
        return freed

    # --------------------------------------------------------------- resumes --
    def _plan_resumes(self, plan: IterationPlan, active: list[ActiveInfo],
                      free_slots: list[int]) -> None:
        """Parked requests re-enter with priority over new admissions (they
        are the oldest work in the system), as soon as a slot is free and
        the worst case of their return traffic — every still-host page
        streamed or promoted next iteration, plus the NVMe staging of any
        disk-demoted pages — fits every TPOT budget. A disk-parked request
        whose staging cannot fit the host tier first pushes YOUNGER parked
        requests' pages down to disk (oldest work wins the host tier)."""
        if self.hold_resumes:
            # prefill-role instance: its parked set is the handoff staging
            # area, not resume candidates — decode belongs to a peer
            return
        for req in list(self.preempted):
            if not free_slots:
                return
            if not self._resume_feasible(req, active):
                continue
            n_disk = self.kv.parked_disk_pages(req.rid)
            short = self.kv.resume_staging_shortfall(req.rid)
            if short > 0:
                # youngest parked first: oldest work wins the host tier
                self._free_host_via_disk(short, [a.rid for a in active],
                                         exclude_rid=req.rid,
                                         youngest_first=True)
            moves = self.kv.resume(req.rid)
            if moves is None:
                continue                     # host cannot stage: stay parked
            if n_disk:
                self.stats["disk_stagings"] += n_disk
            # only HOST-sourced promotions ride the PCIe link; direct
            # disk->device stagings charge the NVMe term alone (their
            # host-transit bytes were never moved, so never billed)
            self.swap.note_promotions(
                sum(1 for m in moves if m.src_tier == HOST))
            slot = free_slots.pop(0)
            self.preempted.remove(req)
            plan.resumes.append(PlannedResume(req, slot, moves))
            active.append(ActiveInfo(req, slot))

    def _resume_feasible(self, req: Request, active: list[ActiveInfo]
                         ) -> bool:
        if not active:
            # starvation guard: with nothing else decoding, the resumed
            # request is the system's only work — resume unconditionally
            # rather than stall forever on its own one-time return spike
            return True
        n_disk = self.kv.parked_disk_pages(req.rid)
        shortfall = self.kv.resume_staging_shortfall(req.rid)
        if shortfall > self._demotable_to_disk([a.rid for a in active],
                                               exclude_rid=req.rid):
            return False                     # NVMe staging cannot land
        host_pages = set(self.kv.host_pages_of(req.rid))
        streamed = self.swap.streamed_host_pages([a.rid for a in active])
        # next iteration's kv_in is promotion copies + remaining streaming —
        # together exactly one pass over the union, however the swap
        # scheduler splits it; later iterations are strictly cheaper. Disk
        # pages stage to host first, so they join the same worst-case pass
        # AND charge the NVMe term — reads for the staging itself plus the
        # write-backs of the shortfall demotions it will trigger.
        kv_in = ((len(streamed | host_pages) + n_disk)
                 * self.kv.page_bytes + self.swap.pending_in_bytes())
        dt = self._iter_dt(len(active) + 1, kv_in,
                           self.swap.pending_out_bytes(),
                           self._chunk_overhead_s(),
                           extra_disk_in_pages=n_disk,
                           extra_disk_out_pages=shortfall)
        bound = min([a.tpot_slo_s for a in active] + [req.tpot_slo_s])
        return dt <= bound * (1 + 1e-9)

    # ------------------------------------------------------------ admissions --
    def _plan_admissions(self, plan: IterationPlan,
                         active: list[ActiveInfo],
                         free_slots: list[int]) -> None:
        for req in list(self.queue):
            if not free_slots:
                return
            total = req.prompt_len + req.max_new_tokens
            if total > self.max_seq:
                req.state = State.REJECTED
                req.reject_reason = "exceeds max_seq"
                self.queue.remove(req)
                plan.rejections.append(req)
                continue
            # SLO feasibility (paper §4.2: pass back to upper scheduler)
            min_i = self.rec_decode.lookup(req.tpot_slo_s,
                                           len(active) + 1, total)
            max_i = self.max_interval_fn()
            if min_i > max_i:
                req.state = State.REJECTED
                req.reject_reason = (f"SLO infeasible: min interval {min_i} "
                                     f"> max {max_i}")
                self.queue.remove(req)
                plan.rejections.append(req)
                continue
            chunked = (self.chunk_tokens > 0
                       and req.prompt_len > 0)
            chunked_bound = None
            if chunked:
                # TTFT feasibility must model the CHUNK SCHEDULE, not a
                # one-shot prefill: the prompt rides ceil(plen/chunk)
                # consecutive iterations and TTFT accrues their latencies.
                # A structurally infeasible request (even an idle system
                # cannot meet its SLO) is rejected outright, like the
                # interval check above; a request whose bound only breaks
                # under today's transient traffic waits instead.
                if (self._chunked_ttft_floor(req)
                        > req.ttft_slo_s * (1 + 1e-9)):
                    req.state = State.REJECTED
                    req.reject_reason = ("chunked TTFT floor exceeds SLO: "
                                         f"{req.prompt_len} tokens / "
                                         f"{self.chunk_tokens}-token chunks")
                    self.queue.remove(req)
                    plan.rejections.append(req)
                    continue
                chunked_bound = self._chunked_ttft_bound(req, active)
                if chunked_bound > req.ttft_slo_s * (1 + 1e-9):
                    continue          # transient traffic: retry next iter
            if not self._try_admit_mem(req, total, active):
                if not (self.cfg.preemption
                        and self._try_preempt_for(req, total, active,
                                                  free_slots, plan)):
                    # memory-infeasible NOW: skip, do not head-of-line block
                    # — a later (shorter) request may still fit this
                    # iteration; this one retries next iteration
                    continue
            slot = free_slots.pop(0)
            self.queue.remove(req)
            adm = PlannedAdmission(req, slot, chunked=chunked)
            if not chunked:
                # stamp the TTFT this admission was certified under — the
                # same ttft_model call, over the spill write-back the alloc
                # just claimed, that the executor charges at prefill time
                adm.certified_ttft_s = self.ttft_model(
                    req, self.kv.spill_writeback_bytes_of(req.rid))
            else:
                # the per-chunk piggyback schedule this admission was
                # certified under (the executor accrues real chunk dts
                # into ttft_accum_s against this bound)
                adm.certified_ttft_s = chunked_bound
            plan.admissions.append(adm)
            if chunked:
                self._prefilling.append(req)
                req.slot = slot       # chunks planned below need the slot
            elif req.max_new_tokens > 1:
                # a one-token budget is satisfied by the prefill itself:
                # the slot never activates, so it must not plan as decoding
                active.append(ActiveInfo(req, slot))

    def _try_admit_mem(self, req: Request, total: int,
                       active: list[ActiveInfo]) -> bool:
        """Claim the KV for ``req`` if memory + SLO budgets allow: device
        pool first, host spill (§4.2 extended) second. Either way the
        iteration the request joins already carries KV traffic (siblings'
        streamed pages, queued write-backs, resume promotion copies) — the
        fused engine only TPOT-checked that traffic on the spill path, so a
        tight-TPOT request could be admitted on device into an iteration
        another request's streaming had already pushed past its SLO."""
        kv_in_now = (self.swap.streamed_bytes([a.rid for a in active])
                     + self.swap.pending_in_bytes())
        kv_out_now = self.swap.pending_out_bytes()
        chunk_s = self._chunk_overhead_s(req)
        disk_now = (self.swap.pending_disk_in_bytes()
                    + self.swap.pending_disk_out_bytes())
        if kv_in_now or kv_out_now or chunk_s or disk_now:
            dt = self._iter_dt(len(active) + 1, kv_in_now, kv_out_now,
                               chunk_s)
            slos = [a.tpot_slo_s for a in active] + [req.tpot_slo_s]
            if dt > min(slos) * (1 + 1e-9):
                return False               # current KV traffic breaks TPOT
        if self.kv.alloc(req.rid, total, allow_host=False,
                         prompt=req.prompt) is not None:
            return True
        return self._try_spill_admit(req, total, active)

    def _try_spill_admit(self, req: Request, total: int,
                         active: list[ActiveInfo]) -> bool:
        """§4.2 admission, extended for the host KV tier: the device pool is
        full, but the request can be admitted with its cold prefix on host —
        provided the streamed KV traffic keeps every active request's TPOT
        and the new request's TTFT feasible at the current interval. The
        stream rides the same link as weight prefetch, so feasibility is
        evaluated with the combined-traffic iteration time.

        Prefix-dedup savings are accounted here: pages the prompt shares
        with live frames claim no new capacity, shared host pages already
        streamed for an active sibling add no link traffic, and dedup'd
        pages need no spill write-back during prefill."""
        kv = self.kv
        active_rids = [a.rid for a in active]
        pv = kv.dedup_preview(req.prompt, total)
        n_fresh = (kv.device.pages_for(total) - pv.n_hits
                   + int(pv.need_reserve))
        n_host = max(n_fresh - kv.device.free_pages, 0)
        n_revive = len(pv.disk_hit_pages())
        host_room = (kv.host.free_pages + kv.reclaimable_host_pages()
                     + self._demotable_to_disk(
                         active_rids, pinned=pv.host_hit_pages()))
        if n_host + n_revive > host_room:
            return False                       # no host room: wait
        if n_host <= 0 and not pv.host_hit_pages() and not n_revive:
            # cannot happen in the synchronous engine: alloc(allow_host=
            # False) fails exactly when fresh pages overflow to host or a
            # hit is host-resident, and nothing mutates between that call
            # and this recomputation. Kept as a defensive wait (not an
            # assert) so an accounting bug degrades to queueing, never to
            # an unchecked host admission.
            return False
        pb = kv.page_bytes
        # unique host frames after admission: currently streamed ∪ shared
        # host hits, plus the freshly spilled pages and revived disk hits
        streamed_pages = self.swap.streamed_host_pages(active_rids)
        streamed_after = (len(streamed_pages | pv.host_hit_pages())
                          + n_host + n_revive) * pb \
            + self.swap.pending_in_bytes()
        # prospective NVMe traffic: demotions that make the host room plus
        # the disk-hit revival reads — charged to the disk term up front
        shortfall = max(n_host + n_revive - kv.host.free_pages
                        - kv.reclaimable_host_pages(), 0)
        dt = self._iter_dt(len(active) + 1, streamed_after,
                           self.swap.pending_out_bytes(),
                           self._chunk_overhead_s(req),
                           extra_disk_in_pages=n_revive,
                           extra_disk_out_pages=shortfall)
        slos = [a.tpot_slo_s for a in active]
        tpot_bound = min(slos + [req.tpot_slo_s])
        if dt > tpot_bound * (1 + 1e-9):
            return False                       # streaming would break TPOT
        if self.ttft_model(req, n_host * pb) > req.ttft_slo_s * (1 + 1e-9):
            return False                       # spill write-back breaks TTFT
        if shortfall > 0:
            # host pressure: push long-parked requests' pages down to NVMe
            # instead of letting the admission wait. The preview's hit
            # frames are pinned — demoting or evicting one would leave the
            # alloc below holding dangling references
            self._free_host_via_disk(shortfall, active_rids,
                                     keep=pv.host_hit_pages(),
                                     keep_disk=pv.disk_hit_pages())
        refs = kv.alloc(req.rid, total, allow_host=True,
                        prompt=req.prompt, preview=pv)
        if refs is None:
            return False                       # room-making fell short: wait
        return True

    # ------------------------------------------------------------ preemption --
    def _victim_pool(self, active: list[ActiveInfo]) -> list[ActiveInfo]:
        """Only requests that are genuinely decoding are parkable: a request
        admitted or resumed earlier in this same plan has no decode cursor
        (or just paid its return trip) — parking it would snapshot garbage
        (or thrash)."""
        return [a for a in active if a.req.state == State.DECODING]

    def _select_victim(self, active: list[ActiveInfo]) -> ActiveInfo | None:
        """Victim policy: largest recurring streaming burden first (parking
        it relieves the link every subsequent iteration), then most
        remaining decode work (least sunk progress is stalled), then most
        TPOT headroom (deadline-aware: the request whose budget the last
        observed iteration dented least absorbs the park stall safest),
        then the latest-arrived (highest rid) — FIFO-respecting."""
        cands = self._victim_pool(active)
        if not cands:
            return None
        return max(cands, key=lambda a: (len(self.kv.host_pages_of(a.rid)),
                                         a.remaining,
                                         a.tpot_slo_s - self.last_dt_s,
                                         a.rid))

    def _preempt_could_help(self, req: Request, total: int,
                            active: list[ActiveInfo]) -> bool:
        """Best case (every active parked): would the admission fit? Parking
        cannot fix a TTFT-infeasible spill, so check that bound too before
        disturbing anyone."""
        kv = self.kv
        pv = kv.dedup_preview(req.prompt, total)
        n_fresh = (kv.device.pages_for(total) - pv.n_hits
                   + int(pv.need_reserve))
        freeable = 0
        pool = self._victim_pool(active)
        rids = [a.rid for a in pool]
        for a in pool:
            n_free, _ = kv.park_preview(a.rid,
                                        [r for r in rids if r != a.rid])
            freeable += n_free
        if n_fresh > kv.device.free_pages + freeable:
            return False
        return self.ttft_model(req, 0.0) <= req.ttft_slo_s * (1 + 1e-9)

    def _try_preempt_for(self, req: Request, total: int,
                         active: list[ActiveInfo], free_slots: list[int],
                         plan: IterationPlan) -> bool:
        """Park AT MOST ONE victim — the top-ranked one — and only when
        that single park provably unblocks ``req``; an admission that would
        need several victims' frames waits instead (conservative by
        design: multi-victim sprees are where park/resume churn lives).

        Anti-thrash guards: a victim is only parked when (a) its recurring
        host-streaming burden strictly exceeds the spill shortfall the
        incoming request would add — equal-burden requests never park each
        other, and pure capacity-motivated eviction is a wait, not a park
        (FIFO admission order already gave the running victim its claim) —
        and (b) a dry-run certifies that the admission clears its memory,
        TPOT and TTFT checks once the victim is gone. No partial parking
        sprees: if one park cannot unblock the request, nobody is parked
        and the request waits."""
        if not self._preempt_could_help(req, total, active):
            return False
        victim = self._select_victim(active)
        if victim is None:
            return False
        shortfall = max(self.kv.device.pages_for(total)
                        - self.kv.device.free_pages, 0)
        relief = len(self.kv.host_pages_of(victim.rid))
        if relief <= shortfall:
            return False                       # no strict win: wait instead
        if not self._admission_feasible_after_park(req, total, active,
                                                   victim):
            return False                       # the park would not unblock
        others = [a.rid for a in active if a.rid != victim.rid]
        # host pressure: the park (and the admission's spill behind it)
        # may need more host frames than free + prefix-cache reclaim can
        # supply — demote long-parked requests' pages to the disk tier
        # instead of refusing the park (the dry-run above already counted
        # this capacity and charged the NVMe write-back to the TPOT check)
        raw_need, _ = self.kv.park_preview(victim.rid, others)
        pv = self.kv.dedup_preview(req.prompt, total)
        n_spill = max(self.kv.device.pages_for(total) - pv.n_hits
                      + int(pv.need_reserve)
                      - (self.kv.device.free_pages + raw_need), 0)
        over = (raw_need + n_spill + len(pv.disk_hit_pages())
                - self.kv.host.free_pages
                - self.kv.reclaimable_host_pages())
        if over > 0:
            # oldest parked requests first; the victim's own spilled pages
            # (cold the moment it parks) retire last. The preview's hit
            # frames are pinned for the _try_admit_mem re-allocation below
            self._free_host_via_disk(over, others, also_rids=[victim.rid],
                                     keep=pv.host_hit_pages(),
                                     keep_disk=pv.disk_hit_pages())
        moves = self.kv.park(victim.rid, others)
        if moves is None:
            # the park fell through after room-making (e.g. disk reclaim
            # came up short of the dry-run's estimate). If the victim's own
            # spill was already retired, stage it straight back: an ACTIVE
            # request must never be left holding disk-tier pages.
            undone = self.kv.unspill_from_disk(victim.rid)
            self.stats["disk_stagings"] += undone
            return False                       # host cannot absorb the park
        self.swap.note_demotions(len(moves))
        active.remove(victim)
        free_slots.append(victim.slot)
        free_slots.sort()
        self.preempted.append(victim.req)
        plan.preemptions.append(
            PlannedPreemption(victim.req, victim.slot, moves))
        return self._try_admit_mem(req, total, active)

    def _admission_feasible_after_park(self, req: Request, total: int,
                                       active: list[ActiveInfo],
                                       victim: ActiveInfo) -> bool:
        """Dry-run of the post-park admission, no mutation: device frames
        the park would free are credited, the victim's streaming debits
        vanish, and the park's own write-back joins the pending kv_out.
        Host supply counts free frames, reclaimable prefix-cache frames
        (``park_preview``'s netted need pins the preview/park parity the
        raw count used to break) AND the disk tier's absorbable capacity —
        whose prospective NVMe write-back is charged to the disk term.
        Mirrors the checks ``_try_admit_mem`` / ``_try_spill_admit`` will
        apply for real after the park."""
        kv = self.kv
        rest = [a for a in active if a.rid != victim.rid]
        rest_rids = [a.rid for a in rest]
        freed, need_host = kv.park_preview(victim.rid, rest_rids)
        pv = kv.dedup_preview(req.prompt, total)
        disk_room = self._demotable_to_disk(rest_rids,
                                            include_rids=[victim.rid],
                                            pinned=pv.host_hit_pages())
        if need_host > kv.host.free_pages + disk_room:
            return False                       # the park itself cannot land
        supply = (kv.host.free_pages + kv.reclaimable_host_pages()
                  + disk_room)
        n_fresh = (kv.device.pages_for(total) - pv.n_hits
                   + int(pv.need_reserve))
        n_host = max(n_fresh - (kv.device.free_pages + freed), 0)
        n_revive = len(pv.disk_hit_pages())
        if n_host + n_revive > supply - freed:
            return False                       # no room for the spill
        pb = kv.page_bytes
        streamed = self.swap.streamed_host_pages(rest_rids)
        kv_in = ((len(streamed | pv.host_hit_pages()) + n_host + n_revive)
                 * pb + self.swap.pending_in_bytes())
        kv_out = self.swap.pending_out_bytes() + freed * pb
        # prospective NVMe traffic if the park + spill overflow into disk
        disk_out = max(freed + n_host + n_revive - kv.host.free_pages
                       - kv.reclaimable_host_pages(), 0)
        dt = self._iter_dt(len(rest) + 1, kv_in, kv_out,
                           self._chunk_overhead_s(req),
                           extra_disk_in_pages=n_revive,
                           extra_disk_out_pages=disk_out)
        slos = [a.tpot_slo_s for a in rest] + [req.tpot_slo_s]
        if dt > min(slos) * (1 + 1e-9):
            return False
        return (self.ttft_model(req, n_host * pb)
                <= req.ttft_slo_s * (1 + 1e-9))

    # --------------------------------------------------------------- chunks --
    def _chunk_overhead_s(self, extra_req: Request | None = None) -> float:
        """Modeled stack seconds the next iteration's prefill chunks add to
        the decode latency every active request pays (the same incremental
        T(end) - T(start) model the executor charges in ``_run_chunks``),
        plus ``extra_req``'s own first chunk when the candidate admission
        would itself be chunked. Folded into every TPOT feasibility check
        so chunk piggybacking cannot break an admission-certified SLO."""
        if self.chunk_tokens <= 0:
            return 0.0

        t_of = self.prefill_seconds
        t = 0.0
        for r in self._prefilling:
            if r.prefill_pos >= r.prompt_len:
                continue
            end = min(r.prefill_pos + self.chunk_tokens, r.prompt_len)
            t += max(t_of(end) - t_of(r.prefill_pos), 0.0)
        if extra_req is not None:
            t += t_of(min(self.chunk_tokens, extra_req.prompt_len))
        return t

    def _chunked_ttft_floor(self, req: Request) -> float:
        """Structural lower bound on a chunked prefill's TTFT: its chunks
        ride ``ceil(prompt_len / chunk_tokens)`` consecutive iterations, so
        even an otherwise idle system pays at least the baseline decode
        latency plus the chunk's own stack time per chunk. No schedule can
        beat this — a request whose floor exceeds its TTFT SLO is rejected
        outright (paper §4.2: pass back to the upper scheduler)."""
        base = iter_time_with_interval_kv(
            self.times_fn(1, self.max_seq, "decode"), self._iv)
        t_of = self.prefill_seconds
        total, start = 0.0, 0
        while start < req.prompt_len:
            end = min(start + self.chunk_tokens, req.prompt_len)
            total += base + max(t_of(end) - t_of(start), 0.0)
            start = end
        return total

    def _chunked_ttft_bound(self, req: Request,
                            active: list[ActiveInfo]) -> float:
        """Certified TTFT for a chunked admission: the modeled latencies of
        the iterations its chunks ride (TTFT accrues per chunk, exactly as
        the executor charges it). The first chunk's iteration carries the
        KV/NVMe traffic already pending at plan time plus every in-flight
        prefill's chunk overhead; later chunks ride iterations with that
        transient traffic drained — the same "later iterations are strictly
        cheaper" worst-case shape ``_resume_feasible`` certifies under."""
        t_of = self.prefill_seconds
        n = len(active) + 1
        kv_in_now = (self.swap.streamed_bytes([a.rid for a in active])
                     + self.swap.pending_in_bytes())
        base = iter_time_with_interval_kv(
            self.times_fn(n, self.max_seq, "decode"), self._iv)
        total = self._iter_dt(n, kv_in_now, self.swap.pending_out_bytes(),
                              self._chunk_overhead_s(req))
        start = min(self.chunk_tokens, req.prompt_len)
        while start < req.prompt_len:
            end = min(start + self.chunk_tokens, req.prompt_len)
            total += base + max(t_of(end) - t_of(start), 0.0)
            start = end
        return total

    def _plan_chunks(self, plan: IterationPlan) -> None:
        """One page-aligned chunk per in-flight chunked prefill per
        iteration, piggybacked on the decode step."""
        for req in list(self._prefilling):
            if req.state in (State.FINISHED, State.REJECTED) \
                    or req.prefill_pos >= req.prompt_len:
                self._prefilling.remove(req)
                continue
            start = req.prefill_pos
            end = min(start + self.chunk_tokens, req.prompt_len)
            plan.chunks.append(PrefillChunk(req, req.slot, start, end))
