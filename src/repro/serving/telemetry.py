"""Iteration-level telemetry plane for the serving engine.

Select-N's premise is that per-iteration timing is deterministic enough to
certify SLOs — so the run itself should be checkable against the model that
certified it. This module gives every ``ServingEngine`` an always-on
``TraceRecorder`` (``engine.trace``) the executor populates on each
``step()``:

  * one typed ``IterationRecord`` per iteration — interval, decode batch,
    admissions/parks/resumes/rejections, per-link bytes moved (PCIe in/out,
    NVMe in/out) split into their sources (streamed / promoted / pending
    drains / COW copies), the modeled dt decomposed into compute vs
    link-queue vs disk-queue terms (``iter_time_breakdown_kv``), per-tier
    allocator occupancy snapshots, and per-slot TPOT-headroom gauges;
  * ``RequestEvent``s for admit / reject / park / resume / prefill / chunk /
    finish, stamped on the modeled clock, carrying the scheduler's certified
    TTFT/dt where one was issued.

On top of the records sit two consumers:

  * ``TraceRecorder.to_perfetto`` — a Chrome trace-event JSON exporter
    (load the file at https://ui.perfetto.dev): per-slot decode/prefill
    spans on the modeled clock, PCIe / NVMe copy-stream lanes, a parked
    lane, and per-tier occupancy counters, making the modeled overlap
    visible.
  * ``audit_trace`` — a conservation-checking auditor that replays a
    finished trace and machine-checks the invariants documented on
    ``AuditReport``. The differential suites assert a clean audit on their
    lockstep traces; ``launch/serve.py --trace-out`` exits nonzero on any
    violation.

All byte quantities are integer page multiples far below 2**53, so the
byte-conservation checks are EXACT equalities — a single page charged twice
or dropped anywhere in the engine trips the auditor.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable

import numpy as np

TRACE_SCHEMA = "repro-trace/v1"

# matches the scheduler's feasibility slack (_FEAS_RTOL): certified bounds
# are compared with the same tolerance admission used
_RTOL = 1e-9
_ATOL = 1e-12


def summarize_latency(samples) -> dict:
    """Shared latency summary (p50/p99 via ``np.quantile``): one definition
    for ``engine.run``, the fig benchmarks and the differential suites
    instead of five hand-rolled copies. ``None`` entries are dropped."""
    xs = np.asarray([s for s in samples if s is not None], dtype=float)
    if xs.size == 0:
        return {"n": 0, "mean_s": 0.0, "p50_s": 0.0, "p99_s": 0.0,
                "max_s": 0.0}
    return {"n": int(xs.size),
            "mean_s": float(xs.mean()),
            "p50_s": float(np.quantile(xs, 0.5)),
            "p99_s": float(np.quantile(xs, 0.99)),
            "max_s": float(xs.max())}


# --------------------------------------------------------------- records --
@dataclasses.dataclass
class SlotGauge:
    """Per-request SLO headroom at the end of one decode iteration: how much
    of the TPOT budget the iteration left unspent (negative = violation)."""
    rid: int
    slot: int
    tpot_slo_s: float
    headroom_s: float              # tpot_slo_s - observed dt


@dataclasses.dataclass
class RequestEvent:
    """One request-lifecycle event on the modeled clock. ``detail`` carries
    kind-specific payload (certified_ttft_s, ttft_s, reject reason, chunk
    bounds, ...)."""
    kind: str                      # admit|reject|park|resume|prefill|chunk|finish
    rid: int
    t_s: float
    slot: int = -1
    dur_s: float = 0.0             # prefill/chunk span length
    iteration: int = -1            # index of the step that emitted it
    detail: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class IterationRecord:
    """Everything one ``step()`` charged to the modeled clock, decomposed so
    the auditor can re-derive the totals from the parts."""
    index: int
    t_start_s: float
    t_end_s: float
    dt_s: float                    # what note_outcome reported (0 if idle)
    interval: int
    decode_batch: int
    n_chunks: int = 0
    admitted: list[int] = dataclasses.field(default_factory=list)
    rejected: list[int] = dataclasses.field(default_factory=list)
    parked: list[int] = dataclasses.field(default_factory=list)
    resumed: list[int] = dataclasses.field(default_factory=list)
    finished: list[int] = dataclasses.field(default_factory=list)
    # PCIe bytes charged to this iteration, and their sources; the auditor
    # checks kv_in == streamed + promoted + pending_in + cow_in exactly
    kv_in_bytes: float = 0.0
    kv_out_bytes: float = 0.0
    streamed_bytes: float = 0.0
    promoted_bytes: float = 0.0
    pending_in_bytes: float = 0.0   # resume-promotion debt drained this step
    pending_out_bytes: float = 0.0  # demotion write-back debt drained
    cow_in_bytes: float = 0.0
    cow_out_bytes: float = 0.0
    # bytes the scheduler could NOT have certified at plan time (post-plan
    # COW stream growth, chunk host-spill write-backs, same-plan prefill
    # spill that streams into its own decode): the certified-dt check
    # allows exactly these bytes' serialization on top of the bound
    uncertified_in_bytes: float = 0.0
    uncertified_out_bytes: float = 0.0
    # PCIe totals the scheduler derived certified_dt_s from; uncertified_*
    # must equal max(actual - certified, 0) exactly (audited)
    certified_kv_in_bytes: float = 0.0
    certified_kv_out_bytes: float = 0.0
    # NVMe channel
    disk_in_bytes: float = 0.0
    disk_out_bytes: float = 0.0
    disk_in_pages: int = 0
    disk_out_pages: int = 0
    # PEER channel: live KV handoff traffic over the instance-to-instance
    # link, drained into this iteration exactly like the NVMe pendings
    peer_in_bytes: float = 0.0
    peer_out_bytes: float = 0.0
    peer_in_pages: int = 0
    peer_out_pages: int = 0
    # physical copy-stage engine activity sampled at the end of the step:
    # pages handed to the data plane vs. pages whose copies actually ran.
    # In sync mode the two are equal every iteration; in async mode issued
    # can lead completed by the in-flight window (audited by I10)
    staged_issued_pages: int = 0
    staged_completed_pages: int = 0
    # modeled dt decomposition (iter_time_breakdown_kv)
    compute_s: float = 0.0
    kv_in_s: float = 0.0
    kv_out_s: float = 0.0
    stall_s: float = 0.0
    pcie_s: float = 0.0
    disk_s: float = 0.0
    peer_s: float = 0.0
    chunk_s: float = 0.0
    # max(pcie_s, disk_s, peer_s); dt = model + chunk
    model_dt_s: float = 0.0
    # drained-engine wait run() skipped to the next arrival BEFORE this
    # iteration began (arrival-honoring loop): the clock-tiling check
    # expects t_start == previous t_end + idle_wait_s (+ mig_wait_s)
    idle_wait_s: float = 0.0
    # cross-instance migration (fleet): ticket payload bytes this instance
    # sent/received over the peer link since the previous iteration, and
    # the modeled transfer seconds charged to this instance's clock before
    # this iteration began. Both endpoints charge the same transfer — the
    # bytes ride BOTH iteration clocks (audited by I11)
    mig_in_bytes: float = 0.0
    mig_out_bytes: float = 0.0
    mig_wait_s: float = 0.0
    link_bw_bytes_s: float = 0.0
    certified_dt_s: float | None = None   # scheduler's stamp (decode only)
    occupancy: dict = dataclasses.field(default_factory=dict)
    reserve_pages: int = 0
    gauges: list[SlotGauge] = dataclasses.field(default_factory=list)


# -------------------------------------------------------------- recorder --
class TraceRecorder:
    """Accumulates the typed trace; attached always-on as ``engine.trace``
    (records are a few hundred bytes per iteration — the differential suites
    audit every run without opting in)."""

    def __init__(self, name: str, max_batch: int, page_bytes: int):
        self.name = name
        self.max_batch = max_batch
        self.page_bytes = page_bytes
        self.iterations: list[IterationRecord] = []
        self.events: list[RequestEvent] = []
        # the engine wires a counters snapshot (allocator/swap totals at
        # export time) so whole-trace conservation can be cross-checked
        # against state the recorder never touched
        self._footer_fn: Callable[[], dict] | None = None

    # -- population -------------------------------------------------------
    def event(self, kind: str, rid: int, t_s: float, slot: int = -1,
              dur_s: float = 0.0, **detail: Any) -> None:
        self.events.append(RequestEvent(kind=kind, rid=rid, t_s=t_s,
                                        slot=slot, dur_s=dur_s,
                                        iteration=len(self.iterations),
                                        detail=detail))

    def add_iteration(self, rec: IterationRecord) -> None:
        self.iterations.append(rec)

    # -- export -----------------------------------------------------------
    def footer(self) -> dict:
        return dict(self._footer_fn()) if self._footer_fn is not None else {}

    def to_dict(self) -> dict:
        return {"schema": TRACE_SCHEMA,
                "engine": self.name,
                "max_batch": self.max_batch,
                "page_bytes": self.page_bytes,
                "iterations": [dataclasses.asdict(r) for r in self.iterations],
                "events": [dataclasses.asdict(e) for e in self.events],
                "footer": self.footer()}

    def totals(self) -> dict:
        """Whole-trace per-link byte totals (what the summary prints)."""
        it = self.iterations
        return {"pcie_in_bytes": sum(r.kv_in_bytes for r in it),
                "pcie_out_bytes": sum(r.kv_out_bytes for r in it),
                "disk_in_bytes": sum(r.disk_in_bytes for r in it),
                "disk_out_bytes": sum(r.disk_out_bytes for r in it),
                "streamed_bytes": sum(r.streamed_bytes for r in it),
                "promoted_bytes": sum(r.promoted_bytes for r in it),
                "mig_in_bytes": sum(r.mig_in_bytes for r in it),
                "mig_out_bytes": sum(r.mig_out_bytes for r in it),
                "peer_in_bytes": sum(r.peer_in_bytes for r in it),
                "peer_out_bytes": sum(r.peer_out_bytes for r in it)}

    def audit(self) -> "AuditReport":
        return audit_trace(self.to_dict())

    def write_trace(self, path: str, audit: "AuditReport | None" = None
                    ) -> None:
        """Write the structured trace (plus an audit report) as JSON."""
        out = self.to_dict()
        if audit is not None:
            out["audit"] = {"ok": audit.ok, "checks": audit.checks,
                            "violations": audit.violations}
        with open(path, "w") as f:
            json.dump(out, f, indent=1)

    # -- Perfetto ---------------------------------------------------------
    # lane layout: tids [0, max_batch) are decode slots; the copy streams
    # and scheduler get their own "threads"
    _PCIE_TID = 100
    _NVME_TID = 101
    _SCHED_TID = 102
    _PARKED_TID = 103
    _PEER_TID = 104

    def to_perfetto(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable). Timestamps are the
        MODELED clock in microseconds — spans show what the analytic
        schedule charged, not wall time."""
        us = 1e6
        pid = 1
        ev: list[dict] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": f"engine:{self.name} (modeled clock)"}}]
        names = {t: n for t, n in
                 [(self._PCIE_TID, "pcie copy stream"),
                  (self._NVME_TID, "nvme channel"),
                  (self._SCHED_TID, "scheduler"),
                  (self._PARKED_TID, "parked"),
                  (self._PEER_TID, "peer link")]}
        names.update({s: f"slot {s}" for s in range(self.max_batch)})
        for tid, nm in sorted(names.items()):
            ev.append({"ph": "M", "pid": pid, "tid": tid,
                       "name": "thread_name", "args": {"name": nm}})

        def slice_(tid, name, t0, dur, **args):
            ev.append({"ph": "X", "pid": pid, "tid": tid, "name": name,
                       "ts": t0 * us, "dur": max(dur, 0.0) * us,
                       "args": args})

        def instant(tid, name, t0, **args):
            ev.append({"ph": "i", "pid": pid, "tid": tid, "name": name,
                       "ts": t0 * us, "s": "t", "args": args})

        for r in self.iterations:
            t0 = r.t_end_s - r.dt_s          # decode window of this step
            for g in r.gauges:
                slice_(g.slot, f"decode r{g.rid}", t0, r.dt_s,
                       headroom_us=g.headroom_s * us,
                       tpot_slo_us=g.tpot_slo_s * us, iteration=r.index)
            if r.kv_in_s > 0:
                slice_(self._PCIE_TID, f"kv_in {int(r.kv_in_bytes)}B",
                       t0, r.kv_in_s, iteration=r.index)
            if r.kv_out_s > 0:
                slice_(self._PCIE_TID, f"kv_out {int(r.kv_out_bytes)}B",
                       t0 + r.kv_in_s, r.kv_out_s, iteration=r.index)
            if r.disk_s > 0:
                slice_(self._NVME_TID,
                       f"nvme {r.disk_in_pages}p in / {r.disk_out_pages}p "
                       f"out", t0, r.disk_s, iteration=r.index)
            if r.peer_s > 0:
                slice_(self._PEER_TID,
                       f"peer {r.peer_in_pages}p in / {r.peer_out_pages}p "
                       f"out", t0, r.peer_s, iteration=r.index)
            for tier, occ in r.occupancy.items():
                ev.append({"ph": "C", "pid": pid, "tid": 0,
                           "name": f"{tier}_pages", "ts": r.t_end_s * us,
                           "args": {"used": occ.get("used_pages", 0),
                                    "cache": occ.get("cache_pages", 0)}})

        parked_since: dict[int, float] = {}
        for e in self.events:
            if e.kind in ("prefill", "chunk"):
                slice_(e.slot if e.slot >= 0 else self._SCHED_TID,
                       f"{e.kind} r{e.rid}", e.t_s, e.dur_s, **e.detail)
            elif e.kind == "park":
                instant(self._SCHED_TID, f"park r{e.rid}", e.t_s)
                parked_since[e.rid] = e.t_s
            elif e.kind == "resume":
                t0 = parked_since.pop(e.rid, None)
                if t0 is not None:
                    slice_(self._PARKED_TID, f"parked r{e.rid}", t0,
                           e.t_s - t0)
                instant(self._SCHED_TID, f"resume r{e.rid}", e.t_s)
            elif e.kind == "finish":
                instant(e.slot if e.slot >= 0 else self._SCHED_TID,
                        f"finish r{e.rid}", e.t_s)
            else:                          # admit / reject
                instant(self._SCHED_TID, f"{e.kind} r{e.rid}", e.t_s,
                        **e.detail)
        t_end = (self.iterations[-1].t_end_s if self.iterations else 0.0)
        for rid, t0 in parked_since.items():   # still parked at export
            slice_(self._PARKED_TID, f"parked r{rid}", t0, t_end - t0)
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def write_perfetto(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)


# --------------------------------------------------------------- auditor --
@dataclasses.dataclass
class AuditReport:
    """Result of replaying a finished trace against the conservation
    invariants:

      I1  per-iteration PCIe conservation (EXACT): ``kv_in_bytes ==
          streamed + promoted + pending_in + cow_in`` and ``kv_out_bytes ==
          pending_out + cow_out`` — every byte charged to the clock has a
          named source, none is charged twice.
      I2  NVMe bytes are whole pages: ``disk_*_bytes == disk_*_pages *
          page_bytes`` (exact).
      I3  dt identity: ``dt == max(pcie_s, disk_s) + chunk_s`` exactly, and
          the PCIe term decomposes into compute + kv_in + stall.
      I4  clock continuity: ``t_end == t_start + one-shot prefill TTFTs +
          dt`` per iteration, and iterations tile the clock
          (``t_start[i+1] == t_end[i] + idle_wait_s[i+1]``, where
          ``idle_wait_s`` is the drained-engine jump the arrival-honoring
          loop took to the next arrival — never backwards).
      I5  occupancy: per tier, ``0 <= used_pages <= total_pages`` and cache
          frames never exceed used frames.
      I6  certified dt: every decode iteration's observed dt is bounded by
          the dt the scheduler certified at plan time, plus the
          serialization of bytes that provably arrived after planning
          (COW copies, chunk host-spill write-backs, same-plan prefill
          spill): ``dt <= certified + uncertified_bytes / link_bw``
          (within admission's 1e-9 slack), where the uncertified totals
          must exactly equal the actual traffic's excess over the
          plan-stamped ``certified_kv_in/out_bytes``.
      I7  certified TTFT: a non-chunked admission's observed prefill TTFT
          never exceeds the TTFT the scheduler certified when admitting it.
      I8  whole-trace conservation vs the allocator's own counters
          (footer): summed per-iteration drains equal the allocator/swap
          cumulative totals minus what is still pending — bytes charged to
          the clock are exactly the bytes the allocator moved, per tier.
      I9  request conservation: every admit is matched by a finish or is
          still in flight at export; parks == resumes + still-parked.
      I10 copy-stage conservation: every page handed to the data plane is
          charged exactly once — summed per-iteration issued pages equal
          the plane's issue counter, the plane's issue counter equals its
          completion counter plus what is still in flight, and at every
          iteration prefix completions never exceed issues (an
          async-reordered trace where a completion is recorded before its
          issue fails here).
      I11 cross-instance migration conservation (fleet traces only): per
          direction, summed per-iteration ticket bytes equal the engine's
          cumulative migration counters minus what is still pending a
          stamp; every per-iteration total is a whole-page multiple;
          summed ``mig_wait_s`` equals the cumulative transfer seconds
          charged to this instance's clock; and migrate_in/out event
          counts match the footer counters. I4 and I9 fold migration in:
          ``t_start == prev t_end + idle_wait + mig_wait``, and a
          migrated-in request counts like an admit (it finishes, stays
          active/parked, or migrates back out) while a migrated-out one
          leaves the books like a finish.
      I12 KV handoff conservation (PEER tier, disaggregated fleets): per
          direction, summed per-iteration peer-link drains equal the
          allocator's cumulative peer page counters minus the pages still
          pending a drain; handoff byte counters are exactly those pages'
          bytes; handoff_in/out event counts (net of rollbacks) match the
          footer. I2, I3 and I9 fold the peer channel in: peer bytes are
          whole pages, ``model_dt_s == max(pcie_s, disk_s, peer_s)``, and
          a handed-off request changes books like a migrated one. The
          cross-instance half — exporter bytes == importer bytes per peer
          link — is ``Fleet.audit``'s check, which sees all endpoints.
    """
    ok: bool
    violations: list[str]
    checks: int
    totals: dict = dataclasses.field(default_factory=dict)


def _close(a: float, b: float, scale: float = 1.0) -> bool:
    return abs(a - b) <= _RTOL * max(abs(a), abs(b), scale) + _ATOL


def audit_trace(trace: dict) -> AuditReport:
    """Replay a finished trace (``TraceRecorder.to_dict()`` or the JSON file
    written by ``--metrics-out``) and check the ``AuditReport`` invariants.
    Pure dict-in / report-out: auditable offline, no engine required."""
    violations: list[str] = []
    checks = 0

    def check(cond: bool, msg: str) -> None:
        nonlocal checks
        checks += 1
        if not cond:
            violations.append(msg)

    pb = float(trace.get("page_bytes", 0))
    its: list[dict] = trace.get("iterations", [])
    events: list[dict] = trace.get("events", [])
    footer: dict = trace.get("footer", {})

    # one-shot prefill TTFTs advance the clock inside the step that ran
    # them (chunked prefills accrue TTFT without their own clock advance)
    prefill_s_of: dict[int, float] = {}
    for e in events:
        if e["kind"] == "prefill":
            prefill_s_of[e["iteration"]] = \
                prefill_s_of.get(e["iteration"], 0.0) + e["dur_s"]

    prev_end = None
    for r in its:
        i = r["index"]
        # I1: per-iteration PCIe conservation (exact: integer page bytes)
        parts_in = (r["streamed_bytes"] + r["promoted_bytes"]
                    + r["pending_in_bytes"] + r["cow_in_bytes"])
        check(r["kv_in_bytes"] == parts_in,
              f"iter {i}: kv_in {r['kv_in_bytes']:.0f}B != streamed "
              f"{r['streamed_bytes']:.0f} + promoted {r['promoted_bytes']:.0f}"
              f" + pending_in {r['pending_in_bytes']:.0f} + cow_in "
              f"{r['cow_in_bytes']:.0f}")
        check(r["kv_out_bytes"] == r["pending_out_bytes"]
              + r["cow_out_bytes"],
              f"iter {i}: kv_out {r['kv_out_bytes']:.0f}B != pending_out "
              f"{r['pending_out_bytes']:.0f} + cow_out "
              f"{r['cow_out_bytes']:.0f}")
        # I2: NVMe / peer-link bytes are whole pages
        check(r["disk_in_bytes"] == r["disk_in_pages"] * pb,
              f"iter {i}: disk_in {r['disk_in_bytes']:.0f}B != "
              f"{r['disk_in_pages']} pages * {pb:.0f}B")
        check(r["disk_out_bytes"] == r["disk_out_pages"] * pb,
              f"iter {i}: disk_out {r['disk_out_bytes']:.0f}B != "
              f"{r['disk_out_pages']} pages * {pb:.0f}B")
        check(r.get("peer_in_bytes", 0.0)
              == r.get("peer_in_pages", 0) * pb,
              f"iter {i}: peer_in {r.get('peer_in_bytes', 0.0):.0f}B != "
              f"{r.get('peer_in_pages', 0)} pages * {pb:.0f}B")
        check(r.get("peer_out_bytes", 0.0)
              == r.get("peer_out_pages", 0) * pb,
              f"iter {i}: peer_out {r.get('peer_out_bytes', 0.0):.0f}B != "
              f"{r.get('peer_out_pages', 0)} pages * {pb:.0f}B")
        # I3: dt identity + decomposition
        check(r["dt_s"] == r["model_dt_s"] + r["chunk_s"],
              f"iter {i}: dt {r['dt_s']} != model {r['model_dt_s']} + chunk "
              f"{r['chunk_s']}")
        check(r["model_dt_s"] == max(r["pcie_s"], r["disk_s"],
                                     r.get("peer_s", 0.0)),
              f"iter {i}: model dt {r['model_dt_s']} != max(pcie "
              f"{r['pcie_s']}, disk {r['disk_s']}, peer "
              f"{r.get('peer_s', 0.0)})")
        if r["decode_batch"] > 0:
            check(_close(r["pcie_s"],
                         r["compute_s"] + r["kv_in_s"] + r["stall_s"],
                         scale=r["pcie_s"]),
                  f"iter {i}: pcie {r['pcie_s']} != compute + kv_in + stall")
            if r["link_bw_bytes_s"] > 0:
                check(_close(r["kv_in_s"],
                             r["kv_in_bytes"] / r["link_bw_bytes_s"],
                             scale=r["kv_in_s"]),
                      f"iter {i}: kv_in_s inconsistent with bytes/bw")
        # I4: clock continuity
        pre = prefill_s_of.get(i, 0.0)
        check(_close(r["t_end_s"], r["t_start_s"] + pre + r["dt_s"],
                     scale=max(r["t_end_s"], 1e-9)),
              f"iter {i}: clock {r['t_start_s']} + prefill {pre} + dt "
              f"{r['dt_s']} != {r['t_end_s']}")
        if prev_end is not None:
            idle = r.get("idle_wait_s", 0.0)
            mig = r.get("mig_wait_s", 0.0)
            check(_close(r["t_start_s"], prev_end + idle + mig,
                         scale=max(r["t_start_s"], 1e-9))
                  and r["t_start_s"] >= prev_end,
                  f"iter {i}: t_start {r['t_start_s']} != previous t_end "
                  f"{prev_end} + idle wait {idle} + migration wait {mig}")
        prev_end = r["t_end_s"]
        # I5: occupancy within capacity
        for tier, occ in r["occupancy"].items():
            used, total = occ["used_pages"], occ["total_pages"]
            check(0 <= used <= total,
                  f"iter {i}: {tier} occupancy {used} exceeds capacity "
                  f"{total}")
            cache = occ.get("cache_pages", 0)
            check(cache <= used,
                  f"iter {i}: {tier} cache frames {cache} > used {used}")
        # I6: observed dt vs the scheduler's certified bound. Post-plan
        # bytes (COW copies, chunk host-spill write-backs, same-plan
        # prefill spill) delay a serial copy stream by at most bytes/bw —
        # allow exactly that. The uncertified totals themselves must be
        # exactly the actual traffic's excess over the certified totals
        # (both integer page multiples).
        cert = r.get("certified_dt_s")
        if cert is not None and r["decode_batch"] > 0:
            check(r["uncertified_in_bytes"]
                  == max(r["kv_in_bytes"] - r["certified_kv_in_bytes"], 0.0),
                  f"iter {i}: uncertified_in {r['uncertified_in_bytes']}B "
                  f"!= kv_in {r['kv_in_bytes']} - certified "
                  f"{r['certified_kv_in_bytes']}")
            check(r["uncertified_out_bytes"]
                  == max(r["kv_out_bytes"] - r["certified_kv_out_bytes"],
                         0.0),
                  f"iter {i}: uncertified_out {r['uncertified_out_bytes']}B "
                  f"!= kv_out {r['kv_out_bytes']} - certified "
                  f"{r['certified_kv_out_bytes']}")
            slack = 0.0
            if r["link_bw_bytes_s"] > 0:
                slack = (r["uncertified_in_bytes"]
                         + r["uncertified_out_bytes"]) / r["link_bw_bytes_s"]
            check(r["dt_s"] <= (cert + slack) * (1 + _RTOL) + _ATOL,
                  f"iter {i}: observed dt {r['dt_s']} exceeds certified "
                  f"{cert} + uncertified slack {slack}")

    # I7: certified TTFT per admission (non-chunked admissions only)
    certified_ttft = {e["rid"]: e["detail"]["certified_ttft_s"]
                      for e in events if e["kind"] == "admit"
                      and e["detail"].get("certified_ttft_s") is not None}
    for e in events:
        if e["kind"] == "prefill" and e["rid"] in certified_ttft:
            cert = certified_ttft[e["rid"]]
            check(e["dur_s"] <= cert * (1 + _RTOL) + _ATOL,
                  f"rid {e['rid']}: observed TTFT {e['dur_s']} exceeds "
                  f"certified {cert}")

    # I8: whole-trace conservation vs allocator counters
    totals = {
        "pcie_in_bytes": sum(r["kv_in_bytes"] for r in its),
        "pcie_out_bytes": sum(r["kv_out_bytes"] for r in its),
        "disk_in_bytes": sum(r["disk_in_bytes"] for r in its),
        "disk_out_bytes": sum(r["disk_out_bytes"] for r in its),
    }
    if footer:
        drained = {
            "disk_in": (footer["disk_in_pages_total"]
                        - footer["pending_disk_in_pages"]) * pb,
            "disk_out": (footer["disk_out_pages_total"]
                         - footer["pending_disk_out_pages"]) * pb,
            "pending_in": (footer["noted_in_pages_total"]
                           - footer["pending_in_pages"]) * pb,
            "pending_out": (footer["noted_out_pages_total"]
                            - footer["pending_out_pages"]) * pb,
            "promoted": footer["promoted_pages_total"] * pb,
        }
        check(totals["disk_in_bytes"] == drained["disk_in"],
              f"trace disk_in {totals['disk_in_bytes']:.0f}B != allocator "
              f"drained {drained['disk_in']:.0f}B")
        check(totals["disk_out_bytes"] == drained["disk_out"],
              f"trace disk_out {totals['disk_out_bytes']:.0f}B != allocator "
              f"drained {drained['disk_out']:.0f}B")
        check(sum(r["pending_in_bytes"] for r in its)
              == drained["pending_in"],
              "trace promotion-debt drains != swap scheduler noted totals")
        check(sum(r["pending_out_bytes"] for r in its)
              == drained["pending_out"],
              "trace write-back drains != swap scheduler noted totals")
        check(sum(r["promoted_bytes"] for r in its) == drained["promoted"],
              "trace promoted bytes != allocator promotion count")
        check(sum(r["cow_in_bytes"] for r in its)
              == footer["cow_in_bytes_total"],
              "trace COW h2d bytes != engine COW counter")
        check(sum(r["cow_out_bytes"] for r in its)
              == footer["cow_out_bytes_total"],
              "trace COW d2h bytes != engine COW counter")

        # I9: request conservation. Migration folds in symmetrically: a
        # migrated-in request counts like an admit (it must finish, stay
        # active/parked, or migrate back out) and joins the parked books
        # without a local park event; a migrated-out one leaves both books.
        n_admit = sum(1 for e in events if e["kind"] == "admit")
        n_finish = sum(1 for e in events if e["kind"] == "finish")
        n_park = sum(1 for e in events if e["kind"] == "park")
        n_resume = sum(1 for e in events if e["kind"] == "resume")
        n_mig_in = sum(1 for e in events if e["kind"] == "migrate_in")
        n_mig_out = sum(1 for e in events if e["kind"] == "migrate_out")
        # live KV handoff folds in exactly like migration; a refused
        # handoff leaves a handoff_out + handoff_rollback pair that nets
        # to zero (the request never left this instance's books)
        n_ho_in = sum(1 for e in events if e["kind"] == "handoff_in")
        n_ho_out = (sum(1 for e in events if e["kind"] == "handoff_out")
                    - sum(1 for e in events
                          if e["kind"] == "handoff_rollback"))
        check(n_finish == footer["n_finished"],
              f"{n_finish} finish events != {footer['n_finished']} finished "
              f"requests")
        check(n_admit + n_mig_in + n_ho_in
              == footer["n_finished"] + footer["n_active"]
              + footer["n_parked"] + n_mig_out + n_ho_out,
              f"{n_admit} admits + {n_mig_in} migrated in + {n_ho_in} "
              f"handed in != finished {footer['n_finished']} + active "
              f"{footer['n_active']} + parked {footer['n_parked']} + "
              f"{n_mig_out} migrated out + {n_ho_out} handed out")
        check(n_park + n_mig_in + n_ho_in
              == n_resume + footer["n_parked"] + n_mig_out + n_ho_out,
              f"{n_park} parks + {n_mig_in} migrated in + {n_ho_in} handed "
              f"in != {n_resume} resumes + {footer['n_parked']} still "
              f"parked + {n_mig_out} migrated out + {n_ho_out} handed out")

        # I11: cross-instance migration conservation (fleet traces only)
        if "mig_out_bytes_total" in footer:
            check(n_mig_in == footer["n_migrated_in"],
                  f"{n_mig_in} migrate_in events != footer "
                  f"{footer['n_migrated_in']}")
            check(n_mig_out == footer["n_migrated_out"],
                  f"{n_mig_out} migrate_out events != footer "
                  f"{footer['n_migrated_out']}")
            for r in its:
                for f_ in ("mig_in_bytes", "mig_out_bytes"):
                    b = r.get(f_, 0.0)
                    whole = b == 0 or (pb > 0 and b == int(b)
                                       and int(b) % int(pb) == 0)
                    check(whole,
                          f"iter {r['index']}: {f_} {b}B not a whole-page "
                          f"multiple of {pb:.0f}B")
            sum_in = sum(r.get("mig_in_bytes", 0.0) for r in its)
            sum_out = sum(r.get("mig_out_bytes", 0.0) for r in its)
            check(sum_in == footer["mig_in_bytes_total"]
                  - footer["pending_mig_in_bytes"],
                  f"trace migration-in bytes {sum_in:.0f}B != engine total "
                  f"{footer['mig_in_bytes_total']:.0f}B - pending "
                  f"{footer['pending_mig_in_bytes']:.0f}B")
            check(sum_out == footer["mig_out_bytes_total"]
                  - footer["pending_mig_out_bytes"],
                  f"trace migration-out bytes {sum_out:.0f}B != engine "
                  f"total {footer['mig_out_bytes_total']:.0f}B - pending "
                  f"{footer['pending_mig_out_bytes']:.0f}B")
            sum_wait = sum(r.get("mig_wait_s", 0.0) for r in its)
            check(_close(sum_wait, footer["mig_wait_total_s"]
                         - footer["pending_mig_wait_s"],
                         scale=max(sum_wait, 1e-9)),
                  f"trace migration wait {sum_wait}s != engine total "
                  f"{footer['mig_wait_total_s']}s - pending "
                  f"{footer['pending_mig_wait_s']}s")

        # I12: KV handoff conservation (PEER tier). Per direction, summed
        # per-iteration peer drains equal the allocator's cumulative peer
        # counters minus what is still pending a drain, and the engine's
        # handoff byte counters are exactly those pages' bytes. The
        # cross-instance half (every exporter's bytes land on exactly one
        # importer, per link) is checked by ``Fleet.audit``, which holds
        # all endpoints' traces.
        if "peer_out_pages_total" in footer:
            check(n_ho_in == footer["n_handoff_in"],
                  f"{n_ho_in} handoff_in events != footer "
                  f"{footer['n_handoff_in']}")
            check(n_ho_out == footer["n_handoff_out"],
                  f"{n_ho_out} net handoff_out events != footer "
                  f"{footer['n_handoff_out']}")
            sum_pin = sum(r.get("peer_in_bytes", 0.0) for r in its)
            sum_pout = sum(r.get("peer_out_bytes", 0.0) for r in its)
            check(sum_pin == (footer["peer_in_pages_total"]
                              - footer["pending_peer_in_pages"]) * pb,
                  f"trace peer-in bytes {sum_pin:.0f}B != allocator drained "
                  f"{(footer['peer_in_pages_total'] - footer['pending_peer_in_pages']) * pb:.0f}B")
            check(sum_pout == (footer["peer_out_pages_total"]
                               - footer["pending_peer_out_pages"]) * pb,
                  f"trace peer-out bytes {sum_pout:.0f}B != allocator "
                  f"drained "
                  f"{(footer['peer_out_pages_total'] - footer['pending_peer_out_pages']) * pb:.0f}B")
            check(footer["handoff_in_bytes_total"]
                  == footer["peer_in_pages_total"] * pb,
                  f"handoff-in bytes {footer['handoff_in_bytes_total']:.0f}B "
                  f"!= {footer['peer_in_pages_total']} peer pages")
            check(footer["handoff_out_bytes_total"]
                  == footer["peer_out_pages_total"] * pb,
                  f"handoff-out bytes "
                  f"{footer['handoff_out_bytes_total']:.0f}B != "
                  f"{footer['peer_out_pages_total']} peer pages")

        # I10: copy-stage conservation (only present once the engine runs a
        # data plane). The final sync() in run() completes trailing pages
        # AFTER the last iteration sampled its counters, so completed sums
        # are bounded by — not equal to — the footer total; issued sums are
        # exact because issues only happen inside steps.
        if "staged_issued_pages_total" in footer:
            sum_issued = sum(r.get("staged_issued_pages", 0) for r in its)
            sum_completed = sum(r.get("staged_completed_pages", 0)
                                for r in its)
            check(sum_issued == footer["staged_issued_pages_total"],
                  f"trace staged issues {sum_issued} != plane issue counter "
                  f"{footer['staged_issued_pages_total']}")
            check(footer["staged_issued_pages_total"]
                  == footer["staged_completed_pages_total"]
                  + footer["staged_inflight_pages"],
                  f"plane issued {footer['staged_issued_pages_total']} != "
                  f"completed {footer['staged_completed_pages_total']} + "
                  f"in flight {footer['staged_inflight_pages']}")
            check(sum_completed <= footer["staged_completed_pages_total"],
                  f"trace staged completions {sum_completed} exceed plane "
                  f"completion counter "
                  f"{footer['staged_completed_pages_total']}")
            run_issued = run_completed = 0
            for r in its:
                run_issued += r.get("staged_issued_pages", 0)
                run_completed += r.get("staged_completed_pages", 0)
                check(run_completed <= run_issued,
                      f"iter {r['index']}: {run_completed} staged pages "
                      f"completed before only {run_issued} were issued "
                      f"(completion recorded ahead of its issue)")
            direct = footer.get("disk_direct_pages_total", 0)
            check(0 <= direct <= footer["disk_in_pages_total"],
                  f"direct disk reads {direct} exceed total disk reads "
                  f"{footer['disk_in_pages_total']}")

    return AuditReport(ok=not violations, violations=violations,
                       checks=checks, totals=totals)
