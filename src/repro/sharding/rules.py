"""Logical-axis sharding rules.

Tensors are annotated with *logical* axis names ("batch", "heads", "mlp", ...).
``resolve()`` maps them onto mesh axes with two safety properties that make
every (arch × shape × mesh) cell compile:

1. divisibility fallback — a candidate mesh-axis tuple is only used if the dim
   size divides evenly; otherwise the next candidate (or replication) is used;
2. no-double-use — a mesh axis is consumed at most once per PartitionSpec,
   resolved greedily left-to-right. This is what makes e.g. the KV cache
   ``[batch, cache_seq, kv, head_dim]`` shard batch over "data" for decode_32k
   (batch=128) but *sequence* over "data" for long_500k (batch=1): batch=1
   fails divisibility, leaving "data" free for cache_seq.

The rules are derived from (ModelConfig, mesh): giant (param_fsdp) archs add
the data axes as a candidate for parameter "fsdp" dims; MoE expert dims try
the model axis (EP) and otherwise leave TP to the per-expert FFN dims.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Rules = dict[str, list[tuple[str, ...]]]


def virtual_kv_heads(cfg: ModelConfig, tp: int) -> int:
    """Number of *stored* KV heads after replication for tensor parallelism.

    Smallest v with v % kv == 0, v % tp == 0, heads % v == 0 (the standard
    vLLM/MaxText KV replication scheme). Falls back to kv (no expansion) when
    impossible — then attention is not head-sharded on this mesh.
    """
    kv, h = cfg.num_kv_heads, cfg.num_heads
    for mult in range(1, h // kv + 1):
        v = kv * mult
        if v % tp == 0 and h % v == 0:
            return v
    return kv


def make_rules(cfg: ModelConfig, mesh: Mesh, step: str = "train",
               global_batch: int | None = None) -> Rules:
    names = mesh.axis_names
    has_pod = "pod" in names
    dp: tuple[str, ...] = ("pod", "data") if has_pod else ("data",)
    tp = mesh.shape["model"]

    fsdp_cands: list[tuple[str, ...]] = [dp, ("data",)] if cfg.param_fsdp else []
    expert_mlp_cands: list[tuple[str, ...]] = [("model",)]

    # Weight-stationary decode pays when the batch amortizes the replicated
    # weight reads; at tiny batches (long_500k: batch 1) the per-chip weight
    # READ dominates the step and FSDP-style small shards win even with the
    # per-token gathers (measured: jamba long_500k 37 ms fsdp vs 53 ms
    # replicated). Threshold: one decode row per data shard.
    ws_decode = step == "decode" and (
        global_batch is None or global_batch >= mesh.shape.get("data", 1))

    if ws_decode:
        # Weight-stationary decode layout (§Perf hillclimb A): FSDP shards
        # parameter *input* dims over "data", which makes XLA re-gather the
        # weight shards on EVERY decode token (74 GB/token for grok-314b).
        # At decode the dispatched MoE activations are tiny (one token per
        # sequence, replicated via "moe_batch" below), so instead: drop the
        # fsdp dim and shard the expert hidden dim over BOTH mesh axes —
        # per-chip residency is unchanged or better and the collective
        # traffic becomes O(tokens x d_model), not O(weight bytes). Dense
        # MLP / mamba inner dims keep the 1D "model" rule: their activation
        # paths stay batch-sharded, and a 2D weight shard there would make
        # XLA re-gather the data component every token (observed on jamba).
        fsdp_cands = []
        expert_mlp_cands = [dp + ("model",) if has_pod
                            else ("data", "model"),
                            dp, ("data",), ("model",)]

    # MoE dispatch activations: batch-sharded like everything else during
    # train/prefill, but REPLICATED at decode — the dispatched tokens are a
    # few MB while re-gathering 2D-sharded expert weights is tens of GB.
    moe_batch: list[tuple[str, ...]] = [] if ws_decode \
        else [dp, ("data",)]

    # Sub-scale-TP remap (§Perf hillclimb B2): a small model on a big mesh
    # wastes the model axis — TP-16 of a d_model~1k stack moves huge
    # activation all-reduces and leaves 16x more tokens per chip than pure
    # DP would (recurrence/attention traffic scales with tokens/chip). When
    # the replicated train state (param + fp32 master + adamw moments + grad
    # ~ 18 B/param) of the non-embedding stack fits comfortably on one chip,
    # fold the model axis into data parallelism and replicate the stack;
    # embeddings stay vocab-sharded on the model axis (they dominate params
    # for small-vocab-heavy archs but train sparsely).
    d = cfg.d_model
    embed_params = cfg.padded_vocab() * d * (1 if cfg.tie_embeddings else 2)
    stack_params = max(cfg.num_params() - embed_params, 0)
    # Recurrent mixers (sLSTM/mLSTM) are excluded: their per-token scans make
    # XLA reduce recurrent-weight grads across the batch axes INSIDE the
    # token loop, and widening the batch axes multiplies that wire traffic
    # (measured 4.7x worse on xlstm-125m; see EXPERIMENTS.md §Perf B3).
    attention_only = all(b.mixer == "attention" for b in cfg.pattern)
    small_dp = (step == "train" and attention_only
                and stack_params * 18 < 10e9)
    batch_cands: list[tuple[str, ...]] = [dp, ("data",)]
    if small_dp:
        batch_cands = [dp + ("model",) if has_pod else ("data", "model"),
                       dp, ("data",)]

    rules: Rules = {
        # activations -------------------------------------------------------
        "batch": batch_cands,
        "moe_batch": moe_batch,
        "seq": [],                      # sharded only via explicit SP paths
        "embed": [],                    # activation d_model dim
        "heads": [] if small_dp else [("model",)],
        "kv": [] if small_dp else [("model",)],   # virtual kv (post expand)
        "head_dim": [],
        "mlp": [] if small_dp else [("model",)],
        "expert_mlp": [] if small_dp else expert_mlp_cands,
        "experts": [] if small_dp else [("model",)],
        "capacity": [],
        # caches -------------------------------------------------------------
        "cache_seq": [dp, ("data",)],   # only wins when batch couldn't shard
        "state": [] if small_dp else [("model",)],  # SSM/recurrent inner dim
        # params --------------------------------------------------------------
        "fsdp": fsdp_cands,             # param in-dims for giant archs
        "vocab": [("model",)],
        "stack": [],                    # scan-stacked layer dim: never sharded
        "conv": [],
        None: [],
    }
    return rules


def resolve(
    shape: Sequence[int],
    logical: Sequence[str | None],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Map logical axis names to a PartitionSpec honouring divisibility and
    single-use of mesh axes (greedy, left-to-right)."""
    assert len(shape) == len(logical), (shape, logical)
    used: set[str] = set()
    out: list[tuple[str, ...] | None] = []
    for dim, name in zip(shape, logical):
        placed: tuple[str, ...] | None = None
        for cand in rules.get(name, []):
            if any(a in used for a in cand):
                continue
            size = math.prod(mesh.shape[a] for a in cand)
            if size > 1 and dim % size == 0:
                placed = cand
                used.update(cand)
                break
        out.append(placed)
    # trim trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# ---------------------------------------------------------------------------
# Context: model code calls shard(x, "batch", "seq", ...) without threading
# mesh/rules through every function signature.
# ---------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def sharding_context(mesh: Mesh | None, rules: Rules | None):
    prev = getattr(_ctx, "val", None)
    _ctx.val = (mesh, rules)
    try:
        yield
    finally:
        _ctx.val = prev


def current_context() -> tuple[Mesh | None, Rules | None]:
    val = getattr(_ctx, "val", None)
    return val if val is not None else (None, None)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a context."""
    mesh, rules = current_context()
    if mesh is None or rules is None:
        return x
    spec = resolve(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(
    mesh: Mesh, rules: Rules, shape: Sequence[int], logical: Sequence[str | None],
    memory_kind: str | None = None,
) -> NamedSharding:
    spec = resolve(shape, logical, rules, mesh)
    if memory_kind is None:
        return NamedSharding(mesh, spec)
    return NamedSharding(mesh, spec, memory_kind=memory_kind)
