"""Gradient compression for the cross-pod data-parallel reduction.

Modes:
  * "none":  fp32 psum (baseline);
  * "bf16":  cast to bf16 before the all-reduce — halves wire bytes, the
             standard large-cluster setting (Megatron/MaxText default);
  * "int8_ef": per-tensor-scale int8 quantization with error feedback. The
             residual (g - dequant(q)) is carried to the next step, so the
             quantization bias vanishes in expectation. Wire volume 1/4 of
             fp32; accumulation happens in int32 via psum.

Used inside shard_map over the DP axes by train_loop.build_train_step.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _psum(tree: Any, axes) -> Any:
    return jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)


def reduce_gradients(grads: Any, axes, mode: str = "none",
                     error_state: Any = None) -> tuple[Any, Any]:
    """All-reduce (mean) gradients across mesh ``axes`` under jit/shard_map.

    Returns (reduced_grads, new_error_state). error_state is None unless
    mode == "int8_ef".
    """
    nshards = 1
    # inside shard_map, axis sizes come from the mesh via psum of ones
    ones = jax.lax.psum(jnp.ones((), jnp.float32), axes)

    if mode == "none":
        red = _psum(jax.tree.map(lambda g: g.astype(jnp.float32), grads), axes)
        return jax.tree.map(lambda g: g / ones, red), error_state

    if mode == "bf16":
        red = _psum(jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), axes)
        return jax.tree.map(lambda g: g.astype(jnp.float32) / ones, red), \
            error_state

    if mode == "int8_ef":
        if error_state is None:
            error_state = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(error_state)
        red_leaves, err_leaves = [], []
        for g, e in zip(flat_g, flat_e):
            gf = g.astype(jnp.float32) + e
            # Shared scale across shards (pmax), so the int32 psum dequantizes
            # exactly: sum_i q_i * s == sum_i dequant(q_i).
            scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axes) / 127.0
            scale = jnp.maximum(scale, 1e-12)
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            err_leaves.append(gf - q.astype(jnp.float32) * scale)
            q_sum = jax.lax.psum(q.astype(jnp.int32), axes)
            red_leaves.append(q_sum.astype(jnp.float32) * scale / ones)
        return (jax.tree.unflatten(treedef, red_leaves),
                jax.tree.unflatten(treedef, err_leaves))

    raise ValueError(mode)
