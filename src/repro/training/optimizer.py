"""AdamW, pure JAX. Parameters stay in the model dtype (bf16); first/second
moments are fp32 and inherit the parameter sharding (so FSDP shards optimizer
state too — ZeRO-style)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, decayed)


def init_opt_state(params: Any) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> tuple[Any, dict, dict]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, count)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (step + cfg.weight_decay
                                             * p.astype(jnp.float32))
        return newp.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
