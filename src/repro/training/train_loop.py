"""Training step builder: microbatch accumulation (scan) + remat + AdamW,
with donated buffers. Gradient reduction across data-parallel axes is
implicit in SPMD (XLA inserts reduce-scatter/all-reduce as the shardings
dictate); the optional explicit compressed-reduction path
(compression.reduce_gradients) is exposed for the cross-pod hop via
``dp_compress``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.sharding.rules import current_context, resolve
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def constrain_grads_like_params(model: Model, grads: Any) -> Any:
    """Pin each gradient to its parameter's sharding (§Perf hillclimb D1).

    Without the constraint XLA materializes full (replicated) weight grads
    with an all-reduce over the data axes before the optimizer slices them
    back to the FSDP shard — 2x the necessary wire bytes. Constraining the
    grads to the parameter shardings lets SPMD emit a reduce-scatter
    instead. No-op outside a sharding context (single-device tests)."""
    mesh, rules = current_context()
    if mesh is None or rules is None:
        return grads
    from jax.sharding import NamedSharding

    def pin(ts, g):
        spec = resolve(ts.shape, ts.logical, rules, mesh)
        return jax.lax.with_sharding_constraint(g, NamedSharding(mesh, spec))

    return jax.tree.map(pin, model.spec, grads)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1        # grad-accumulation steps per train_step
    remat: bool = True
    dp_compress: str = "none"    # none | bf16 | int8_ef (cross-pod explicit)


def build_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch leaves have a leading microbatch dim when
    tcfg.microbatches > 1."""

    def loss_fn(params, mb):
        loss, metrics = model.loss_fn(params, mb, remat=tcfg.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def accum(carry, mb):
                gsum, lsum = carry
                (loss, _m), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (g0, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
        else:
            (loss, _m), grads = grad_fn(params, batch)
        grads = constrain_grads_like_params(model, grads)

        params2, opt_state2, om = adamw_update(
            tcfg.optimizer, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params2, opt_state2, metrics

    return train_step


def init_train_state(model: Model, key: jax.Array):
    params = model.init(key)
    return params, init_opt_state(params)


def opt_state_spec(model: Model):
    """TensorSpec tree for the optimizer state (fp32 moments mirror params)."""
    import dataclasses as dc

    from repro.models.spec import TensorSpec, tree_map_spec
    pspec = model.spec
    f32 = lambda s: dc.replace(s, dtype=jnp.float32)
    return {
        "m": tree_map_spec(f32, pspec),
        "v": tree_map_spec(f32, pspec),
        "count": TensorSpec((), (), dtype=jnp.int32, init="zeros"),
    }
