"""Shared test builder for reduced serving engines.

One place owns the "HBM = resident weights + K KV pages, host tier = N
pages" sizing dance (unit_weight_bytes / kv_cache_bytes / OffloadPlan), so
the tier split cannot drift between the serving, kv-offload, and
differential suites.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core import costs
from repro.core.analyzer import PerformanceAnalyzer
from repro.core.hardware import A10
from repro.core.interval import NO_OFFLOAD, OffloadPlan
from repro.models.model import build_model
from repro.models.transformer import pattern_info
from repro.serving.engine import EngineConfig, ServingEngine


def mk_reduced_engine(*, name="e0", d_model=32, heads=2, layers=8, d_ff=64,
                      vocab=128, max_batch=4, max_seq=48, page_size=16,
                      hbm_gb: float | None = None,
                      extra_device_pages: float | None = None,
                      host_pages: int = 0, prefix_dedup: bool = False,
                      preemption: bool = False,
                      prefill_chunk_tokens: int = 0,
                      host_prefix_cache_pages: int = 0,
                      disk_pages: int = 0, disk_bw_bytes_s: float = 3e9,
                      disk_latency_s: float = 1e-7,
                      disk_backing_path: str | None = None,
                      async_data_plane: bool = False,
                      incremental_prefill: bool = False,
                      autotune: bool = False,
                      prefetch_pages_per_boundary: int = 1,
                      role: str = "mixed",
                      peer_bw_bytes_s: float = 16e9,
                      peer_latency_s: float = 1e-7,
                      batches=(1, 2, 4, 8), seqs=(16, 32, 64)):
    """Reduced-qwen engine + analyzer. Size HBM either directly (``hbm_gb``)
    or as resident weights plus ``extra_device_pages`` KV pages (the
    tiered-serving shape); ``host_pages`` / ``disk_pages`` size the
    pinned-host and NVMe KV pools in pages of the same geometry.
    ``preemption`` / ``prefill_chunk_tokens`` / ``host_prefix_cache_pages``
    switch on the scheduler policies."""
    cfg = reduce_config(get_config("qwen2.5-3b"), d_model=d_model,
                        heads=heads, layers=layers, d_ff=d_ff, vocab=vocab)
    model = build_model(cfg)
    an = PerformanceAnalyzer(cfg, A10, measure="model")
    kv_tok = max(costs.kv_cache_bytes(cfg, 1, 1, model.virtual_kv), 1)
    page_bytes = page_size * kv_tok
    if extra_device_pages is not None:
        _, units = pattern_info(cfg)
        unit = costs.unit_weight_bytes(cfg)
        hbm = OffloadPlan(units, NO_OFFLOAD).device_bytes(unit) \
            + extra_device_pages * page_bytes
    else:
        assert hbm_gb is not None
        hbm = hbm_gb * 1e9
    slos = [0.002 * k for k in range(1, 30)]
    rec_p = an.generate_record(slos, list(batches), list(seqs), "prefill")
    rec_d = an.generate_record(slos, list(batches), list(seqs), "decode")
    eng = ServingEngine(name, model, A10, rec_p, rec_d, an.layer_times,
                        EngineConfig(max_batch=max_batch, max_seq=max_seq,
                                     page_size=page_size,
                                     hbm_budget_bytes=hbm,
                                     host_kv_bytes=host_pages * page_bytes,
                                     prefix_dedup=prefix_dedup,
                                     preemption=preemption,
                                     prefill_chunk_tokens=prefill_chunk_tokens,
                                     host_prefix_cache_pages=
                                     host_prefix_cache_pages,
                                     disk_kv_bytes=disk_pages * page_bytes,
                                     disk_bw_bytes_s=disk_bw_bytes_s,
                                     # reduced models iterate in ~us; the
                                     # real-NVMe 100us default latency would
                                     # dwarf every TPOT at this scale
                                     disk_latency_s=disk_latency_s,
                                     disk_backing_path=disk_backing_path,
                                     async_data_plane=async_data_plane,
                                     incremental_prefill=incremental_prefill,
                                     autotune=autotune,
                                     prefetch_pages_per_boundary=
                                     prefetch_pages_per_boundary,
                                     role=role,
                                     peer_bw_bytes_s=peer_bw_bytes_s,
                                     peer_latency_s=peer_latency_s))
    return eng, an
