"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Tier-1 must run on a bare ``jax + numpy + pytest`` container. When hypothesis
is available the property tests use it (shrinking, coverage-guided search);
otherwise this shim replays each ``@given`` test over a fixed pseudo-random
sample of the declared strategies, always including the strategy endpoints so
boundary cases stay covered. Strategies implemented: the subset the test
suite uses (floats / integers / lists).
"""
from __future__ import annotations

import itertools

import numpy as np


class _Strategy:
    def __init__(self, sample, endpoints=()):
        self._sample = sample
        self.endpoints = tuple(endpoints)

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


class _St:
    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)),
                         endpoints=(min_value, max_value))

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)),
                         endpoints=(min_value, max_value))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10,
              **_kw) -> _Strategy:
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.sample(rng) for _ in range(n)]
        return _Strategy(sample,
                         endpoints=([e] * max(min_size, 1)
                                    for e in elem.endpoints))


st = _St()


def settings(max_examples: int = 100, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        n = getattr(fn, "_fallback_max_examples", 100)

        def runner():
            names = list(strategies)
            # corner cases first: all combinations of strategy endpoints
            corners = itertools.product(
                *(list(strategies[k].endpoints) or [None] for k in names))
            rng = np.random.default_rng(0)
            done = 0
            for combo in corners:
                if done >= n:
                    break
                if any(v is None for v in combo):
                    continue
                fn(**dict(zip(names, combo)))
                done += 1
            while done < n:
                fn(**{k: strategies[k].sample(rng) for k in names})
                done += 1
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco
