"""Make the shared test helpers (tests/_hypothesis_fallback.py) importable
from this sub-package the same way the top-level tests import them."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
