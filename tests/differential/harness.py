"""Differential-testing harness: paged engine vs a frozen dense reference.

``DenseShadow`` is the slot-dense decode path the engine used before the
paged-kernel rewrite, preserved here as an executable specification: plain
``Model.prefill`` / ``Model.decode_step`` over stacked [R, B, max_seq, ...]
caches, with the engine's old batch-axis insert. It does no scheduling of its
own — ``DualEngine`` drives it in lock-step with the real engine, feeding it
the same prompts, tokens, and positions the paged engine used, and asserts
the two produce matching logits and identical greedy tokens at every
iteration (prefill and decode alike). Because the shadow never touches the
allocator, interval changes, host spills, streaming, and page reuse on the
engine side must all be invisible in the numbers — that is the property the
harness machine-checks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import spec as S
from repro.serving.engine import ServingEngine


def _batch_axis(cshape: tuple, nshape: tuple) -> int:
    """Locate the batch axis: first axis where shapes differ (the frozen
    helper from the pre-paged engine)."""
    for a, (cs, ns) in enumerate(zip(cshape, nshape)):
        if cs != ns:
            return a
    return 0


class DenseShadow:
    """Frozen slot-dense reference decoder (pre-paged engine decode path)."""

    def __init__(self, model, params, max_batch: int, max_seq: int):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        caches = S.initialize(model.cache_spec(max_batch, max_seq),
                              jax.random.PRNGKey(1))
        self.caches = jax.tree.map(lambda x: x * 0, caches)
        self._jit_prefill = jax.jit(model.prefill,
                                    static_argnames=("cache_len",))
        self._jit_decode = jax.jit(model.decode_step)

    def prefill(self, prompt: np.ndarray, slot: int) -> np.ndarray:
        inputs = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
        logits, caches1, _ = self._jit_prefill(self.params, inputs,
                                               cache_len=self.max_seq)

        def ins(c, n):
            axis = _batch_axis(c.shape, n.shape)
            idx = [slice(None)] * c.ndim
            idx[axis] = slice(slot, slot + 1)
            return c.at[tuple(idx)].set(n)

        self.caches = jax.tree.map(ins, self.caches, caches1)
        return np.asarray(logits[0], np.float32)

    def decode(self, tokens: np.ndarray, pos: np.ndarray) -> np.ndarray:
        logits, self.caches = self._jit_decode(
            self.params, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32), self.caches)
        return np.asarray(logits, np.float32)


class DualEngine:
    """Steps a paged ``ServingEngine`` and its dense shadow in lock-step,
    asserting logits closeness and greedy-token agreement at every prefill
    and every decode iteration.

    Tolerance rationale: weights and KV are bf16 in both paths (the stored
    page bits are identical), so the only numeric difference is attention
    reduction order, quantized to a few bf16 ulps per layer; across L layers
    that reaches ~0.1 absolute on O(1) logits and does NOT compound over the
    trace (measured stationary — the repo's own split-vs-plain equivalence
    tests accept the same family of bounds). Logic bugs — wrong page,
    off-by-one write position, stale KV after reuse — produce O(1) divergence
    on many elements and trip the allclose gate immediately.

    Token gate: argmax must be identical unless the reference itself scores
    the two candidate tokens within the cross-implementation noise bound (a
    numeric tie, which cannot fork the trajectory because the shadow is
    teacher-forced with the engine's tokens). Ties are counted; trace tests
    bound their rate so systematic drift cannot hide behind the tie rule.
    """

    def __init__(self, engine: ServingEngine, rtol: float = 5e-2,
                 atol: float = 1e-1):
        self.eng = engine
        self.shadow = DenseShadow(engine.model, engine.params,
                                  engine.ecfg.max_batch, engine.ecfg.max_seq)
        self.rtol, self.atol = rtol, atol
        self.iters = 0
        self.decode_compares = 0
        self.prefill_compares = 0
        self.tied_tokens = 0

    def _check(self, got: np.ndarray, want: np.ndarray, what: str) -> None:
        np.testing.assert_allclose(got, want, rtol=self.rtol, atol=self.atol,
                                   err_msg=f"logit divergence at {what}")
        gi, wi = int(np.argmax(got)), int(np.argmax(want))
        if gi == wi:
            return
        tie = self.atol + self.rtol * abs(float(want[wi]))
        assert (want[wi] - want[gi] <= tie and got[gi] - got[wi] <= tie), \
            f"sampled-token divergence beyond numeric tie at {what}"
        self.tied_tokens += 1

    def step(self, **kw) -> None:
        self.eng.step(**kw)
        d = self.eng.last_decode
        # Apply shadow prefills in engine order relative to the decode: a
        # one-shot prefill activates its slot before the decode step (the
        # slot is active in last_decode), while a chunked prefill's final
        # chunk activates it after (inactive this iteration — the shadow's
        # idle-row decode write must not land on the fresh cache).
        before, after = [], []
        for e in self.eng.prefill_log:
            (before if d is not None and d["active"][e[1]] else after).append(e)

        def apply(entries):
            for req, slot, logits in entries:
                ref = self.shadow.prefill(req.prompt, slot)
                self._check(logits, ref, f"prefill rid={req.rid} slot={slot} "
                                         f"iter={self.iters}")
                self.prefill_compares += 1

        apply(before)
        if d is not None:
            ref = self.shadow.decode(d["tokens"], d["pos"])
            for slot in np.flatnonzero(d["active"]):
                self._check(d["logits"][slot], ref[slot],
                            f"decode iter={self.iters} slot={slot}")
                self.decode_compares += 1
        apply(after)
        self.iters += 1

    def run_until_drained(self, max_iters: int = 2000, **kw) -> None:
        it = 0
        while (self.eng.scheduler.has_work()
               or self.eng._active_batch() > 0) and it < max_iters:
            self.step(**kw)
            it += 1
        assert not self.eng.scheduler.has_work() \
            and self.eng._active_batch() == 0, \
            f"trace did not drain in {max_iters} iterations"


class PagedDualEngine:
    """Locksteps a prefix-dedup engine against a dedup-OFF engine (the PR-2
    paged baseline) fed the same request stream, asserting at every
    iteration that admissions, logits, and greedy tokens are identical.

    Unlike ``DualEngine`` the two sides generate independently (no teacher
    forcing), so the traces only stay comparable if dedup is numerically
    invisible. It is, by construction: a deduped page holds the *stored
    bf16 KV bits* of the origin request's prefill, and the suites pair
    requests of equal prompt length, so the baseline engine computes
    bit-identical KV for those positions itself (causal attention: prefix
    hidden states depend only on prefix tokens). Any logic bug — scatter
    into a shared frame, missing COW, stale index entry after migration —
    corrupts whole pages and trips the gates immediately.

    Both engines must be built from the same reduced config (identical
    params via the same init key) and the same memory sizing, roomy enough
    that the BASELINE admits everything it sees the same iteration the
    dedup engine does; the dedup side then has strictly spare capacity,
    which ``device_frames_saved`` reports.
    """

    def __init__(self, baseline: ServingEngine, dedup: ServingEngine,
                 rtol: float = 5e-2, atol: float = 1e-1):
        assert not baseline.ecfg.prefix_dedup and dedup.ecfg.prefix_dedup
        self.base = baseline
        self.dedup = dedup
        self.rtol, self.atol = rtol, atol
        self.iters = 0
        self.decode_compares = 0
        self.prefill_compares = 0

    def _close(self, got: np.ndarray, want: np.ndarray, what: str) -> None:
        np.testing.assert_allclose(got, want, rtol=self.rtol, atol=self.atol,
                                   err_msg=f"logit divergence at {what}")
        assert int(np.argmax(got)) == int(np.argmax(want)), \
            f"greedy-token divergence at {what}"

    def step(self, **kw) -> None:
        self.base.step(**kw)
        self.dedup.step(**kw)
        b_pre = [(r.rid, s) for r, s, _ in self.base.prefill_log]
        d_pre = [(r.rid, s) for r, s, _ in self.dedup.prefill_log]
        assert b_pre == d_pre, \
            f"admission divergence at iter={self.iters}: {b_pre} != {d_pre}"
        for (br, bs, bl), (_, _, dl) in zip(self.base.prefill_log,
                                            self.dedup.prefill_log):
            self._close(dl, bl, f"prefill rid={br.rid} iter={self.iters}")
            self.prefill_compares += 1
        b, d = self.base.last_decode, self.dedup.last_decode
        assert (b is None) == (d is None)
        if b is not None:
            assert np.array_equal(b["active"], d["active"])
            assert np.array_equal(b["tokens"], d["tokens"])
            assert np.array_equal(b["pos"], d["pos"])
            for slot in np.flatnonzero(b["active"]):
                self._close(d["logits"][slot], b["logits"][slot],
                            f"decode iter={self.iters} slot={slot}")
                self.decode_compares += 1
        self.iters += 1

    def run_until_drained(self, max_iters: int = 2000, **kw) -> None:
        it = 0
        while (self.base.scheduler.has_work()
               or self.base._active_batch() > 0
               or self.dedup.scheduler.has_work()
               or self.dedup._active_batch() > 0) and it < max_iters:
            self.step(**kw)
            it += 1
        for eng in (self.base, self.dedup):
            assert not eng.scheduler.has_work() \
                and eng._active_batch() == 0, \
                f"trace did not drain in {max_iters} iterations"

    def device_frames_saved(self) -> int:
        return self.base.device_pages_peak - self.dedup.device_pages_peak
