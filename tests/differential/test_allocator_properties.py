"""Property-based test: drive ``TieredKVAllocator`` with random
alloc / extend / swap_in / swap_out / resize / free sequences and assert the
structural invariants after every single operation:

  * no page ref is on both tiers, and the per-request refs lists exactly
    match the per-tier pools (``check_invariants``),
  * every live request holds exactly ``pages_for(tokens)`` refs,
  * a failed extend rolls back to the exact prior refs list (demotions may
    remain per the documented contract: the data plane may already have
    copied them, so a DEVICE ref may have turned HOST — nothing else),
  * resize either raises without mutating (overflow > host capacity) or
    returns demotions + remap consistent with the new refs.

Runs under real hypothesis when installed, else the deterministic fallback
shim — pure accounting, no JAX compiles: fast CI tier.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

import pytest

from repro.serving.kv_cache import PageConfig
from repro.serving.kv_offload import (CACHE_RID, DEVICE, DISK, HOST,
                                      LinkSpec, PageRef, TieredKVAllocator)

PAGE = 4   # tokens per page
BPT = 4    # bytes per token
PB = PAGE * BPT


def _page_count(kv, rid, tier):
    return len(kv.device_pages_of(rid) if tier == DEVICE
               else kv.host_pages_of(rid))


@given(codes=st.lists(st.integers(0, (1 << 30) - 1), min_size=0, max_size=50),
       dev_pages=st.integers(0, 10), host_pages=st.integers(0, 10))
@settings(max_examples=80, deadline=None)
def test_tiered_allocator_random_op_sequences(codes, dev_pages, host_pages):
    kv = TieredKVAllocator(dev_pages * PB, host_pages * PB,
                           PageConfig(PAGE, bytes_per_token=BPT))
    tokens: dict[int, int] = {}          # live rid -> token count
    next_rid = 0
    for code in codes:
        op, arg = code % 6, code // 6
        alive = sorted(tokens)
        if op == 0:                                          # alloc
            want = arg % ((dev_pages + host_pages + 2) * PAGE) + 1
            refs = kv.alloc(next_rid, want, allow_host=bool(arg % 2))
            if refs is not None:
                assert len(refs) == kv.device.pages_for(want)
                tokens[next_rid] = want
                next_rid += 1
            else:
                kv.free(next_rid)        # nothing claimed: must be a no-op
        elif op == 1 and alive:                              # extend
            rid = alive[arg % len(alive)]
            before = kv.refs(rid)
            new_total = tokens[rid] + arg % (3 * PAGE) + 1
            out = kv.extend(rid, new_total, allow_host=bool(arg % 2))
            after = kv.refs(rid)
            if out is None:
                # exact rollback: same length, and position-wise either the
                # identical ref or a documented DEVICE->HOST demotion
                assert len(after) == len(before)
                for b4, now in zip(before, after):
                    assert now == b4 or (b4.tier == DEVICE
                                         and now.tier == HOST)
            else:
                tokens[rid] = new_total
        elif op == 2 and alive:                              # swap_out
            rid = alive[arg % len(alive)]
            n_dev = _page_count(kv, rid, DEVICE)
            free_host = kv.host.free_pages
            moves = kv.swap_out(rid, arg % 3 + 1)
            assert len(moves) == min(arg % 3 + 1, n_dev, free_host)
            for m in moves:
                assert m.dst_page in kv.host_pages_of(rid)
        elif op == 3 and alive:                              # swap_in
            rid = alive[arg % len(alive)]
            n_host = _page_count(kv, rid, HOST)
            moves = kv.swap_in(rid, arg % 3 + 1)
            assert len(moves) <= min(arg % 3 + 1, n_host)
        elif op == 4:                                        # resize
            new_bytes = (arg % (dev_pages + 4)) * PB
            if kv.can_resize_device(new_bytes):
                res = kv.resize_device(new_bytes)
                # remap's new frames are exactly the surviving device pages
                live_dev = sorted(p for r in tokens
                                  for p in kv.device_pages_of(r))
                assert sorted(n for _, n in res.remap) == live_dev
                for m in res.demotions:
                    assert m.src_tier == DEVICE
                    assert m.dst_page in kv.host_pages_of(m.rid)
            else:
                snapshot = {r: kv.refs(r) for r in alive}
                with pytest.raises(RuntimeError):
                    kv.resize_device(new_bytes)
                # failed resize must not have mutated anything
                assert {r: kv.refs(r) for r in alive} == snapshot
        elif op == 5 and alive:                              # free
            rid = alive[arg % len(alive)]
            kv.free(rid)
            del tokens[rid]
            assert kv.refs(rid) == []

        # ---- invariants after every operation -----------------------------
        kv.check_invariants()            # tiers/pools/refs exactly consistent
        for rid, tok in tokens.items():
            refs = kv.refs(rid)
            assert len(refs) == kv.device.pages_for(tok)
            # no ref claims both tiers; per-tier counts match the pools
            assert (_page_count(kv, rid, DEVICE)
                    + _page_count(kv, rid, HOST)) == len(refs)

    for rid in list(tokens):
        kv.free(rid)
    kv.check_invariants()
    assert kv.device.used_pages == 0 and kv.host.used_pages == 0


# ---------------------------------------------------------------------------
# Refcounted sharing / copy-on-write property test
# ---------------------------------------------------------------------------


def _total_refcounts(kv) -> int:
    return (sum(kv.device._rc.values()) + sum(kv.host._rc.values()))


def _live_references(kv) -> int:
    """Block-table entries + COW reserves across all live requests."""
    return (sum(len(refs) for refs in kv._refs.values())
            + kv.n_reserve_frames())


@given(codes=st.lists(st.integers(0, (1 << 30) - 1), min_size=0, max_size=60),
       dev_pages=st.integers(0, 12), host_pages=st.integers(0, 12))
@settings(max_examples=80, deadline=None)
def test_refcounted_dedup_random_op_sequences(codes, dev_pages, host_pages):
    """Drive the dedup-enabled allocator with random share / write(COW) /
    swap / free / resize sequences. After EVERY operation:

      * the sum of pool refcounts equals the number of live references
        (block-table entries + COW reserves) — nothing leaked, nothing
        double-freed,
      * ``check_invariants`` holds (pool partition, refcount multiplicity,
        reserve privacy, index <-> frame consistency),
      * every live request still holds exactly ``pages_for(tokens)``
        block-table entries.

    Prompts are drawn from 3 families so shared prefixes actually occur;
    writes replay the engine's decode write sequence (position = prompt_len
    + generated so far) through ``prepare_write``, which is where COW fires.
    """
    kv = TieredKVAllocator(dev_pages * PB, host_pages * PB,
                           PageConfig(PAGE, bytes_per_token=BPT),
                           scope="prop", enable_dedup=True)
    state: dict[int, dict] = {}       # rid -> {tokens, prompt_len, written}
    next_rid = 0
    for code in codes:
        op, arg = code % 6, code // 6
        alive = sorted(state)
        if op == 0:                                          # alloc w/ prompt
            fam = arg % 3
            plen = arg // 3 % (3 * PAGE) + 1
            extra = arg // 9 % (2 * PAGE)
            prompt = (np.arange(plen, dtype=np.int64) + 10_000 * fam)
            refs = kv.alloc(next_rid, plen + extra,
                            allow_host=bool(arg % 2), prompt=prompt)
            if refs is not None:
                assert len(refs) == kv.device.pages_for(plen + extra)
                state[next_rid] = {"tokens": plen + extra, "plen": plen,
                                   "written": 0}
                next_rid += 1
            else:
                kv.free(next_rid)    # nothing claimed: must be a no-op
        elif op == 1 and alive:                              # decode write
            rid = alive[arg % len(alive)]
            s = state[rid]
            pos = s["plen"] + s["written"]
            if pos < s["tokens"]:
                before = kv.refs(rid)
                moves = kv.prepare_write(rid, pos // PAGE)
                after = kv.refs(rid)
                for m in moves:
                    # COW swaps exactly the written page, onto a private
                    # frame, without disturbing any other entry
                    assert m.rid == rid
                    assert before[pos // PAGE] == m.src
                    assert after[pos // PAGE] == m.dst
                    assert kv.refcount(m.dst) == 1
                assert [r for i, r in enumerate(before)
                        if i != pos // PAGE] == \
                    [r for i, r in enumerate(after) if i != pos // PAGE]
                # the written page is now safe: private, or rid is its origin
                wref = kv.refs(rid)[pos // PAGE]
                assert kv.refcount(wref) == 1 or \
                    kv.reserve_of(rid) is None
                s["written"] += 1
        elif op == 2 and alive:                              # swap_out
            rid = alive[arg % len(alive)]
            moves = kv.swap_out(rid, arg % 3 + 1)
            for m in moves:          # a shared frame moved for every owner
                assert all(PageRef(DEVICE, m.src_page) not in kv.refs(r)
                           for r in alive)
        elif op == 3 and alive:                              # swap_in
            rid = alive[arg % len(alive)]
            kv.swap_in(rid, arg % 3 + 1)
        elif op == 4:                                        # resize
            new_bytes = (arg % (dev_pages + 4)) * PB
            if kv.can_resize_device(new_bytes):
                res = kv.resize_device(new_bytes)
                live_dev = sorted({p for r in state
                                   for p in kv.device_pages_of(r)}
                                  | {v.page for m in kv._reserves.values()
                                     for v in m.values()
                                     if v.tier == DEVICE})
                assert sorted(n for _, n in res.remap) == live_dev
            else:
                with pytest.raises(RuntimeError):
                    kv.resize_device(new_bytes)
        elif op == 5 and alive:                              # free
            rid = alive[arg % len(alive)]
            kv.free(rid)
            del state[rid]
            assert kv.refs(rid) == []

        # ---- invariants after every operation -----------------------------
        kv.check_invariants()
        assert _total_refcounts(kv) == _live_references(kv), \
            "refcount sum != live block-table entries + reserves"
        for rid, s in state.items():
            assert len(kv.refs(rid)) == kv.device.pages_for(s["tokens"])

    for rid in list(state):
        kv.free(rid)
    kv.check_invariants()
    assert kv.device.used_pages == 0 and kv.host.used_pages == 0
    assert _total_refcounts(kv) == 0
    assert len(kv.index) == 0, "prefix index outlived its frames"


# ---------------------------------------------------------------------------
# Three-tier (device / host / disk) property test
# ---------------------------------------------------------------------------


def _total_refcounts_3t(kv) -> int:
    return sum(sum(pool._rc.values()) for pool in kv.pools.values())


def _cache_claims(kv) -> int:
    """CACHE_RID's keep-alive claims across both below-device tiers."""
    return len(kv._cache_lru) + len(kv._disk_cache)


@given(codes=st.lists(st.integers(0, (1 << 30) - 1), min_size=0, max_size=60),
       dev_pages=st.integers(0, 10), host_pages=st.integers(0, 10),
       disk_pages=st.integers(0, 10))
@settings(max_examples=80, deadline=None)
def test_three_tier_random_op_sequences(codes, dev_pages, host_pages,
                                        disk_pages):
    """Drive the THREE-tier allocator (dedup + keep-alive cache on, so
    host-pressure reclaim exercises the cache-to-disk retirement path) with
    random alloc / demote / promote / park-to-disk / resume / resize / free
    sequences. After EVERY operation:

      * per-tier refcount sums == live references (block-table entries +
        COW reserves + keep-alive cache claims on host AND disk),
      * ``check_invariants`` (pool partitions, ref/pool agreement per tier,
        reserve privacy, index <-> frame consistency, cache LRU <-> pool),
      * every live request holds exactly ``pages_for(tokens)`` refs and no
        capacity is conjured (pool invariants bound used <= total),
      * disk pages only ever belong to requests the caller treats as
        parked — an "active" request (even subset) never loses a page to
        disk.
    """
    kv = TieredKVAllocator(dev_pages * PB, host_pages * PB,
                           PageConfig(PAGE, bytes_per_token=BPT),
                           scope="3t", enable_dedup=True,
                           host_prefix_cache_pages=3,
                           disk_bytes=disk_pages * PB,
                           disk_link=LinkSpec(bw_bytes_s=1e9))
    tokens: dict[int, int] = {}
    next_rid = 0
    for code in codes:
        op, arg = code % 7, code // 7
        alive = sorted(tokens)
        # a deterministic "active" subset: parity of the rid + arg salt
        active = [r for r in alive if (r + arg) % 3 != 0]
        if op == 0:                                          # alloc w/ prompt
            fam = arg % 3
            plen = arg // 3 % (3 * PAGE) + 1
            extra = arg // 9 % (2 * PAGE)
            prompt = (np.arange(plen, dtype=np.int64) + 10_000 * fam)
            refs = kv.alloc(next_rid, plen + extra,
                            allow_host=bool(arg % 2), prompt=prompt)
            if refs is not None:
                assert len(refs) == kv.device.pages_for(plen + extra)
                assert all(r.tier != DISK for r in refs), \
                    "alloc mapped a disk page without revival"
                tokens[next_rid] = plen + extra
                next_rid += 1
            else:
                kv.free(next_rid)    # nothing claimed: must be a no-op
        elif op == 1 and alive:                              # swap_out
            rid = alive[arg % len(alive)]
            kv.swap_out(rid, arg % 3 + 1, active_rids=active)
        elif op == 2 and alive:                              # swap_in
            rid = alive[arg % len(alive)]
            kv.swap_in(rid, arg % 3 + 1)
        elif op == 3 and alive:                              # park to disk
            rid = alive[arg % len(alive)]
            if rid not in active:
                before = {r: set(kv.disk_pages_of(r)) for r in active}
                moves = kv.demote_to_disk(rid, arg % 4 + 1, active)
                for m in moves:
                    assert m.src_tier == HOST and m.dst_tier == DISK
                for r in active:
                    assert set(kv.disk_pages_of(r)) == before[r], \
                        "an active request lost a page to disk"
        elif op == 4 and alive:                              # resume
            rid = alive[arg % len(alive)]
            out = kv.resume(rid)
            if out is None:
                assert kv.disk_pages_of(rid), \
                    "resume refused without disk pages to stage"
            else:
                assert kv.disk_pages_of(rid) == [], \
                    "resume left disk pages behind"
        elif op == 5:                                        # resize
            new_bytes = (arg % (dev_pages + 4)) * PB
            if kv.can_resize_device(new_bytes):
                kv.resize_device(new_bytes)
            else:
                with pytest.raises(RuntimeError):
                    kv.resize_device(new_bytes)
        elif op == 6 and alive:                              # free
            rid = alive[arg % len(alive)]
            kv.free(rid)
            del tokens[rid]
            assert kv.refs(rid) == []

        # ---- invariants after every operation -----------------------------
        kv.check_invariants()
        live = (sum(len(refs) for refs in kv._refs.values())
                + kv.n_reserve_frames() + _cache_claims(kv))
        assert _total_refcounts_3t(kv) == live, \
            "refcount sum != live refs + reserves + cache claims"
        for rid, tok in tokens.items():
            refs = kv.refs(rid)
            assert len(refs) == kv.device.pages_for(tok)
            per_tier = sum(len(kv.tier_pages_of(rid, t))
                           for t in (DEVICE, HOST, DISK))
            assert per_tier == len(refs), "a ref claims several tiers"

    for rid in list(tokens):
        kv.free(rid)
    kv.check_invariants()
    # only keep-alive cache claims may outlive the requests
    assert _total_refcounts_3t(kv) == _cache_claims(kv)
    assert kv.device.used_pages == 0
    assert kv.host.used_pages == len(kv._cache_lru)
    assert kv.disk.used_pages == len(kv._disk_cache)
