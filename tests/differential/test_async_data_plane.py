"""Async data plane + incremental prefill differential traces (nightly).

The async copy-stage engine moves the physical page copies off the modeled
critical path — but the tokens, the modeled clock, and the conservation
audit must be UNCHANGED: both engines run the same plans over the same
accounting plane, so any divergence is a hazard bug (a copy observed the
wrong bytes) rather than a policy difference. Two traces:

  * **Disk pressure**: the fig18 shape — parks overflow to NVMe, resumes
    stage disk -> host -> device, and the async run additionally prefetches
    parked pages ahead of their predicted resume. Bitwise tokens, exactly
    equal modeled clocks, clean audits (including the I10 copy-stage
    conservation check, which only the async run exercises non-trivially).
  * **Preempt/resume without disk traffic**: parks and resume promotions
    ride the plane's queue alone — the reorder window is largest here
    because nothing forces an early drain.

Plus the incremental-prefill gate: with the chunk kernel on, the engine
locksteps the frozen dense reference (final-chunk logits + every decode
row) while the REAL prefill compute drops from quadratic to linear in the
chunk schedule.
"""
import numpy as np
import pytest

from repro.core.interval import iter_time_with_interval_kv
from repro.serving.request import Request
from repro.serving.telemetry import audit_trace

from _engine_builders import mk_reduced_engine
from harness import DualEngine

pytestmark = pytest.mark.slow


def _req(rng, rid, plen, new, tpot):
    return Request(rid=rid, prompt=rng.integers(0, 100, plen
                                                ).astype(np.int32),
                   max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=tpot)


def _tpot_short(eng):
    pb = eng.kv.page_bytes
    dt_1 = iter_time_with_interval_kv(eng.times_fn(4, 48, "decode"),
                                      eng.interval, 1 * pb)
    dt_2 = iter_time_with_interval_kv(eng.times_fn(1, 48, "decode"),
                                      eng.interval, 2 * pb)
    assert dt_1 < dt_2
    return (dt_1 + dt_2) / 2


def _drain(eng, max_iters=400):
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) \
            and it < max_iters:
        eng.step()
        it += 1
    assert it < max_iters, "trace did not drain"
    eng.kv.check_invariants()
    report = eng.trace.audit()
    assert report.ok, report.violations
    return eng


def _run_disk_pressure(async_plane: bool):
    """The fig18 pressure trace from test_disk_tier, async on/off."""
    eng, _ = mk_reduced_engine(name=f"adp{async_plane}", max_batch=4,
                               max_seq=48, page_size=8,
                               extra_device_pages=4, host_pages=2,
                               preemption=True, disk_pages=16,
                               async_data_plane=async_plane,
                               batches=(1, 2, 4), seqs=(16, 32, 64))
    tpot = _tpot_short(eng)
    rng = np.random.default_rng(11)
    s0 = _req(rng, 9, 4, 12, 1e-3)
    l1 = _req(rng, 0, 16, 16, 1e-3)
    shorts = [_req(rng, i, 4, 4, tpot) for i in range(1, 5)]
    eng.submit(s0)
    eng.submit(l1)
    eng.step()
    eng.step()
    for s in shorts:
        eng.submit(s)
    return _drain(eng)


def _run_preempt_burst(async_plane: bool):
    """The preemption burst with a disk tier attached but ample host: every
    park/resume rides the plane's d2h/h2d queue, no NVMe traffic."""
    eng, _ = mk_reduced_engine(name=f"apb{async_plane}", max_batch=4,
                               max_seq=48, page_size=8,
                               extra_device_pages=4, host_pages=64,
                               preemption=True, disk_pages=16,
                               async_data_plane=async_plane,
                               batches=(1, 2, 4), seqs=(16, 32, 64))
    tpot = _tpot_short(eng)
    rng = np.random.default_rng(3)
    s0 = _req(rng, 0, 4, 12, 1e-3)
    long_req = _req(rng, 1, 16, 16, 1e-3)
    shorts = [_req(rng, i, 4, 4, tpot) for i in range(2, 8)]
    eng.submit(s0)
    eng.submit(long_req)
    eng.step()
    eng.step()
    for s in shorts:
        eng.submit(s)
    return _drain(eng)


def _assert_equivalent(sync_eng, async_eng, expect_disk: bool,
                       exact_clock: bool = True):
    # bitwise greedy tokens per request
    tok_s = {r.rid: list(r.generated) for r in sync_eng.finished}
    tok_a = {r.rid: list(r.generated) for r in async_eng.finished}
    assert tok_s.keys() == tok_a.keys()
    for rid in tok_s:
        assert tok_s[rid] == tok_a[rid], f"token divergence rid={rid}"
    if exact_clock:
        # EXACTLY the same modeled clock: without prefetch the async plane
        # moves physical copies, never modeled charges
        assert async_eng.clock_s == sync_eng.clock_s
    else:
        # prefetch shifts NVMe charges to earlier iterations (honest
        # accounting, different timing) — the clocks stay within a hair
        # and every request still meets its SLOs in both runs
        assert abs(async_eng.clock_s - sync_eng.clock_s) \
            <= 0.02 * sync_eng.clock_s
        for eng in (sync_eng, async_eng):
            for r in eng.finished:
                m = r.metrics()
                assert m["tpot_ok"] and m["ttft_ok"], f"SLO miss rid={r.rid}"
    # the async run actually queued work and finished it all
    foot_a = async_eng.trace.footer()
    assert foot_a["staged_issued_pages_total"] > 0
    assert foot_a["staged_inflight_pages"] == 0
    assert foot_a["staged_issued_pages_total"] \
        == foot_a["staged_completed_pages_total"]
    # sync mode completes every op in the iteration that issued it
    for r in sync_eng.trace.iterations:
        assert r.staged_issued_pages == r.staged_completed_pages
    if expect_disk:
        assert sync_eng.kv.disk_out_pages_total > 0


def test_async_disk_pressure_bitwise_and_clock_identical():
    sync_eng = _run_disk_pressure(async_plane=False)
    async_eng = _run_disk_pressure(async_plane=True)
    _assert_equivalent(sync_eng, async_eng, expect_disk=True,
                       exact_clock=False)
    # at least one iteration's copies were still in flight at its end —
    # the plane really deferred work past the boundary that issued it
    deferred = any(r.staged_issued_pages != r.staged_completed_pages
                   for r in async_eng.trace.iterations)
    assert deferred, "async run never overlapped a copy"
    # the staged prefetch engaged, and it creates no extra NVMe traffic:
    # every disk page is still read exactly once per round trip
    assert async_eng.prefetch_pages_total >= 1
    assert sync_eng.prefetch_pages_total == 0
    assert async_eng.kv.disk_in_pages_total == sync_eng.kv.disk_in_pages_total
    assert async_eng.kv.disk_out_pages_total \
        == sync_eng.kv.disk_out_pages_total


def test_async_preempt_burst_bitwise_and_clock_identical():
    sync_eng = _run_preempt_burst(async_plane=False)
    async_eng = _run_preempt_burst(async_plane=True)
    assert async_eng.scheduler.stats["preemptions"] >= 1
    _assert_equivalent(sync_eng, async_eng, expect_disk=False)


def test_async_trace_roundtrip_audits_offline():
    """The exported async trace (dict -> json -> dict) passes audit_trace
    offline, staged counters included — the CI smoke's exact path."""
    import json
    eng = _run_disk_pressure(async_plane=True)
    rt = json.loads(json.dumps(eng.trace.to_dict()))
    report = audit_trace(rt)
    assert report.ok, report.violations
    assert rt["footer"]["staged_issued_pages_total"] > 0


def test_incremental_prefill_locksteps_and_is_linear():
    """Incremental chunk kernel vs the frozen dense reference, and the
    end of quadratic recompute: total prefill tokens computed must equal
    the summed prompt lengths exactly (the recompute path pays the full
    prefix again on every chunk)."""
    eng, _ = mk_reduced_engine(name="incr", max_batch=2, max_seq=32,
                               page_size=8, extra_device_pages=16,
                               host_pages=0, prefill_chunk_tokens=8,
                               incremental_prefill=True,
                               batches=(1, 2, 4), seqs=(16, 32, 64))
    dual = DualEngine(eng)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 100, 6 + 7 * (i % 3)
                                        ).astype(np.int32),
                    max_new_tokens=8, ttft_slo_s=10.0, tpot_slo_s=10.0)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    dual.run_until_drained(max_iters=400)
    assert len(eng.finished) == 6
    for r in eng.finished:
        assert len(r.generated) == 8
        assert r.prefill_pos == r.prompt_len
    assert dual.prefill_compares == 6
    assert dual.decode_compares >= 6 * 7
    # linear, not quadratic: every prompt token computed exactly once
    assert eng.prefill_tokens_computed == sum(len(r.prompt) for r in reqs)
    eng.kv.check_invariants()


def test_recompute_prefill_is_quadratic_baseline():
    """Pin the bug the incremental kernel fixes: the recompute path's real
    compute strictly exceeds the summed prompt lengths whenever a prompt
    spans several chunks."""
    eng, _ = mk_reduced_engine(name="quad", max_batch=2, max_seq=32,
                               page_size=8, extra_device_pages=16,
                               host_pages=0, prefill_chunk_tokens=8,
                               batches=(1, 2, 4), seqs=(16, 32, 64))
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(0, 100, 24).astype(np.int32),
                  max_new_tokens=4, ttft_slo_s=10.0, tpot_slo_s=10.0)
    eng.submit(req)
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 50:
        eng.step()
        it += 1
    assert len(eng.finished) == 1
    # chunks at 8/16/24: recompute pays 8 + 16 + 24 = 48 > 24
    assert eng.prefill_tokens_computed == 48
