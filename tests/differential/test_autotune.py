"""Sustained-load autotuning differential traces (nightly tier).

Gates, mirroring how previous subsystems were landed:

  * **Sweep**: the fig19 scenario at a reduced request count — autotuned
    vs every fixed interval in the offline range {1, 2} on the same
    arrival-honored diurnal trace. The autotuned run must be the only
    SLO-clean *and* throughput-undominated configuration, while hosting
    strictly more weight bytes (time-averaged) than the SLO-clean fixed
    choice, with bitwise-identical greedy tokens: the interval schedule
    changes timing and memory placement, never the numbers.
  * **Lockstep**: a ``DualEngine`` dense-shadow run over an autotuned
    engine whose tuner provably moves mid-trace — every prefill and decode
    logit is checked against the frozen slot-dense reference across the
    interval switches.
  * **Regressions** for the bug family underneath: arrivals honored on the
    modeled clock (no admission before ``arrival_s``), the ``submit_all``
    compat path bitwise-unchanged, ``set_interval`` refusing (not
    corrupting) a resize that would orphan live KV, and the coordinator
    floor ``_min_interval_now`` folding ACTIVE requests, not just the head
    of the queue.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.interval import NO_OFFLOAD
from repro.serving.request import Request

from _engine_builders import mk_reduced_engine
from harness import DualEngine

import benchmarks.fig19_sustained_load as fig19

pytestmark = pytest.mark.slow

N_SWEEP = 40


@pytest.fixture(scope="module")
def sweep():
    """fig19's engines on fig19's workload, reduced to 40 requests."""
    reqs = fig19.workload(N_SWEEP)
    out = {}
    for name, fixed in [("auto", None), ("fixed-1", 1), ("fixed-2", 2)]:
        eng = fig19.mk_engine(name, autotune=fixed is None)
        if fixed is not None:
            assert eng.set_interval(fixed)
        summary = eng.run(fig19.clone_requests(reqs), max_iters=100_000)
        out[name] = (eng, summary)
    return reqs, out


def _violations(summary):
    return sum((0 if m["tpot_ok"] else 1) + (0 if m["ttft_ok"] else 1)
               for m in summary["per_request"])


def test_sweep_arrivals_honored(sweep):
    reqs, out = sweep
    for eng, summary in out.values():
        assert summary["arrivals_honored"]
        assert summary["first_arrival_s"] == reqs[0].arrival_s > 0
        assert summary["first_admit_s"] >= summary["first_arrival_s"]
        assert summary["idle_wait_s"] > 0      # diurnal troughs drain it


def test_sweep_all_finish_and_audit_clean(sweep):
    reqs, out = sweep
    for eng, summary in out.values():
        assert summary["finished"] == len(reqs)
        assert summary["rejected"] == 0
        rep = eng.trace.audit()
        assert rep.ok, rep.violations[:5]


def test_sweep_only_autotuned_is_slo_clean(sweep):
    _, out = sweep
    assert _violations(out["auto"][1]) == 0
    assert _violations(out["fixed-1"][1]) > 0   # 2.46ms iters vs 2ms TPOT
    assert _violations(out["fixed-2"][1]) == 0  # the safe-but-small choice


def test_sweep_autotuned_throughput_undominated(sweep):
    _, out = sweep
    tput = {k: s["throughput_tok_s"] for k, (_, s) in out.items()}
    assert tput["auto"] >= tput["fixed-2"] * (1 - 1e-9)
    assert tput["auto"] > tput["fixed-1"]       # strict over the violator


def test_sweep_autotuned_hosts_more_weight_bytes(sweep):
    _, out = sweep
    auto, _ = out["auto"]
    fixed2, _ = out["fixed-2"]
    a = fig19.hosted_bytes_time_avg(auto)
    f2 = fig19.hosted_bytes_time_avg(fixed2)
    assert a > f2                               # the paper's objective
    assert auto.tuner.lifts > 0 and auto.tuner.retreats > 0
    assert auto.interval_switches >= 2


def test_sweep_tokens_bitwise_equal_best_fixed(sweep):
    _, out = sweep
    auto, _ = out["auto"]
    fixed2, _ = out["fixed-2"]
    toks_a = {r.rid: list(r.generated) for r in auto.finished}
    toks_f = {r.rid: list(r.generated) for r in fixed2.finished}
    assert toks_a == toks_f


# --------------------------------------------------------------------------
# Dense-shadow lockstep across live interval switches
# --------------------------------------------------------------------------

def test_dual_engine_lockstep_across_tuner_switches():
    """Interactive requests pin interval 2; once only the loose class
    remains, the tuner lifts host-ward to 1 — the shadow must agree on
    every logit through the switch (and through the retreat demotions a
    later tight arrival would force)."""
    eng = fig19.mk_engine("dual-auto", autotune=True)
    rng = np.random.default_rng(3)

    def req(rid, tpot, new):
        return Request(rid=rid,
                       prompt=rng.integers(0, fig19.VOCAB, 16
                                           ).astype(np.int32),
                       max_new_tokens=new, ttft_slo_s=1.0, tpot_slo_s=tpot)

    for rid in range(4):                        # interactive: short outputs
        eng.submit(req(rid, 0.002, 4))
    for rid in range(4, 10):                    # loose class: long outputs
        eng.submit(req(rid, 0.02, 14))
    dual = DualEngine(eng)
    dual.run_until_drained(max_iters=500)
    assert len(eng.finished) == 10
    assert dual.decode_compares > 0 and dual.prefill_compares == 10
    assert eng.interval_switches >= 1, \
        "trace never exercised a live interval switch"
    assert eng.tuner.lifts >= 1


# --------------------------------------------------------------------------
# Regressions: the fixed-interval bug family
# --------------------------------------------------------------------------

def _small_engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 48)
    kw.setdefault("page_size", 16)
    eng, _ = mk_reduced_engine(extra_device_pages=kw.pop("pages", 8), **kw)
    return eng


def _small_req(rid, arrival_s=0.0, new=4):
    rng = np.random.default_rng(100 + rid)
    return Request(rid=rid, prompt=rng.integers(0, 100, 8).astype(np.int32),
                   max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=1.0,
                   arrival_s=arrival_s)


def test_arrival_not_admitted_before_arrival_s():
    eng = _small_engine()
    req = _small_req(0, arrival_s=0.05)
    summary = eng.run([req])
    assert summary["first_arrival_s"] == 0.05
    assert summary["first_admit_s"] >= 0.05
    admits = [e.t_s for e in eng.trace.events if e.kind == "admit"]
    assert admits and min(admits) >= 0.05
    # the engine was empty until then: the idle jump IS the arrival gap
    assert summary["idle_wait_s"] == pytest.approx(0.05)
    # queueing delay measured from arrival, not from t=0
    assert req.submitted_s == 0.05
    m = req.metrics()
    assert m["queue_delay_s"] is not None and m["queue_delay_s"] < 0.05


def test_submit_all_compat_path_is_bitwise_unchanged():
    """submit_all=True with nonzero arrivals must reproduce the pre-arrival
    engine exactly: same modeled clock, same tokens as arrival_s=0."""
    reqs_arr = [_small_req(i, arrival_s=0.01 * (i + 1)) for i in range(4)]
    reqs_zero = [dataclasses.replace(_small_req(i), arrival_s=0.0)
                 for i in range(4)]
    a = _small_engine(name="compat-a")
    b = _small_engine(name="compat-b")
    sa = a.run(reqs_arr, submit_all=True)
    sb = b.run(reqs_zero)
    assert not sa["arrivals_honored"] and sb["arrivals_honored"]
    assert a.clock_s == b.clock_s
    assert sa["idle_wait_s"] == sb["idle_wait_s"] == 0.0
    toks_a = {r.rid: list(r.generated) for r in a.finished}
    toks_b = {r.rid: list(r.generated) for r in b.finished}
    assert toks_a == toks_b


def test_set_interval_refusal_leaves_engine_intact():
    """Growing the resident set must be REFUSED when the displaced KV has
    nowhere to go (host pool absent), not silently corrupt live pages."""
    eng = _small_engine(pages=8, host_pages=0, page_size=8, max_seq=48)
    assert eng.set_interval(1)                  # tiny resident set, huge pool
    for i in range(2):
        eng.submit(_small_req(i, new=40))
    for _ in range(80):
        eng.step()
        if eng.kv.device.used_pages > 8:
            break
    used = eng.kv.device.used_pages
    assert used > 8, "trace too small to exercise the refusal"
    assert eng.set_interval(NO_OFFLOAD) is False
    assert eng.interval == 1                    # position held
    assert eng.interval_refusals == 1
    assert eng._trace_footer()["interval_refusals_total"] == 1
    assert eng.kv.device.used_pages == used     # nothing moved
    # and the trace drains cleanly afterwards
    while eng.scheduler.has_work() or eng._active_batch() > 0:
        eng.step()
    assert len(eng.finished) == 2
    rep = eng.trace.audit()
    assert rep.ok, rep.violations[:5]


def test_min_interval_folds_active_slots_not_just_queue_head():
    """A tight-TPOT request already DECODING must raise the coordinator
    floor exactly like a tight waiter would (the old code only looked at
    the head of the queue, so a rebalance could break a live request)."""
    eng = fig19.mk_engine("floor")
    loose = Request(rid=0, prompt=np.arange(16, dtype=np.int32),
                    max_new_tokens=8, ttft_slo_s=1.0, tpot_slo_s=0.02)
    eng.submit(loose)
    eng.step()
    assert eng._active_batch() == 1 and not eng.queue
    floor_loose = eng._min_interval_now()
    assert floor_loose == eng.rec["decode"].lookup(0.02, 1, 24)

    tight = Request(rid=1, prompt=np.arange(16, dtype=np.int32),
                    max_new_tokens=8, ttft_slo_s=1.0, tpot_slo_s=0.002)
    eng.submit(tight)
    eng.step()
    assert eng._active_batch() == 2 and not eng.queue
    floor_both = eng._min_interval_now()
    want = eng.rec["decode"].lookup(0.002, 2, 24)
    assert floor_both == want > floor_loose
    assert want == 2        # 2.46ms interval-1 iters cannot meet 2ms TPOT
