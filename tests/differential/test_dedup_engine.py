"""Dedup differential suite: serving with cross-request prefix dedup +
copy-on-write pages must be indistinguishable from the PR-2 paged engine —
same logits, same greedy tokens, same admissions — while allocating strictly
fewer physical frames.

Two layers:

  * accounting differentials (fast CI tier, no JAX): a dedup-enabled
    ``TieredKVAllocator`` replays the same request trace as a dedup-off one
    and must preserve every per-request page count while never using more
    frames, across sharing, COW, migration, and resize;
  * full-engine lock-step traces (``PagedDualEngine``, compile-heavy:
    nightly tier): a dedup engine and a baseline engine consume the same
    shared-prefix request stream and must emit identical logits/tokens at
    every prefill and decode iteration, with >= 40% peak device-frame
    savings on the acceptance trace (4 requests, 75%-length common prefix).
"""
import numpy as np
import pytest

from repro.serving.kv_cache import PageConfig
from repro.serving.kv_offload import (DEVICE, HOST, SwapScheduler,
                                      TieredKVAllocator)

from _engine_builders import mk_reduced_engine
from harness import PagedDualEngine

PAGE = 4
BPT = 4
PB = PAGE * BPT


def _pair(dev_pages: int, host_pages: int) -> tuple[TieredKVAllocator,
                                                    TieredKVAllocator]:
    mk = lambda dedup: TieredKVAllocator(  # noqa: E731
        dev_pages * PB, host_pages * PB, PageConfig(PAGE, bytes_per_token=BPT),
        scope="m0", enable_dedup=dedup)
    return mk(False), mk(True)


def _prompt(family: int, n: int) -> np.ndarray:
    return (np.arange(n, dtype=np.int32) + 1000 * family)


# ---------------------------------------------------------------------------
# Accounting differentials (fast tier)
# ---------------------------------------------------------------------------


def test_dedup_accounting_matches_baseline_on_shared_trace():
    """Same trace through both allocators: every request sees the same page
    count at every step (capacity semantics unchanged), the dedup side never
    uses more frames, and it uses strictly fewer once prompts share."""
    base, dd = _pair(64, 16)
    total = 3 * PAGE + 6                       # 3 shared-able pages + tail
    for rid in range(6):
        prompt = _prompt(family=rid % 2, n=3 * PAGE)
        rb = base.alloc(rid, total, prompt=prompt)
        rd = dd.alloc(rid, total, prompt=prompt)
        assert rb is not None and rd is not None
        assert len(rb) == len(rd)
        assert (base.device.used_pages + base.host.used_pages
                >= dd.device.used_pages + dd.host.used_pages)
        base.check_invariants()
        dd.check_invariants()
    # 2 families x 3 shared pages, reused by 2 later requests each
    assert dd.dedup_pages_reused == 2 * 3 * 2
    saved = base.device.used_pages - dd.device.used_pages
    assert saved == dd.dedup_pages_reused
    # interleaved frees keep the remaining requests' pages alive
    for rid in (0, 3):
        base.free(rid)
        dd.free(rid)
        base.check_invariants()
        dd.check_invariants()
    for rid in (1, 2, 4, 5):
        assert len(dd.refs(rid)) == len(base.refs(rid))
    for rid in (1, 2, 4, 5):
        base.free(rid)
        dd.free(rid)
    assert dd.device.used_pages == 0 and dd.host.used_pages == 0
    assert len(dd.index) == 0, "index entries must die with their frames"


def test_dedup_admits_when_baseline_is_out_of_memory():
    """The capacity win admission banks on: a device pool exactly sized for
    one request cannot take a second identical prompt without dedup, and can
    with it (only the private tail is new)."""
    total = 2 * PAGE + PAGE                    # 2 prompt pages + 1 tail page
    base, dd = _pair(dev_pages=4, host_pages=0)
    prompt = _prompt(0, 2 * PAGE)
    assert base.alloc(10, total, prompt=prompt) is not None
    assert dd.alloc(10, total, prompt=prompt) is not None
    assert base.alloc(11, total, prompt=prompt) is None     # waits forever
    refs = dd.alloc(11, total, prompt=prompt)               # shares 2 pages
    assert refs is not None
    assert dd.dedup_hit_pages(11) == [0, 1]
    assert refs[0] == dd.refs(10)[0] and refs[1] == dd.refs(10)[1]
    dd.check_invariants()


def test_dedup_differential_survives_migration_and_resize():
    """Sharing must stay intact while frames move: swap the shared prefix
    host-ward and back, shrink and regrow the device pool — afterwards a
    third identical prompt still dedups against the (migrated) frames, and
    the baseline/dedup page-count parity still holds."""
    base, dd = _pair(16, 16)
    total = 2 * PAGE + 2                       # partial third page (2 tok)
    prompt = _prompt(0, total)                 # prompt == total: no reserve
    for rid in (0, 1):
        base.alloc(rid, total, prompt=prompt)
        dd.alloc(rid, total, prompt=prompt)
    assert dd.dedup_hit_pages(1) == [0, 1, 2]  # partial page shared too
    for kv in (base, dd):
        kv.swap_out(0, 2)
        kv.check_invariants()
    # the shared frames moved ONCE, for both owners
    assert dd.refs(0)[:2] == dd.refs(1)[:2]
    assert all(r.tier == HOST for r in dd.refs(1)[:2])
    for kv in (base, dd):
        res = kv.resize_device(8 * PB)
        kv.check_invariants()
        kv.swap_in(0, 99)
        kv.check_invariants()
    assert dd.refs(0) == dd.refs(1)[:len(dd.refs(0))]
    assert all(r.tier == DEVICE for r in dd.refs(1))
    # a new identical prompt dedups against the post-migration frames
    r2 = dd.alloc(2, total, prompt=prompt)
    assert r2 is not None and dd.dedup_hit_pages(2) == [0, 1, 2]
    assert r2 == dd.refs(0)
    for rid in (0, 1, 2):
        dd.free(rid)
    dd.check_invariants()
    assert dd.device.used_pages == 0 and len(dd.index) == 0
    del res


def test_dedup_streamed_host_hits_add_no_new_capacity():
    """Host-parked prefixes are shared too (LMCache-style): with ZERO device
    pages, a second identical prompt claims only its private tail on host."""
    base, dd = _pair(dev_pages=0, host_pages=8)
    total = 2 * PAGE + PAGE
    prompt = _prompt(0, 2 * PAGE)
    base.alloc(0, total, prompt=prompt)
    dd.alloc(0, total, prompt=prompt)
    assert base.host.used_pages == 3 and dd.host.used_pages == 3
    base.alloc(1, total, prompt=prompt)
    dd.alloc(1, total, prompt=prompt)
    assert base.host.used_pages == 6
    assert dd.host.used_pages == 4               # shared prefix + 1 tail
    sched = SwapScheduler(dd)
    # ... and the shared host pages stream once for the pair
    assert sched.streamed_bytes([0, 1]) == 4 * PB
    dd.check_invariants()


# ---------------------------------------------------------------------------
# Full-engine lock-step traces (nightly tier)
# ---------------------------------------------------------------------------


def _mk_engine_pair(device_pages: float, host_pages: int, max_batch=4,
                    max_seq=48, page_size=4):
    """Baseline (PR-2, dedup off) and dedup engine with identical params,
    records, and memory sizing."""
    base, _ = mk_reduced_engine(name="base", max_batch=max_batch,
                                max_seq=max_seq, page_size=page_size,
                                extra_device_pages=device_pages,
                                host_pages=host_pages, batches=(1, 2, 4))
    dd, _ = mk_reduced_engine(name="dedup", max_batch=max_batch,
                              max_seq=max_seq, page_size=page_size,
                              extra_device_pages=device_pages,
                              host_pages=host_pages, prefix_dedup=True,
                              batches=(1, 2, 4))
    return base, dd


def _shared_prefix_reqs(n, prefix_len, suffix_len, new, seed=0):
    """n requests sharing a common ``prefix_len`` prompt prefix, each with a
    distinct equal-length suffix (equal prompt lengths keep the stored
    prefix KV bit-identical across both engines — see PagedDualEngine)."""
    from repro.serving.request import Request

    rng = np.random.default_rng(seed)
    common = rng.integers(0, 100, prefix_len).astype(np.int32)
    out = []
    for i in range(n):
        suffix = rng.integers(0, 100, suffix_len).astype(np.int32)
        out.append(Request(rid=i,
                           prompt=np.concatenate([common, suffix]),
                           max_new_tokens=new,
                           ttft_slo_s=10.0, tpot_slo_s=10.0))
    return out


@pytest.mark.slow
def test_dedup_engine_acceptance_75pct_shared_prefix():
    """Acceptance trace: 4 requests whose prompts share a 75%-length common
    prefix. The dedup engine must match the PR-2 baseline's logits and
    greedy tokens at every iteration AND allocate >= 40% fewer device frames
    at peak."""
    base, dd = _mk_engine_pair(device_pages=44, host_pages=0)
    dual = PagedDualEngine(base, dd)
    # prompt 32 = 24 shared + 8 private; page 4 => 6 shared pages/request
    for r in _shared_prefix_reqs(4, prefix_len=24, suffix_len=8, new=8):
        base.submit(r)
    for r in _shared_prefix_reqs(4, prefix_len=24, suffix_len=8, new=8):
        dd.submit(r)
    dual.run_until_drained(max_iters=100)

    assert len(base.finished) == 4 and len(dd.finished) == 4
    for rb, rd in zip(sorted(base.finished, key=lambda r: r.rid),
                      sorted(dd.finished, key=lambda r: r.rid)):
        assert rb.generated == rd.generated
    assert dual.prefill_compares == 4
    assert dual.decode_compares >= 4 * 7
    assert dd.kv.dedup_pages_reused == 6 * 3   # 6 pages x 3 sharers
    # acceptance: >= 40% fewer device frames at peak
    assert base.device_pages_peak == 40
    assert dd.device_pages_peak <= 0.6 * base.device_pages_peak
    for eng in (base, dd):
        assert eng.kv.device.used_pages == 0
        eng.kv.check_invariants()
    assert len(dd.kv.index) == 0


@pytest.mark.slow
def test_dedup_engine_cow_partial_page_trace():
    """Identical prompts with a partially-filled last prompt page: every
    later request shares it and copy-on-writes off it at its first decode
    write. The trace must still match the baseline exactly (a missed COW
    would cross-corrupt the four requests' contexts and fork the tokens)."""
    base, dd = _mk_engine_pair(device_pages=44, host_pages=0)
    dual = PagedDualEngine(base, dd)
    for eng in (base, dd):
        for r in _shared_prefix_reqs(4, prefix_len=10, suffix_len=0, new=8,
                                     seed=3):
            eng.submit(r)
    dual.run_until_drained(max_iters=100)
    assert dual.decode_compares >= 4 * 7
    assert dd.cow_events == 3, "rids 1-3 must each move off the shared page"
    assert base.cow_events == 0
    gens = [r.generated for r in sorted(dd.finished, key=lambda r: r.rid)]
    assert all(g == gens[0] for g in gens)     # identical prompts
    assert dd.device_pages_peak < base.device_pages_peak
    dd.kv.check_invariants()


@pytest.mark.slow
def test_dedup_engine_shared_prefix_on_host_tier():
    """Long shared-prefix trace with the prefix parked on HOST: the shared
    pages stream through the slab once per iteration for all sharers, and
    the lock-step equality must survive streaming, promotion, and the COW
    of a host-resident shared page."""
    base, dd = _mk_engine_pair(device_pages=6.5, host_pages=64, max_batch=4,
                               max_seq=48)
    dual = PagedDualEngine(base, dd)
    for eng in (base, dd):
        for r in _shared_prefix_reqs(8, prefix_len=18, suffix_len=0, new=10,
                                     seed=7):
            eng.submit(r)
    dual.run_until_drained(max_iters=300)
    assert len(dd.finished) == 8
    assert dd.host_kv_peak_pages > 0, "trace never used the host tier"
    assert dd.streamed_pages_peak > 0, "trace never streamed host pages"
    assert dd.kv.dedup_pages_reused > 0
    assert dual.decode_compares >= 8 * 9
    # dedup's host footprint must also shrink (prefix stored once)
    assert dd.host_kv_peak_pages <= base.host_kv_peak_pages
    for eng in (base, dd):
        assert eng.kv.device.used_pages == 0 and eng.kv.host.used_pages == 0
        eng.kv.check_invariants()
