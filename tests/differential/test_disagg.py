"""Disaggregated prefill/decode fleet differential: role-typed tiers change
WHERE tokens are computed and WHEN bytes move, never the numbers.

Three servings of the same workload — a 1-prefill + 1-decode disaggregated
fleet, a 2-instance symmetric affinity fleet, and one pooled instance with
the combined capacity — must produce bitwise-identical greedy tokens per
request (shape-bucketed prefill makes KV pages placement-independent, and
the handoff payload is the same host-frame snapshot the park/resume path
round-trips). On top of the bitwise gate: zero SLO violations anywhere, and
the handoff conservation invariant (bytes exported == bytes imported, per
link and fleet-wide — trace invariant I12 plus ``Fleet.audit``'s
cross-instance half) clean over the full trace."""
import numpy as np
import pytest

from repro.data.workload import SLOClass, WorkloadConfig, generate_workload
from repro.serving.fleet import Fleet
from repro.serving.request import Request

from _engine_builders import mk_reduced_engine

# compile-heavy (full JAX jit of models/kernels): excluded from the fast CI
# tier, run in the nightly full suite
pytestmark = pytest.mark.slow

MAX_SEQ, PAGE = 96, 16


def _mk_instance(name, role="mixed", scale=1):
    eng, _ = mk_reduced_engine(
        name=name, max_batch=scale * 4, max_seq=MAX_SEQ, page_size=PAGE,
        extra_device_pages=scale * 8, host_pages=scale * 40,
        preemption=True, role=role)
    return eng


def _workload(n=14, seed=23):
    wcfg = WorkloadConfig(
        seed=seed, process="poisson", rate_per_s=3000.0,
        mean_rounds=1.0, mean_think_s=0.0005, tenants=2,
        system_prompt_len=32, median_turn_len=12, turn_len_sigma=0.3,
        max_prompt_len=72, mean_output_len=6.0, max_output_len=10,
        vocab_size=128,
        slo_classes=(SLOClass("standard", 4.0, 0.05, weight=1.0),))
    return generate_workload(wcfg, n)


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens,
                    ttft_slo_s=r.ttft_slo_s, tpot_slo_s=r.tpot_slo_s,
                    arrival_s=r.arrival_s, tenant=r.tenant) for r in reqs]


def _tokens(engines):
    return {r.rid: tuple(r.generated) for e in engines for r in e.finished}


def test_disagg_bitwise_vs_affinity_vs_pooled():
    reqs = _workload()

    disagg = Fleet([_mk_instance("p0", role="prefill"),
                    _mk_instance("d0", role="decode")], policy="affinity")
    s_dis = disagg.run(_clone(reqs), max_iters=50_000)

    aff = Fleet([_mk_instance("a0"), _mk_instance("a1")], policy="affinity")
    s_aff = aff.run(_clone(reqs), max_iters=50_000)

    pooled = _mk_instance("pooled", scale=2)
    pooled.run(_clone(reqs), max_iters=50_000)

    t_dis, t_aff = _tokens(disagg.engines), _tokens(aff.engines)
    t_pool = _tokens([pooled])
    assert len(t_dis) == len(t_aff) == len(t_pool) == len(reqs)
    assert t_dis == t_aff == t_pool          # the bitwise gate

    # the disaggregation actually disaggregated: every request with decode
    # work prefilled on p0, handed off through the PEER tier, and decoded
    # to completion on d0 (a single-token request IS its prefill — nothing
    # to hand off, it completes on the prefill side)
    n_decode = sum(1 for r in reqs if r.max_new_tokens > 1)
    assert s_dis["handoffs"] == n_decode
    assert s_dis["per_instance"]["p0"]["finished"] == len(reqs) - n_decode
    assert s_dis["per_instance"]["d0"]["finished"] == n_decode
    assert s_dis["per_instance"]["p0"]["handoffs_out"] == n_decode
    assert s_dis["per_instance"]["d0"]["handoffs_in"] == n_decode
    assert s_dis["handoff_bytes"] > 0

    # zero SLO violations anywhere
    assert s_dis["slo_ok"] and s_aff["slo_ok"]

    # full trace audits (per-instance I1-I12) + the fleet-level handoff
    # conservation cross-check: bytes exported == bytes imported, per link
    for fleet in (disagg, aff):
        ok, violations = fleet.audit()
        assert ok, violations
    assert pooled.trace.audit().ok


def test_disagg_refused_handoff_rolls_back_then_flushes():
    """A decode instance whose host tier is too small to absorb any ticket
    refuses every import: a forced export rolls back loudly-conserved (no
    peer bytes booked in either direction), and the drained-fleet flush
    eventually releases ``hold_resumes`` so the prefill instance decodes
    its stranded parked set locally — graceful degradation, tokens still
    bitwise vs a mixed single engine."""
    reqs = _workload(n=4, seed=5)

    ref = _mk_instance("ref")
    ref.run(_clone(reqs), max_iters=50_000)

    p0 = _mk_instance("p1", role="prefill")
    # 2 host pages: every ticket (>= 3-page prompts) fails certification
    d0, _ = mk_reduced_engine(name="d1", max_batch=4, max_seq=MAX_SEQ,
                              page_size=PAGE, extra_device_pages=8,
                              host_pages=2, preemption=True, role="decode")
    fleet = Fleet([p0, d0], policy="affinity")

    # drive p0 until a freshly-prefilled request parks into the staging
    # set, then force the handoff the fleet's pre-certification would have
    # skipped: the import must refuse and the rollback must net to zero
    for r in _clone(reqs):
        fleet._submit(r)
    while not p0.scheduler.preempted:
        p0.step()
    rid = p0.scheduler.preempted[0].rid
    out = p0.export_handoff(rid)
    assert out is not None
    got, ticket = out
    assert not d0.import_handoff(got, ticket)      # cannot certify: refuse
    p0.rollback_handoff(got, ticket)
    assert any(r.rid == rid for r in p0.scheduler.preempted)  # re-adopted
    # export accounting fully cancelled — the conservation audit sees a
    # net zero on both the pending and the lifetime counters
    assert p0.kv.pending_peer_out_pages == 0
    assert p0.kv.peer_out_pages_total == 0
    assert p0.handoff_out_bytes_total == 0 and p0.n_handoff_out == 0
    assert d0.kv.pending_peer_in_pages == 0 and d0.n_handoff_in == 0

    s = fleet.run([], max_iters=50_000)
    assert s["handoffs"] == 0                 # nothing ever certified
    assert not p0.scheduler.hold_resumes      # flush released the staging
    assert _tokens(fleet.engines) == _tokens([ref])
    assert {r.rid for r in p0.finished} == {r.rid for r in reqs}
    ok, violations = fleet.audit()
    assert ok, violations
