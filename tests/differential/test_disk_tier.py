"""Three-tier (NVMe) offload differential traces (nightly tier).

Two gates, mirroring how the host tier itself was landed:

  * **Lockstep**: the three-tier engine with the disk tier disabled — and
    with it enabled but never engaged — produces bitwise the greedy tokens
    and exactly the modeled clock of the two-tier engine on the preemption
    burst trace. The disk tier must be invisible until host pressure makes
    it do something.
  * **Pressure**: under a host pool too small to hold two parked victims,
    the host-only engine can park only once (later bursts wait); the disk
    engine retires long-parked pages to NVMe, parks strictly more victims,
    admits the second long request strictly earlier, and still finishes
    with zero TTFT/TPOT violations and bitwise-identical tokens per
    request — park -> disk -> resume is numerically invisible.

Plus the physical gate: page bytes survive device -> host -> disk -> host
-> device bitwise through the engine's real pool buffers (including a
file-backed np.memmap disk pool).
"""
import numpy as np
import pytest

from repro.core.interval import iter_time_with_interval_kv
from repro.serving.request import Request
from repro.serving.telemetry import summarize_latency

from _engine_builders import mk_reduced_engine

pytestmark = pytest.mark.slow


def _mk_engine(disk_pages=0, host_pages=4, device_pages=4,
               disk_backing_path=None):
    eng, _ = mk_reduced_engine(name=f"disk{disk_pages}", max_batch=4,
                               max_seq=48, page_size=8,
                               extra_device_pages=device_pages,
                               host_pages=host_pages, preemption=True,
                               disk_pages=disk_pages,
                               disk_backing_path=disk_backing_path,
                               batches=(1, 2, 4), seqs=(16, 32, 64))
    return eng


def _tpot_short(eng):
    """TPOT affording one streamed page but never two (analytic, like the
    fig17 trace, so the pressure point is not brittle)."""
    pb = eng.kv.page_bytes
    dt_1 = iter_time_with_interval_kv(eng.times_fn(4, 48, "decode"),
                                      eng.interval, 1 * pb)
    dt_2 = iter_time_with_interval_kv(eng.times_fn(1, 48, "decode"),
                                      eng.interval, 2 * pb)
    assert dt_1 < dt_2
    return (dt_1 + dt_2) / 2


def _req(rng, rid, plen, new, tpot):
    return Request(rid=rid, prompt=rng.integers(0, 100, plen
                                                ).astype(np.int32),
                   max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=tpot)


def _run_pressure(disk_pages: int) -> "object":
    """The host pool (2 pages) holds exactly the streaming long request's
    spilled prefix. Parking it needs 2 more host frames — host-only that is
    refused and the tight burst must wait for the long request to drain;
    with the disk tier the victim's own spill retires to NVMe ("preempt to
    host, overflow to disk"), the park lands, and the burst serves at full
    batch. Resume stages disk -> host -> device."""
    eng = _mk_engine(disk_pages=disk_pages, host_pages=2)
    tpot = _tpot_short(eng)
    rng = np.random.default_rng(11)
    s0 = _req(rng, 9, 4, 12, 1e-3)             # 2 dev pages, long-running
    l1 = _req(rng, 0, 16, 16, 1e-3)            # 2 dev + 2 host (streams)
    shorts = [_req(rng, i, 4, 4, tpot) for i in range(1, 5)]

    eng.submit(s0)
    eng.submit(l1)
    eng.step()
    eng.step()                                 # L1 decoding (parkable)
    assert len(eng.kv.host_pages_of(l1.rid)) == 2   # streams its cold prefix
    for s in shorts:
        eng.submit(s)
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 400:
        eng.step()
        it += 1
    assert it < 400, "trace did not drain"
    eng.kv.check_invariants()
    assert eng.kv.device.used_pages == 0 and eng.kv.host.used_pages == 0
    assert eng.kv.disk.used_pages == 0
    # the iteration trace must conserve: every byte charged to a link is a
    # byte the allocator actually moved, occupancy stays within capacity,
    # and no iteration exceeded its scheduler-certified latency
    report = eng.trace.audit()
    assert report.ok, report.violations
    pb = eng.kv.page_bytes
    totals = eng.trace.totals()
    assert totals["disk_in_bytes"] == eng.kv.disk_in_pages_total * pb
    assert totals["disk_out_bytes"] == eng.kv.disk_out_pages_total * pb
    assert totals["promoted_bytes"] == eng.swap.promoted_pages_total * pb
    return eng


def test_disk_pressure_parks_more_and_stays_slo_safe_and_bitwise():
    base = _run_pressure(disk_pages=0)
    disk = _run_pressure(disk_pages=16)

    # host-only cannot park at all (host is full of the victim's own
    # spill); the disk tier retires that spill to NVMe and parks
    assert base.scheduler.stats["preemptions"] == 0
    assert disk.scheduler.stats["preemptions"] >= 1, "no park via disk"
    assert disk.scheduler.stats["resumes"] == \
        disk.scheduler.stats["preemptions"]
    assert disk.scheduler.stats["disk_demotions"] >= 2
    assert disk.scheduler.stats["disk_stagings"] >= 2
    assert disk.disk_kv_peak_pages > 0
    assert base.scheduler.stats["disk_demotions"] == 0

    # both runs finish everything with zero modeled SLO violations
    for eng in (base, disk):
        assert len(eng.finished) == 6 and not eng.rejected
        for r in eng.finished:
            m = r.metrics()
            assert m["tpot_ok"], f"TPOT violation rid={r.rid}"
            assert m["ttft_ok"], f"TTFT violation rid={r.rid}"

    # park -> disk -> resume is numerically invisible: bitwise token
    # equality per request across the two runs
    tok = {e: {r.rid: list(r.generated) for r in e.finished}
           for e in (base, disk)}
    assert set(tok[base]) == set(tok[disk])
    for rid in tok[base]:
        assert tok[base][rid] == tok[disk][rid], f"divergence rid={rid}"

    # strictly more work in flight: the burst is admitted while the victim
    # is parked instead of queueing behind it — p99 queue delay collapses
    # and the whole trace finishes sooner
    def p99(eng):
        return summarize_latency(
            [r.queue_delay_s for r in eng.finished])["p99_s"]
    assert p99(disk) < p99(base)
    assert disk.clock_s < base.clock_s


def test_disk_enabled_but_idle_locksteps_two_tier_bitwise():
    """The differential gate for the N-tier refactor itself: with a disk
    pool configured but ample host capacity, the NVMe tier must never
    engage, and the run is bit-identical (tokens AND modeled clock) to the
    disk-disabled engine on the same preemption burst trace."""
    def run(disk_pages):
        eng = _mk_engine(disk_pages=disk_pages, host_pages=64)
        tpot = _tpot_short(eng)
        rng = np.random.default_rng(3)
        l1 = _req(rng, 0, 16, 16, 1e-3)
        shorts = [_req(rng, i, 4, 4, tpot) for i in range(1, 6)]
        eng.submit(l1)
        eng.step()
        eng.step()
        for s in shorts:
            eng.submit(s)
        it = 0
        while (eng.scheduler.has_work() or eng._active_batch() > 0) \
                and it < 400:
            eng.step()
            it += 1
        assert it < 400
        return eng

    base = run(disk_pages=0)
    idle = run(disk_pages=32)
    assert idle.disk_kv_peak_pages == 0        # the tier never engaged
    assert idle.kv.disk_in_pages_total == 0
    assert idle.kv.disk_out_pages_total == 0
    assert idle.scheduler.stats["preemptions"] == \
        base.scheduler.stats["preemptions"]
    assert {r.rid: list(r.generated) for r in idle.finished} == \
        {r.rid: list(r.generated) for r in base.finished}
    assert idle.clock_s == base.clock_s        # exactly, not approximately
    # both lockstep traces audit clean — conservation holds with the tier
    # configured-but-idle exactly as it does without it
    for eng in (base, idle):
        report = eng.trace.audit()
        assert report.ok, report.violations


def test_park_resume_page_bytes_round_trip_through_disk(tmp_path):
    """Physical gate: a parked request's device page bytes survive
    device -> host -> disk (np.memmap file) -> host -> device bitwise,
    through the engine's real pool buffers and the allocator's synchronous
    disk_copy hook."""
    from repro.kernels import ops
    import jax.numpy as jnp

    eng = _mk_engine(disk_pages=16, host_pages=8,
                     disk_backing_path=str(tmp_path / "kv_disk.bin"))
    rng = np.random.default_rng(5)
    long_req = _req(rng, 0, 16, 16, 1e-3)
    eng.submit(long_req)
    eng.step()
    eng.step()
    refs_before = eng.kv.refs(long_req.rid)
    dev_before = [r.page for r in refs_before if r.tier == "device"]
    before = np.asarray(ops.gather_kv_pages(
        eng.pool, jnp.asarray(dev_before, jnp.int32)))
    host_before = {r.page: np.array(eng.host_pool[r.page])
                   for r in refs_before if r.tier == "host"}

    moves = eng.kv.park(long_req.rid, [])
    assert moves is not None
    ops.copy_pages_to_host(eng.pool, [m.src_page for m in moves],
                           eng.host_pool, [m.dst_page for m in moves])
    # the whole parked set retires to NVMe; the disk legs copy through the
    # engine's hook synchronously
    d_moves = eng.kv.demote_to_disk(long_req.rid, 99)
    assert len(d_moves) == len(refs_before)
    assert eng.kv.host.used_pages == 0
    eng.host_pool[:] = 0                       # clobber the host pool

    # resume stages disk -> host and promotes host -> device entirely
    # through the engine's synchronous hooks (disk_copy + promote_copy) in
    # planning order: transit host frames are reused across stagings, so a
    # deferred batch copy here would read already-overwritten frames — the
    # exact hazard the hook design removes
    back = eng.kv.resume(long_req.rid)
    assert back is not None and len(back) == len(refs_before)

    refs_after = eng.kv.refs(long_req.rid)
    assert all(r.tier == "device" for r in refs_after)
    for pos, (rb, ra) in enumerate(zip(refs_before, refs_after)):
        got = np.asarray(ops.gather_kv_pages(
            eng.pool, jnp.asarray([ra.page], jnp.int32)))[0]
        if rb.tier == "device":
            want = before[dev_before.index(rb.page)]
        else:
            want = host_before[rb.page]
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            err_msg=f"page {pos} bytes changed through the disk tier")
    eng.kv.check_invariants()
