"""Dual-engine differential traces: the paged engine must be numerically
indistinguishable from the frozen dense reference on full serving traces —
across interval changes (device-pool resize + physical frame remap), host
spills (streamed pages + dirty-page write-back), and request completion /
slot + page reuse.

These drive full jitted engines and are compile-heavy: nightly tier.
"""
import numpy as np
import pytest

from repro.core.interval import NO_OFFLOAD
from repro.serving.request import Request

from _engine_builders import mk_reduced_engine
from harness import DualEngine

pytestmark = pytest.mark.slow


def _mk_engine(device_pages: float, host_pages: int, max_batch=2, max_seq=32,
               page_size=8):
    """Engine whose HBM fits the resident weights plus ``device_pages`` KV
    pages; the host tier absorbs the rest."""
    eng, _ = mk_reduced_engine(name="dual", max_batch=max_batch,
                               max_seq=max_seq, page_size=page_size,
                               extra_device_pages=device_pages,
                               host_pages=host_pages, batches=(1, 2, 4))
    return eng


def _reqs(n, prompt_len=6, new=20):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, 100, prompt_len).astype(np.int32),
                    max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=10.0)
            for i in range(n)]


def test_dual_engine_mixed_trace_with_interval_change_and_spill():
    """Acceptance trace: >= 200 compared decode iterations on a mixed
    request stream that spills KV to host and changes the offloading
    interval twice (grow and shrink the device pool, exercising promotion,
    demotion + write-back, and the physical frame remap). Every prefill and
    every decode iteration must match the dense reference."""
    eng = _mk_engine(device_pages=5.5, host_pages=64)
    dual = DualEngine(eng)
    for r in _reqs(24):
        eng.submit(r)

    interval_changes = 0
    while eng.queue or eng._active_batch() > 0:
        assert dual.iters < 1000
        if dual.iters == 40:
            eng.set_interval(2)        # smaller resident set: pool grows
            assert eng.interval == 2
            interval_changes += 1
        if dual.iters == 110:
            eng.set_interval(NO_OFFLOAD)   # pool shrinks: demotes host-ward
            assert eng.interval == NO_OFFLOAD
            interval_changes += 1
        dual.step()

    assert interval_changes == 2
    assert len(eng.finished) == 24
    for r in eng.finished:
        assert len(r.generated) == 20
    assert eng.host_kv_peak_pages > 0, "trace never spilled to host"
    assert eng.streamed_pages_peak > 0, "trace never streamed host pages"
    assert dual.decode_compares >= 200
    assert dual.prefill_compares == 24
    # numeric top-2 ties must stay rare: systematic divergence cannot hide
    # behind the tie rule
    assert dual.tied_tokens <= 0.02 * dual.decode_compares
    # all KV pages returned to both tiers
    assert eng.kv.device.used_pages == 0 and eng.kv.host.used_pages == 0
    eng.kv.check_invariants()


def test_dual_engine_device_only_completion_and_slot_reuse():
    """Device-only control: completion frees pages mid-trace and later
    requests reuse the same frames and batch slots; the reused frames must
    not leak stale KV into the new requests' logits."""
    eng = _mk_engine(device_pages=16, host_pages=0, max_batch=2)
    dual = DualEngine(eng)
    reqs = _reqs(5, prompt_len=5, new=9)
    for r in reqs:
        eng.submit(r)
    dual.run_until_drained(max_iters=300)
    assert len(eng.finished) == 5
    assert dual.prefill_compares == 5
    # prefill emits each request's first token: 9-token requests decode 8x
    assert dual.decode_compares >= 5 * 8
    assert eng.kv.device.used_pages == 0


def test_dual_engine_spill_heavy_zero_device_pages():
    """Extreme tier split: the device accounting pool holds zero pages, so
    every page of every request lives on host and the whole context is
    streamed through the slab each iteration, with the decode write landing
    on a streamed page (dirty write-back path) every single step."""
    eng = _mk_engine(device_pages=0.25, host_pages=32)
    assert eng.kv.device.total_pages == 0
    dual = DualEngine(eng)
    for r in _reqs(4, prompt_len=6, new=12):
        eng.submit(r)
    dual.run_until_drained(max_iters=200)
    assert len(eng.finished) == 4
    assert eng.streamed_pages_peak > 0
    assert dual.decode_compares >= 4 * 12 // 2
    assert eng.kv.host.used_pages == 0
