"""Fast-tier differential tests: the paged Pallas decode kernel (interpret
mode — how CPU CI executes it) vs the dense jnp oracle, focused on the
padded-input shapes the engine actually produces: block tables padded with
arbitrary (even out-of-range) frame ids, context lengths not divisible by
the page size, idle batch rows (context length 0), and batch=1."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _mk(b, h, vh, d, npages, page, nb, seed=3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (npages, page, vh, d), jnp.float32)
    vp = jax.random.normal(ks[2], (npages, page, vh, d), jnp.float32)
    perm = jax.random.permutation(ks[3], npages)[: b * nb]
    bt = perm.reshape(b, nb).astype(jnp.int32)
    cl = jax.random.randint(ks[4], (b,), 1, nb * page + 1, jnp.int32)
    return q, kp, vp, bt, cl


def _assert_match(q, kp, vp, bt, cl, **kw):
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True, **kw)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_padded_block_table_entries_are_ignored():
    """Table slots past the live context may hold anything — including frame
    ids outside the pool. The kernel clamps them before the index map runs
    and the context mask keeps them out of the softmax."""
    q, kp, vp, bt, _ = _mk(2, 4, 2, 32, 11, 8, 4)
    cl = jnp.asarray([9, 17], jnp.int32)         # 2 resp. 3 live pages of 4
    bt = np.array(bt)
    bt[0, 2:] = 10_000                           # garbage past the live pages
    bt[1, 3:] = -7
    bt = jnp.asarray(bt)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    # oracle sees an in-range table (values masked anyway)
    safe = jnp.clip(bt, 0, kp.shape[0] - 1)
    want = ref.ref_paged_decode_attention(q, kp, vp, safe, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_context_len_not_divisible_by_page_size():
    q, kp, vp, bt, _ = _mk(3, 4, 4, 32, 16, 8, 4)
    cl = jnp.asarray([1, 13, 27], jnp.int32)     # none divisible by 8
    _assert_match(q, kp, vp, bt, cl)


def test_batch_one_edge():
    q, kp, vp, bt, _ = _mk(1, 8, 2, 32, 7, 4, 5)
    for c in (1, 3, 4, 19, 20):                  # incl. exact page multiples
        _assert_match(q, kp, vp, bt, jnp.asarray([c], jnp.int32))


def test_idle_row_yields_zeros():
    """context_len <= 0 marks an idle batch slot (the engine's null-frame
    rows): the kernel must emit zeros, not NaNs, and not disturb live rows."""
    q, kp, vp, bt, _ = _mk(2, 4, 2, 32, 11, 8, 4)
    cl = jnp.asarray([0, 21], jnp.int32)
    got = np.asarray(ops.paged_decode_attention(q, kp, vp, bt, cl,
                                                interpret=True))
    assert np.all(got[0] == 0.0) and not np.any(np.isnan(got))
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(got[1], np.asarray(want)[1],
                               rtol=1e-5, atol=1e-5)


def test_oversized_context_len_is_clamped():
    q, kp, vp, bt, _ = _mk(2, 4, 2, 32, 11, 8, 4)
    cl_over = jnp.asarray([500, 32], jnp.int32)  # table capacity is 32
    cl_full = jnp.asarray([32, 32], jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl_over, interpret=True)
    want = ops.paged_decode_attention(q, kp, vp, bt, cl_full, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
