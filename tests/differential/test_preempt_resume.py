"""Preempt-to-host and chunked-prefill differential traces (nightly tier).

Token-exactness gate: a request that is parked on the host tier mid-decode
and later resumed must generate EXACTLY the greedy tokens it generates when
served without preemption — the park/resume round trip (accounting + the
physical page copies) must be invisible in the numbers. The comparison runs
two full engines on the same request stream (preemption on vs the wait-only
baseline, which is the PR-3 admission behavior) and compares every
request's generated tokens bitwise.

Chunked prefill is gated the other way: against the frozen dense reference
(``DualEngine``), because the final chunk's logits must equal a one-shot
prefill's logits bit-for-bit modulo the usual cross-implementation noise
bound (causal attention: the chunk KV recompute sees exactly the prompt
prefix).
"""
import numpy as np
import pytest

from repro.core.interval import iter_time_with_interval_kv
from repro.serving.request import Request
from repro.serving.telemetry import summarize_latency

from _engine_builders import mk_reduced_engine
from harness import DualEngine

pytestmark = pytest.mark.slow


def _mk_engine(preemption=False, chunk=0, device_pages=4, host_pages=64,
               max_batch=4, max_seq=48, page_size=8):
    eng, _ = mk_reduced_engine(name="pr", max_batch=max_batch,
                               max_seq=max_seq, page_size=page_size,
                               extra_device_pages=device_pages,
                               host_pages=host_pages, preemption=preemption,
                               prefill_chunk_tokens=chunk,
                               batches=(1, 2, 4), seqs=(16, 32, 64))
    return eng


def _burst_trace(eng):
    """The head-of-line burst the ROADMAP items target: a long-running
    request S0, a streaming-heavy long request L (cold prefix spilled to
    host), then a burst of short tight-TPOT requests that wait-only cannot
    admit while L streams."""
    pb = eng.kv.page_bytes
    iv = eng.interval
    # tpot for the shorts: one streamed page is always affordable, two never
    # are (computed from the analytic model so the trace is not brittle)
    dt_1 = iter_time_with_interval_kv(eng.times_fn(4, 48, "decode"), iv,
                                      1 * pb)
    dt_2 = iter_time_with_interval_kv(eng.times_fn(1, 48, "decode"), iv,
                                      2 * pb)
    assert dt_1 < dt_2
    tpot_short = (dt_1 + dt_2) / 2
    rng = np.random.default_rng(3)

    def req(rid, plen, new, tpot):
        return Request(rid=rid,
                       prompt=rng.integers(0, 100, plen).astype(np.int32),
                       max_new_tokens=new, ttft_slo_s=10.0, tpot_slo_s=tpot)

    s0 = req(0, 4, 12, 1e-3)          # 2 pages, long-running, loose TPOT
    long_req = req(1, 16, 16, 1e-3)   # 4 pages: 2 device + 2 host (streams)
    shorts = [req(i, 4, 4, tpot_short) for i in range(2, 8)]  # 1 page each
    return s0, long_req, shorts


def _run_burst(preemption: bool):
    eng = _mk_engine(preemption=preemption)
    s0, long_req, shorts = _burst_trace(eng)
    eng.submit(s0)
    eng.submit(long_req)
    eng.step()
    eng.step()                        # L is decoding (parkable) now
    assert len(eng.kv.host_pages_of(1)) == 2   # L streams its cold prefix
    for s in shorts:
        eng.submit(s)
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 300:
        eng.step()
        it += 1
    assert it < 300, "trace did not drain"
    eng.kv.check_invariants()
    assert eng.kv.device.used_pages == 0 and eng.kv.host.used_pages == 0
    report = eng.trace.audit()               # conservation on every burst
    assert report.ok, report.violations
    return eng


def test_preempted_request_tokens_bitwise_identical_and_slo_safe():
    """Acceptance trace: with preemption ON the burst finishes with zero
    TPOT violations, strictly higher admitted throughput than the wait-only
    baseline, and every request's greedy tokens — including the
    preempted-then-resumed ones — bitwise identical to the wait-only run."""
    base = _run_burst(preemption=False)
    pre = _run_burst(preemption=True)

    assert base.scheduler.stats["preemptions"] == 0
    assert pre.scheduler.stats["preemptions"] >= 1
    assert pre.scheduler.stats["resumes"] == pre.scheduler.stats["preemptions"]
    preempted = [r for r in pre.finished if r.preempt_count > 0]
    assert preempted, "trace never preempted"

    assert len(base.finished) == len(pre.finished) == 8
    for eng in (base, pre):
        for r in eng.finished:
            m = r.metrics()
            assert m["tpot_ok"], f"TPOT violation rid={r.rid} " \
                                 f"(preemption={eng is pre})"
            assert m["ttft_ok"]

    # bitwise token equality per request across the two engines
    tok = {e: {r.rid: list(r.generated) for r in e.finished}
           for e in (base, pre)}
    for rid in tok[base]:
        assert tok[base][rid] == tok[pre][rid], \
            f"token divergence rid={rid}"

    # strictly higher admitted throughput: same tokens, less modeled time
    # (the parked victim stops streaming while the burst drains, and
    # resumes into a freer device pool)
    assert pre.clock_s < base.clock_s
    n_tok = sum(len(g) for g in tok[base].values())
    assert n_tok / pre.clock_s > n_tok / base.clock_s

    # the burst's queueing delay collapses: shorts no longer wait for L
    def p99(eng):
        return summarize_latency(
            [r.queue_delay_s for r in eng.finished])["p99_s"]
    assert p99(pre) < p99(base)


def test_park_resume_page_bytes_round_trip_exactly():
    """Physical gate for the accounting above: after a park + resume round
    trip, the request's device pages hold bitwise the bytes they held
    before the park (through the pinned-host pool and back)."""
    from repro.kernels import ops
    import jax.numpy as jnp

    eng = _mk_engine(preemption=True)
    s0, long_req, _ = _burst_trace(eng)
    eng.submit(long_req)
    eng.step()
    eng.step()
    refs_before = eng.kv.refs(long_req.rid)
    dev_before = [r.page for r in refs_before if r.tier == "device"]
    before = np.asarray(ops.gather_kv_pages(
        eng.pool, jnp.asarray(dev_before, jnp.int32)))
    moves = eng.kv.park(long_req.rid, [])
    assert {m.src_page for m in moves} == set(dev_before)
    ops.copy_pages_to_host(eng.pool, [m.src_page for m in moves],
                           eng.host_pool, [m.dst_page for m in moves])
    back = eng.kv.resume(long_req.rid)
    # every parked device frame promotes back (the free pool it vacated)
    assert len(back) == len(dev_before)
    eng.pool = ops.copy_pages_from_host(
        eng.host_pool, [m.src_page for m in back],
        eng.pool, [m.dst_page for m in back])
    # same page positions, possibly different frames — compare per position
    refs_after = eng.kv.refs(long_req.rid)
    idx = {r: i for i, r in enumerate(refs_before)}
    for pos, (rb, ra) in enumerate(zip(refs_before, refs_after)):
        if rb.tier != "device":
            continue
        got = np.asarray(ops.gather_kv_pages(
            eng.pool, jnp.asarray([ra.page], jnp.int32)))[0]
        want = before[dev_before.index(rb.page)]
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"page {pos} bytes changed")
    del idx


def test_chunked_prefill_locksteps_dense_reference():
    """Chunked prefill against the frozen dense reference: long prompts
    scatter KV chunk-by-chunk across iterations while other slots decode,
    and every final-chunk logit row + every decode row must match the
    one-shot dense reference (numerically invisible chunking)."""
    eng = _mk_engine(chunk=8, device_pages=16, host_pages=0, max_batch=2,
                     max_seq=32)
    dual = DualEngine(eng)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 100, 6 + 7 * (i % 3)
                                        ).astype(np.int32),
                    max_new_tokens=8, ttft_slo_s=10.0, tpot_slo_s=10.0)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    dual.run_until_drained(max_iters=400)
    assert len(eng.finished) == 6
    for r in eng.finished:
        assert len(r.generated) == 8
        assert r.prefill_pos == r.prompt_len
    assert dual.prefill_compares == 6
    assert dual.decode_compares >= 6 * 7
    assert eng.scheduler.stats["chunked_prefill_iters"] >= 3
    assert eng.kv.device.used_pages == 0
    eng.kv.check_invariants()


def test_chunked_prefill_ttft_accrues_per_chunk():
    """TTFT accounting under chunking: a long prompt's TTFT is the sum of
    the iteration latencies its chunks rode, so it exceeds a short
    request's TTFT but stays finite and SLO-checked."""
    eng = _mk_engine(chunk=8, device_pages=16, host_pages=0, max_batch=2,
                     max_seq=48)
    rng = np.random.default_rng(1)
    long_req = Request(rid=0, prompt=rng.integers(0, 100, 24
                                                  ).astype(np.int32),
                       max_new_tokens=4, ttft_slo_s=10.0, tpot_slo_s=10.0)
    eng.submit(long_req)
    it = 0
    while (eng.scheduler.has_work() or eng._active_batch() > 0) and it < 50:
        eng.step()
        it += 1
    assert len(eng.finished) == 1
    # 24 tokens / 8-token chunks = 3 chunk iterations accrued into TTFT
    assert eng.scheduler.stats["chunked_prefill_iters"] == 3
    assert long_req.ttft_s is not None and long_req.ttft_s > 0
    assert long_req.ttft_s == pytest.approx(long_req.ttft_accum_s)
