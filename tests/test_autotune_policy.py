"""Unit tests for the online interval tuner policy and the offline-range
plumbing it leans on — pure stubs, no engine, no jit.

The tuner is the paper's §5 online stage: inside the offline bracket
``[min_interval, max_interval]`` it lifts host-ward (smaller interval =
more host memory) when the predicted latency leaves headroom, retreats
before a predicted violation, and under a backlog optimizes service rate
instead of host bytes. These tests pin each of those decisions against a
hand-built ``TunerGauges``."""
import pytest

from repro.core.coordinator import InstanceState
from repro.core.interval import LayerTimes, NO_OFFLOAD
from repro.serving.autotune import IntervalTuner, TunerConfig, TunerGauges

# 4 layers, 1ms transfer per layer, negligible compute: predicted dt is
# ~(offloaded layers) * 1ms, so interval 1 -> 4ms, 2 -> 2ms, 4 -> 1ms.
TIMES = LayerTimes(t_compute_s=1e-6, t_transfer_s=1e-3, num_layers=4,
                   layer_bytes=1000, t_rest_s=0.0)


def gauges(*, tpot=1.0, min_i=1, max_i=4, queue=0, batch=1,
           resize=lambda i: 0.0, capacity=None, kv_in=0.0, kv_out=0.0,
           peer_in=0.0, peer_out=0.0, peer_bw=0.0, peer_lat=0.0):
    return TunerGauges(batch=batch, queue_depth=queue, min_interval=min_i,
                       max_interval=max_i, num_units=4, times=TIMES,
                       kv_in_bytes=kv_in, kv_out_bytes=kv_out,
                       tpot_budget_s=tpot, resize_out_bytes=resize,
                       batch_capacity=capacity,
                       peer_in_bytes=peer_in, peer_out_bytes=peer_out,
                       peer_bw=peer_bw, peer_latency_s=peer_lat)


def test_candidates_respect_offline_range_without_fallback():
    t = IntervalTuner()
    assert t.candidates(gauges(min_i=2, max_i=3)) == [2, 3]
    # NO_OFFLOAD only when the fully-resident model genuinely fits
    assert t.candidates(gauges(min_i=1, max_i=NO_OFFLOAD)) == \
        [1, 2, 3, 4, NO_OFFLOAD]
    assert NO_OFFLOAD not in t.candidates(gauges(min_i=1, max_i=4))
    # contradictory bounds -> empty, and propose() holds position
    g = gauges(min_i=3, max_i=2)
    assert t.candidates(g) == []
    assert t.propose(g, 2) == 2


def test_lift_requires_patience_then_fires():
    t = IntervalTuner(TunerConfig(lift_patience=2))
    # budget 10ms: every interval feasible; smallest (1) is the target but
    # the first proposal must hold position (streak=1 < patience)
    g = gauges(tpot=10.0 / 0.8)
    assert t.propose(g, 3) == 3
    assert t.lifts == 0
    assert t.propose(g, 3) == 1          # second consecutive: fires
    assert t.lifts == 1


def test_lift_streak_resets_when_target_moves():
    t = IntervalTuner(TunerConfig(lift_patience=2))
    roomy = gauges(tpot=10.0 / 0.8)
    assert t.propose(roomy, 3) == 3      # streak (1, n=1)
    # budget tightens: target jumps to 2, which restarts the streak
    mid = gauges(tpot=2.5e-3 / 0.8)
    assert t.propose(mid, 3) == 3
    assert t.propose(mid, 3) == 2


def test_retreat_is_immediate_no_patience():
    t = IntervalTuner(TunerConfig(lift_patience=2))
    # budget 2ms with 20% headroom -> 1.6ms: intervals 3 and 4 (~1ms) fit;
    # current interval 2 predicts ~2ms > budget -> move NOW, and to the
    # smallest feasible (3), not all the way out
    g = gauges(tpot=2e-3)
    assert t.propose(g, 2) == 3
    assert t.retreats == 1


def test_nothing_feasible_sheds_as_much_as_memory_allows():
    t = IntervalTuner()
    g = gauges(tpot=1e-4, max_i=3)       # nothing fits the budget
    assert t.propose(g, 1) == 3          # largest in range, not NO_OFFLOAD
    assert t.retreats == 1


def test_banned_intervals_are_replanned_around():
    t = IntervalTuner(TunerConfig(lift_patience=1))
    g = gauges(tpot=10.0 / 0.8)
    assert t.propose(g, 4) == 1
    assert t.propose(g, 4, banned={1}) == 2
    assert t.propose(g, 4, banned={1, 2, 3}) == 4
    t.note_refusal(1)
    t.note_refusal(2)
    assert t.refusals == 2


def test_resize_writeback_counts_against_switch_targets_only():
    t = IntervalTuner(TunerConfig(lift_patience=1))
    # demotion write-back makes switching to 1 cost 2 extra layer-times
    # (2000 bytes over the layer link rate of 1000 bytes/ms): 4+2=6ms
    # exceeds the 5ms budget, so the tuner settles for 2 (2ms)
    g = gauges(tpot=5e-3 / 0.8,
               resize=lambda i: 2000.0 if i != 2 else 0.0)
    assert t.predicted_dt_s(g, 2, 2) == pytest.approx(2e-3, rel=1e-2)
    assert t.predicted_dt_s(g, 1, 2) == pytest.approx(6e-3, rel=1e-2)
    assert t.propose(g, 2) == 2


def test_backlog_mode_optimizes_service_rate_not_host_bytes():
    t = IntervalTuner(TunerConfig(lift_patience=1))
    # interval 1 frees enough KV room for batch 4, interval 2 for batch 2,
    # the rest fit batch 1 — service rates 4/4ms == 2/2ms == 1/1ms tie
    # (transfer time scales linearly with offloaded layers), so the
    # host-ward tie-break keeps the smallest interval in play
    cap = {1: 4, 2: 2, 3: 1, 4: 1}.get
    roomy = gauges(tpot=10.0 / 0.8, capacity=cap)
    # no backlog: host-memory objective, smallest feasible
    assert t.propose(roomy, 1) == 1
    # backlog, rate tie: host-ward tie-break holds interval 1
    pressured = gauges(tpot=10.0 / 0.8, queue=3, capacity=cap)
    assert t.propose(pressured, 1) == 1
    # backlog, interval 1's capacity halved: its rate 2/4ms loses to
    # interval 4's 1/1.001ms — throughput now beats host bytes
    starved = gauges(tpot=10.0 / 0.8, queue=3,
                     capacity={1: 2, 2: 1, 3: 1, 4: 1}.get)
    assert t.propose(starved, 4) == 4
    # the backlog winner must still be SLO-feasible: with a 3ms budget
    # interval 1 (4ms) drops out even though its capacity is highest
    tight = gauges(tpot=3e-3 / 0.8, queue=3, capacity=cap)
    assert t.propose(tight, 2) == 2


def test_backlog_mode_requires_packing_capacity_gauge():
    # the `else 1` constant fallback is gone: backlog mode without the
    # scheduler's packing-plan gauge must fail loudly, not silently
    # degrade to a latency-only objective
    t = IntervalTuner(TunerConfig(lift_patience=1))
    with pytest.raises(ValueError, match="batch_capacity"):
        t.propose(gauges(tpot=10.0 / 0.8, queue=3, capacity=None), 1)
    # empty queue never consults the gauge — no regression for callers
    # that only ever run the host-memory objective
    assert t.propose(gauges(tpot=10.0 / 0.8, capacity=None), 1) == 1


def test_peer_traffic_folds_into_prediction():
    # pending peer-link handoff bytes ride their own concurrent channel:
    # predicted dt = max(weight-PCIe time, peer transfer time). 3000 bytes
    # at 1e6 B/s -> 3ms peer term dominates every interval's PCIe time and
    # busts a 2.5ms budget, so the tuner sheds transfers entirely.
    t = IntervalTuner(TunerConfig(lift_patience=1))
    quiet = gauges(tpot=2.5e-3 / 0.8)
    assert t.propose(quiet, 4) == 2      # smallest feasible (2ms <= 2.5ms)
    busy = gauges(tpot=2.5e-3 / 0.8, peer_in=3000.0, peer_bw=1e6)
    assert t.predicted_dt_s(busy, 4, 4) == pytest.approx(3e-3, rel=1e-2)
    assert t.propose(busy, 2) == 4       # nothing feasible: shed max
    assert t.retreats == 1


# --------------------------------------------------------------------------
# Offline-range plumbing (satellite of the same bug family): the coordinator
# must not resurrect NO_OFFLOAD when the memory bound rules everything out.
# --------------------------------------------------------------------------

def _state(min_i, max_i, idle=False):
    return InstanceState(name="i0", num_units=4, unit_bytes=1000,
                         t_iter_s=1e-3, min_interval=min_i,
                         max_interval=max_i, idle=idle)


def test_valid_intervals_empty_when_slo_and_memory_contradict():
    st = _state(min_i=3, max_i=2)
    assert st.valid_intervals() == []
    assert not st.admissible()


def test_valid_intervals_no_no_offload_fallback_below_capacity():
    # memory caps at 2: NO_OFFLOAD must NOT appear even though the range
    # is non-empty (the old fallback re-added it whenever the range was
    # empty, admitting requests the device cannot hold)
    st = _state(min_i=1, max_i=2)
    assert st.valid_intervals() == [1, 2]
    assert NO_OFFLOAD not in st.valid_intervals()


def test_valid_intervals_keeps_no_offload_when_it_fits():
    st = _state(min_i=5, max_i=NO_OFFLOAD)
    assert st.valid_intervals() == [NO_OFFLOAD]
    assert st.admissible()


def test_idle_instance_is_admissible_at_no_offload():
    st = _state(min_i=3, max_i=2, idle=True)
    assert st.valid_intervals() == [NO_OFFLOAD]
    assert st.admissible()
