"""Select-N algebra: interval feasibility, simulator consistency, record
lookups, coordinator — including hypothesis property tests on the system's
invariants."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare container: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core.baselines import (deepspeed_plan, flexgen_decision,
                                  flexgen_equivalent_interval,
                                  flexgen_host_bytes)
from repro.core.coordinator import (CoordinationResult, InstanceState,
                                    coordinate, max_interval_for_memory)
from repro.core.hardware import A10
from repro.core.interval import (LayerTimes, NO_OFFLOAD, OffloadPlan,
                                 iter_time_with_interval,
                                 min_feasible_interval, optimal_interval)
from repro.core.record import PerformanceRecord
from repro.core.simulator import (schedule_deepspeed, schedule_for_interval,
                                  simulate_iteration, simulate_shared_bus)

TIMES = LayerTimes(t_compute_s=2e-3, t_transfer_s=5e-3, num_layers=32,
                   layer_bytes=400 << 20, t_rest_s=1e-3)


# ---------------------------------------------------------------------------
# Interval algebra
# ---------------------------------------------------------------------------

def test_interval_monotone_latency():
    prev = float("inf")
    for i in range(1, TIMES.num_layers + 1):
        t = iter_time_with_interval(TIMES, i)
        assert t <= prev + 1e-12, f"latency must not increase with interval {i}"
        prev = t
    assert iter_time_with_interval(TIMES, NO_OFFLOAD) == pytest.approx(
        TIMES.t_iter_no_offload_s)


def test_min_feasible_meets_slo_and_is_minimal():
    slo = 1.3 * TIMES.t_iter_no_offload_s
    i = min_feasible_interval(TIMES, slo)
    assert iter_time_with_interval(TIMES, i) <= slo
    if i > 1:
        assert iter_time_with_interval(TIMES, i - 1) > slo


@given(tc=st.floats(1e-4, 1e-1), tt=st.floats(1e-4, 1e-1),
       n=st.integers(2, 80), i=st.integers(1, 80))
@settings(max_examples=200, deadline=None)
def test_analytic_matches_simulator(tc, tt, n, i):
    """iter_time_with_interval must equal the discrete-event simulation for
    uniform layer times (the paper's Fig. 7 schedule)."""
    i = min(i, n)
    times = LayerTimes(tc, tt, n, 1 << 20, t_rest_s=0.0)
    analytic = iter_time_with_interval(times, i)
    sched = schedule_for_interval([tc] * n, i, tt)
    sim = simulate_iteration(sched)["latency_s"]
    assert sim == pytest.approx(analytic, rel=1e-9, abs=1e-12)


@given(tc=st.floats(1e-4, 5e-2), tt=st.floats(1e-4, 5e-2),
       n=st.integers(2, 64), slack=st.floats(0.0, 3.0))
@settings(max_examples=200, deadline=None)
def test_optimal_interval_is_slo_safe(tc, tt, n, slack):
    """The paper's record formula must never yield an SLO-violating interval
    (validated against the event simulator)."""
    times = LayerTimes(tc, tt, n, 1 << 20, t_rest_s=0.0)
    slo = times.t_iter_no_offload_s * (1.0 + slack)
    i = optimal_interval(times, slo)
    if i >= NO_OFFLOAD:
        return
    sched = schedule_for_interval([tc] * n, i, tt)
    sim = simulate_iteration(sched)["latency_s"]
    assert sim <= slo * (1 + 1e-9)


def test_plan_accounting():
    plan = OffloadPlan(num_units=32, interval=4)
    assert plan.num_groups == 8
    assert plan.num_offloaded == 8
    assert plan.num_resident == 24
    assert plan.offloaded_indices() == [3, 7, 11, 15, 19, 23, 27, 31]
    lb = 100
    assert plan.host_bytes(lb) == 800
    assert plan.device_bytes(lb) == (24 + 2) * lb
    assert OffloadPlan(32, NO_OFFLOAD).host_bytes(lb) == 0
    assert OffloadPlan(32, 1).num_resident == 0


@given(n=st.integers(1, 128), i=st.integers(1, 200))
@settings(max_examples=200, deadline=None)
def test_plan_partition_invariant(n, i):
    plan = OffloadPlan(n, i)
    assert plan.num_resident + plan.num_offloaded == n
    assert plan.tail_units >= 0
    assert plan.num_groups * plan.interval + plan.tail_units == n or \
        not plan.enabled


# ---------------------------------------------------------------------------
# Simulator baselines
# ---------------------------------------------------------------------------

def test_deepspeed_slowdown_matches_paper_shape():
    """When transfer >> compute (paper Fig. 2: 13.8x at decode), DeepSpeed's
    latency approaches L*t_transfer, i.e. t_t/t_c-fold slowdown."""
    tc, tt, n = 1e-3, 13.8e-3, 32
    sched = schedule_deepspeed([tc] * n, tt)
    sim = simulate_iteration(sched)
    assert sim["latency_s"] >= n * tt
    slowdown = sim["latency_s"] / (n * tc)
    assert 12.0 <= slowdown <= 16.0


def test_selectn_meets_slo_where_deepspeed_fails():
    tc, tt, n = 1e-3, 6e-3, 32
    times = LayerTimes(tc, tt, n, 1 << 20)
    slo = 1.25 * times.t_iter_no_offload_s
    ds = simulate_iteration(schedule_deepspeed([tc] * n, tt))["latency_s"]
    assert ds > slo
    i = min_feasible_interval(times, slo)
    sn = simulate_iteration(schedule_for_interval([tc] * n, i, tt))["latency_s"]
    assert sn <= slo
    assert OffloadPlan(n, i).num_offloaded > 0


def test_contention_oversubscription_stretches_transfers():
    tc, tt, n = 1e-3, 4e-3, 16
    s1 = schedule_for_interval([tc] * n, 4, tt)
    s2 = schedule_for_interval([tc] * n, 4, tt)
    alone = simulate_iteration(s1)["latency_s"]
    rate = OffloadPlan(n, 4).link_bytes_per_iter(100) / alone
    shared = simulate_shared_bus([s1, s2], total_bw=1.2 * rate,
                                 demands=[rate, rate])
    assert all(r["latency_s"] > alone for r in shared)


# ---------------------------------------------------------------------------
# Record
# ---------------------------------------------------------------------------

def test_record_roundtrip_and_conservative_lookup():
    rec = PerformanceRecord("m", "a10", "decode", batches=[4, 8, 16],
                            seqs=[128, 256])
    rec.set(0.050, 4, 128, 5)
    rec.set(0.050, 8, 128, 4)
    rec.set(0.050, 16, 128, 3)
    rec.set(0.050, 4, 256, 4)
    rec.set(0.050, 8, 256, 3)
    rec.set(0.050, 16, 256, 2)
    rec2 = PerformanceRecord.from_json(rec.to_json())
    assert rec2.lookup(0.050, 8, 256) == 3
    # batch 12 rounds DOWN to 8, seq 300 rounds DOWN to 256 (conservative)
    assert rec2.lookup(0.050, 12, 300) == 3
    # SLO 49ms rounds DOWN to 48ms bucket -> absent -> NO_OFFLOAD
    assert rec2.lookup(0.049, 8, 256) == NO_OFFLOAD
    # tighter-than-recorded SLO: NO_OFFLOAD
    assert rec2.lookup(0.001, 8, 256) == NO_OFFLOAD
    assert "inf" not in rec2.render(0.050).split("\n")[2]


@given(b=st.integers(1, 64), s=st.integers(1, 1024))
@settings(max_examples=100, deadline=None)
def test_record_lookup_never_crashes(b, s):
    rec = PerformanceRecord("m", "a10", "decode", batches=[4, 8], seqs=[128])
    rec.set(0.050, 4, 128, 5)
    rec.set(0.050, 8, 128, 3)
    assert rec.lookup(0.050, b, s) >= 1


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------

def _inst(name, min_i, max_i=NO_OFFLOAD, t_iter=0.050, nbytes=400 << 20,
          n=32, idle=False):
    return InstanceState(name=name, num_units=n, unit_bytes=nbytes,
                         t_iter_s=t_iter, min_interval=min_i,
                         max_interval=max_i, idle=idle)


def test_coordinator_inadmissible():
    res = coordinate([_inst("a", min_i=8, max_i=4)], link_bw=1e12)
    assert not res.ok and "upper-level" in res.reason


def test_coordinator_respects_bandwidth_and_maximizes_host():
    a, b = _inst("a", 2), _inst("b", 2)
    wide = coordinate([a, b], link_bw=1e14)
    assert wide.ok
    # unconstrained: both take min interval (max host usage)
    assert wide.intervals == {"a": 2, "b": 2}
    narrow = coordinate([a, b], link_bw=wide.total_link_rate / 2)
    assert narrow.ok
    assert narrow.total_link_rate <= wide.total_link_rate / 2 + 1e-6
    assert narrow.total_host_bytes <= wide.total_host_bytes


def test_coordinator_idle_peer_gets_full_bandwidth():
    a = _inst("a", 2)
    idle = _inst("b", 1, idle=True)
    res = coordinate([a, idle], link_bw=a.link_rate(2) * 1.01)
    assert res.ok and res.intervals["a"] == 2


@given(mins=st.lists(st.integers(1, 16), min_size=2, max_size=4),
       bw_scale=st.floats(0.2, 4.0))
@settings(max_examples=60, deadline=None)
def test_coordinator_greedy_feasible(mins, bw_scale):
    insts = [_inst(f"i{k}", m) for k, m in enumerate(mins)]
    full = sum(i.link_rate(i.min_interval) for i in insts)
    res = coordinate(insts, link_bw=full * bw_scale)
    if res.ok:
        assert res.total_link_rate <= full * bw_scale * (1 + 1e-9)
        for inst in insts:
            assert res.intervals[inst.name] >= inst.min_interval


def test_max_interval_for_memory():
    # 32 units x 100 bytes; budget 1500 bytes -> resident+2buf <= 15 units
    got = max_interval_for_memory(32, 100, 1500)
    assert OffloadPlan(32, got).device_bytes(100) <= 1500
    assert OffloadPlan(32, got + 1).device_bytes(100) > 1500
    assert max_interval_for_memory(4, 100, 1e9) == NO_OFFLOAD


# ---------------------------------------------------------------------------
# FlexGen baseline
# ---------------------------------------------------------------------------

def test_flexgen_underoffloads_vs_selectn():
    """Observations #2/#3: worst-case bandwidth assumption + peak-FLOPs
    estimation make FlexGen offload less than Select-N at the same SLO.
    Setting matches the paper's §5.3: SLO = the no-offload iteration latency
    (zero slack), decode phase, two instances on the bus."""
    tc_real = 2e-3
    layer_flops = A10.peak_flops * tc_real * 0.35   # real kernels run at 35% peak
    times = LayerTimes(tc_real, 4e-3, 32, 400 << 20, t_rest_s=0.0)
    slo = times.t_iter_no_offload_s                 # zero slack

    fg = flexgen_decision(times, A10, slo, layer_flops, n_bus_sharers=2)
    sn_interval = min_feasible_interval(times, slo)
    sn_host = OffloadPlan(32, sn_interval).host_bytes(times.layer_bytes)
    fg_host = flexgen_host_bytes(times, fg)
    assert fg_host < sn_host
    # Fig. 4 / Observation #2: the peak-FLOPs layer-time estimate is well
    # below the real layer time.
    assert A10.peak_exec_time(layer_flops) < tc_real
    assert fg.est_iter_s <= slo * (1 + 1e-9)
    assert flexgen_equivalent_interval(times, fg) >= sn_interval
