"""Copy-stage engine hazards and the direct disk<->device path.

Every scenario runs the SAME allocator op sequence against a synchronous
twin and an async twin (drains only at pass boundaries, like the serving
engine) and asserts the physical pools are bitwise identical afterwards —
the async data plane must be observationally equivalent to the PR 5
synchronous hooks, just off the critical path.
"""
import numpy as np
import jax.numpy as jnp

from repro.serving.data_plane import CopyStageEngine
from repro.serving.kv_cache import PageConfig
from repro.serving.kv_offload import (DEVICE, DISK, HOST, LinkSpec,
                                      TieredKVAllocator)

_PAGE = 4          # tokens per page
_BPT = 4           # bytes per token -> page_bytes = 16
_W = 8             # payload floats per physical page frame


class _Twin:
    """One allocator + physical pools + copy-stage plane, hooks wired the
    way serving/engine.py wires them."""

    def __init__(self, *, dev_pages, host_pages, disk_pages,
                 async_mode, direct=False, background=True):
        pcfg = PageConfig(page_size=_PAGE, bytes_per_token=_BPT)
        pb = _PAGE * _BPT
        self.kv = TieredKVAllocator(dev_pages * pb, host_pages * pb, pcfg,
                                    disk_bytes=disk_pages * pb,
                                    disk_link=LinkSpec(bw_bytes_s=1e9,
                                                       latency_s=0.0))
        self._pool = [jnp.zeros((dev_pages, _W), jnp.float32)]
        self.host_pool = np.zeros((host_pages, _W), np.float32)
        self.disk_pool = np.zeros((disk_pages, _W), np.float32)
        self.plane = CopyStageEngine(host_pool=self.host_pool,
                                     disk_pool=self.disk_pool,
                                     get_pool=lambda: self._pool[0],
                                     set_pool=self._set_pool,
                                     async_mode=async_mode,
                                     background=background)
        self.kv.park_copy = lambda s, d: self.plane.stage("d2h", s, d)
        self.kv.promote_copy = lambda s, d: self.plane.stage("h2d", s, d)
        self.kv.disk_copy = self._disk_copy
        if direct:
            self.kv.direct_copy = self._direct_copy

    def _set_pool(self, pool):
        self._pool[0] = pool

    def _disk_copy(self, st, sp, dt, dp):
        self.plane.stage("h2disk" if dt == DISK else "disk2h", sp, dp)

    def _direct_copy(self, st, sp, dt, dp):
        self.plane.stage("disk2d" if dt == DEVICE else "d2disk", sp, dp)

    def fill_device(self, rid, base):
        for i, f in enumerate(self.kv.device_pages_of(rid)):
            self._pool[0] = self._pool[0].at[f].set(
                float(base + i) * np.ones(_W, np.float32))

    def pools(self):
        self.plane.sync()
        return (np.asarray(self._pool[0]), self.host_pool.copy(),
                self.disk_pool.copy())


def _assert_twins_equal(sync, asyn):
    for name, a, b in zip(("device", "host", "disk"),
                          sync.pools(), asyn.pools()):
        np.testing.assert_array_equal(a, b, err_msg=f"{name} pool diverged")


def _park_to_disk(tw, rid, tokens, base):
    """alloc on device, fill with recognizable bytes, park, retire the
    whole parked set to disk."""
    assert tw.kv.alloc(rid, tokens) is not None
    tw.fill_device(rid, base)
    assert tw.kv.park(rid) is not None
    tw.kv.demote_to_disk(rid, len(tw.kv.host_pages_of(rid)))


# ---------------------------------------------------------------------------
# hazard scenarios (satellite: async hazard unit tests)
# ---------------------------------------------------------------------------

def test_resume_chains_through_one_transit_frame_waw():
    """Resume staging reuses ONE host transit frame for a chain of disk
    pages: disk2h -> h2d -> disk2h (same frame). The queued promotion must
    read the frame before the next staging overwrites it (WAW/RAR on the
    reusable transit frame)."""
    twins = []
    for mode in (False, True):
        tw = _Twin(dev_pages=4, host_pages=4, disk_pages=8, async_mode=mode)
        _park_to_disk(tw, 1, 16, base=10)          # 4 disk pages
        if mode:
            # the engine drains plan-staged ops BEFORE any prefill scatter
            # writes device frames; rid 2's fill below emulates that scatter
            tw.plane.drain()
        # rid 2 occupies 3 host frames so resume(1) has exactly one transit
        assert tw.kv.alloc(2, 12) is not None
        tw.fill_device(2, base=50)
        assert tw.kv.park(2) is not None
        if mode:
            tw.plane.drain()                       # iteration boundary
        assert tw.kv.host.free_pages == 1
        moves = tw.kv.resume(1)
        assert moves is not None
        twins.append(tw)
    _assert_twins_equal(*twins)
    # the resumed request's device frames hold its original bytes
    tw = twins[1]
    dev = tw.pools()[0]
    got = sorted(float(dev[f][0]) for f in tw.kv.device_pages_of(1))
    assert got == [10.0, 11.0, 12.0, 13.0]


def test_park_overlaps_same_pass_demotion():
    """A park's d2h legs and a demotion's h2disk retirement of those same
    frames land in ONE planning pass — plus a second park that reuses the
    freed host frames in the same pass. FIFO drain must read the frames
    to disk before the second park overwrites them."""
    twins = []
    for mode in (False, True):
        tw = _Twin(dev_pages=4, host_pages=2, disk_pages=8, async_mode=mode)
        assert tw.kv.alloc(1, 8) is not None       # 2 device pages
        tw.fill_device(1, base=20)
        assert tw.kv.alloc(2, 8) is not None
        tw.fill_device(2, base=70)
        # one pass, no drain in between: park(1) writes host frames, the
        # demotion reads them to disk and frees them, park(2) rewrites them
        assert tw.kv.park(1) is not None
        tw.kv.demote_to_disk(1, 2)
        assert tw.kv.park(2) is not None
        twins.append(tw)
    _assert_twins_equal(*twins)
    tw = twins[1]
    _, host, disk = tw.pools()
    assert sorted(float(disk[r.page][0])
                  for r in tw.kv._disk_refs_of(1)) == [20.0, 21.0]
    assert sorted(float(host[p][0])
                  for p in tw.kv.host_pages_of(2)) == [70.0, 71.0]


def test_prefetch_races_its_own_resume():
    """A staged prefetch's disk2h writes and the resume's h2d promotions of
    the SAME host frames queue back to back — the promotion must observe
    the prefetched bytes (RAW across the prefetch/resume boundary)."""
    twins = []
    for mode in (False, True):
        tw = _Twin(dev_pages=4, host_pages=4, disk_pages=8, async_mode=mode)
        _park_to_disk(tw, 1, 16, base=30)
        # prefetch and resume in one pass, no drain between: the resume's
        # promotions read host frames the queued prefetch has not yet
        # physically written
        assert tw.kv.prefetch_from_disk(1, tw.kv.host.free_pages) == 4
        moves = tw.kv.resume(1)
        assert moves is not None
        twins.append(tw)
    _assert_twins_equal(*twins)
    tw = twins[1]
    dev = tw.pools()[0]
    got = sorted(float(dev[f][0]) for f in tw.kv.device_pages_of(1))
    assert got == [30.0, 31.0, 32.0, 33.0]


def test_background_retirement_vs_host_write_guard():
    """An engine-side host-pool write (decode writeback / prefill spill)
    must wait for an in-flight background retirement that still reads the
    frame: guard_host_writes serializes them, so the disk page keeps the
    pre-overwrite bytes."""
    host = np.zeros((4, _W), np.float32)
    disk = np.zeros((4, _W), np.float32)
    box = [jnp.zeros((2, _W), jnp.float32)]
    plane = CopyStageEngine(host_pool=host, disk_pool=disk,
                            get_pool=lambda: box[0],
                            set_pool=lambda p: box.__setitem__(0, p),
                            async_mode=True)
    host[1] = 7.0
    plane.stage("h2disk", 1, 2)
    plane.drain()                       # submits to the background worker
    plane.guard_host_writes([1])        # engine about to overwrite frame 1
    host[1] = 99.0
    plane.sync()
    assert float(disk[2][0]) == 7.0     # retirement read the old bytes


def test_duplicate_dst_flushes_batch():
    """Two queued ops writing the same dst frame never share a batched
    scatter (XLA duplicate-index order is unspecified): last write wins,
    exactly as in sync mode."""
    pools = []
    for mode in (False, True):
        host = np.arange(4 * _W, dtype=np.float32).reshape(4, _W)
        disk = np.zeros((4, _W), np.float32)
        box = [jnp.zeros((2, _W), jnp.float32)]
        plane = CopyStageEngine(host_pool=host, disk_pool=disk,
                                get_pool=lambda: box[0],
                                set_pool=lambda p: box.__setitem__(0, p),
                                async_mode=mode, background=False)
        plane.stage("h2disk", 0, 3)
        plane.stage("h2disk", 1, 3)     # WAW on disk frame 3
        plane.stage("h2d", 2, 0)
        plane.stage("h2d", 3, 0)        # WAW on device frame 0
        plane.sync()
        pools.append((np.asarray(box[0]), disk.copy()))
    np.testing.assert_array_equal(pools[0][0], pools[1][0])
    np.testing.assert_array_equal(pools[0][1], pools[1][1])
    np.testing.assert_array_equal(pools[1][1][3], host[1])
    np.testing.assert_array_equal(pools[1][0][0], host[3])


def test_iteration_counters_conserve():
    """issued == completed + inflight at every point; per-iteration deltas
    sum to the totals (the engine-side contract behind audit check I10)."""
    host = np.ones((4, _W), np.float32)
    disk = np.zeros((4, _W), np.float32)
    box = [jnp.zeros((2, _W), jnp.float32)]
    plane = CopyStageEngine(host_pool=host, disk_pool=disk,
                            get_pool=lambda: box[0],
                            set_pool=lambda p: box.__setitem__(0, p),
                            async_mode=True, background=False)
    plane.stage("h2disk", 0, 0)
    plane.stage("h2disk", 1, 1)
    assert plane.inflight_pages() == 2
    assert plane.take_iteration_counters() == (2, 0)
    plane.drain()
    assert plane.inflight_pages() == 0
    assert plane.take_iteration_counters() == (0, 2)
    assert plane.issued_pages_total == plane.completed_pages_total == 2


# ---------------------------------------------------------------------------
# direct disk<->device path (satellite: host bounce bypass + byte accounting)
# ---------------------------------------------------------------------------

def test_direct_resume_bypasses_host_and_pcie_charge():
    """With direct_copy wired, resume stages disk pages straight onto free
    device frames: the NVMe read is still charged, the host-transit PCIe
    promotion charge disappears, and the bytes land bit-identically to the
    host-bounce path."""
    bounce = _Twin(dev_pages=4, host_pages=4, disk_pages=8, async_mode=False)
    direct = _Twin(dev_pages=4, host_pages=4, disk_pages=8, async_mode=False,
                   direct=True)
    for tw in (bounce, direct):
        _park_to_disk(tw, 1, 16, base=40)
        tw.moves = tw.kv.resume(1)
        assert tw.moves is not None
    # byte accounting: both charge 4 NVMe reads ...
    for tw in (bounce, direct):
        assert tw.kv.disk_in_pages_total == 4
        assert tw.kv.pending_disk_in_pages == 4
    # ... but only the bounce path puts promotion bytes on the PCIe link
    # (the scheduler charges HOST-src migrations via note_promotions)
    assert sum(1 for m in bounce.moves if m.src_tier == HOST) == 4
    assert sum(1 for m in direct.moves if m.src_tier == HOST) == 0
    assert sum(1 for m in direct.moves if m.src_tier == DISK) == 4
    assert direct.kv.disk_direct_pages_total == 4
    assert bounce.kv.disk_direct_pages_total == 0
    # the direct path never touched a host frame
    assert direct.kv.host.used_pages == 0
    # bitwise identical device-resident KV either way
    dev_b, dev_d = bounce.pools()[0], direct.pools()[0]
    got_b = sorted(tuple(dev_b[f]) for f in bounce.kv.device_pages_of(1))
    got_d = sorted(tuple(dev_d[f]) for f in direct.kv.device_pages_of(1))
    assert got_b == got_d


def test_direct_path_shortfall_drops_transit_frame():
    """resume_staging_shortfall: the host-bounce path always needs one
    transit frame; the direct path needs none when the device can absorb
    the whole disk set."""
    for direct, want in ((False, 1), (True, 0)):
        tw = _Twin(dev_pages=4, host_pages=4, disk_pages=8,
                   async_mode=False, direct=direct)
        _park_to_disk(tw, 1, 16, base=40)
        # consume every host frame so staging has no transit room
        assert tw.kv.alloc(2, 12) is not None
        tw.fill_device(2, base=60)
        assert tw.kv.park(2) is not None
        assert tw.kv.alloc(3, 4) is not None
        assert tw.kv.park(3) is not None
        assert tw.kv.host.free_pages == 0
        assert tw.kv.resume_staging_shortfall(1) == want


def test_prefetch_only_uses_free_host_frames():
    """Prefetch is opportunistic: it stops at host capacity, never evicts,
    and charges the pending NVMe counters like any staging."""
    tw = _Twin(dev_pages=4, host_pages=4, disk_pages=8, async_mode=True)
    _park_to_disk(tw, 1, 16, base=10)
    tw.plane.drain()
    assert tw.kv.alloc(2, 8) is not None
    tw.fill_device(2, base=90)
    assert tw.kv.park(2) is not None            # 2 host frames taken
    before = tw.kv.pending_disk_in_pages
    n = tw.kv.prefetch_from_disk(1, 99)
    assert n == 2                               # only the free frames
    assert tw.kv.host.free_pages == 0
    assert tw.kv.pending_disk_in_pages == before + 2
    assert len(tw.kv._disk_refs_of(1)) == 2     # half still on disk
    tw.plane.sync()
    host = tw.pools()[1]
    got = sorted(float(host[p][0]) for p in tw.kv.host_pages_of(1))
    assert got == [10.0, 11.0]
