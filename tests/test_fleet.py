"""Multi-instance fleet: KV-affinity routing, cross-instance preemption,
and the bitwise-composability contract (placement and migration change
timing, never numbers)."""
import numpy as np
import pytest

from repro.data.workload import SLOClass, WorkloadConfig, generate_workload
from repro.kernels import ops
from repro.serving.fleet import Fleet, Router
from repro.serving.request import Request, State

from _engine_builders import mk_reduced_engine

# compile-heavy (full JAX jit of models/kernels): excluded from the fast CI
# tier, run in the nightly full suite
pytestmark = pytest.mark.slow

MAX_SEQ, PAGE = 96, 16


def _mk_instance(name, scale=1):
    """One fleet instance; ``scale=2`` builds the consolidated big-instance
    baseline with the pooled capacity of a 2-instance fleet."""
    eng, _ = mk_reduced_engine(
        name=name, max_batch=scale * 4, max_seq=MAX_SEQ, page_size=PAGE,
        extra_device_pages=scale * 6, host_pages=scale * 40,
        prefix_dedup=True, preemption=True,
        host_prefix_cache_pages=scale * 10)
    return eng


def _tenant_reqs(n=20, seed=7):
    wcfg = WorkloadConfig(
        seed=seed, process="poisson", rate_per_s=3000.0,
        mean_rounds=2.0, mean_think_s=0.0005, tenants=2,
        system_prompt_len=48, median_turn_len=12, turn_len_sigma=0.3,
        max_prompt_len=80, mean_output_len=6.0, max_output_len=10,
        vocab_size=128,
        slo_classes=(SLOClass("standard", 4.0, 0.05, weight=1.0),))
    return generate_workload(wcfg, n)


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens,
                    ttft_slo_s=r.ttft_slo_s, tpot_slo_s=r.tpot_slo_s,
                    arrival_s=r.arrival_s, tenant=r.tenant) for r in reqs]


def _gen_tokens(engines):
    return {r.rid: tuple(r.generated) for e in engines for r in e.finished}


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        Router("fastest_first")


def test_fleet_bitwise_vs_big_instance_and_round_robin():
    """The fleet differential: the same workload served by a 2-instance
    affinity fleet, a round-robin fleet, and one consolidated big instance
    produces bitwise-identical greedy tokens per request; the affinity
    router actually routes on claimed prefix pages; every audit passes."""
    reqs = _tenant_reqs()

    aff = Fleet([_mk_instance("aff0"), _mk_instance("aff1")],
                policy="affinity")
    aff.run(_clone(reqs), max_iters=50_000)
    rr = Fleet([_mk_instance("rr0"), _mk_instance("rr1")],
               policy="round_robin")
    rr.run(_clone(reqs), max_iters=50_000)
    big = _mk_instance("big", scale=2)
    big.run(_clone(reqs), max_iters=50_000)

    t_aff, t_rr = _gen_tokens(aff.engines), _gen_tokens(rr.engines)
    t_big = _gen_tokens([big])
    assert len(t_aff) == len(t_rr) == len(t_big) == len(reqs)
    assert t_aff == t_rr == t_big

    # the affinity router saw and used real prefix hits (multi-round
    # sessions re-arrive while their earlier pages are still claimed)
    assert sum(max(d.hits) for d in aff.router.decisions) > 0
    # same-tenant sessions pile onto the instance claiming their prefix:
    # with hits present, at least one instance serves a strict majority
    # of some tenant's requests
    for fleet in (aff, rr):
        ok, violations = fleet.audit()
        assert ok, violations
    assert big.trace.audit().ok


def test_prefix_reuse_bitwise_across_unequal_lengths():
    """Shape-bucketed prefill contract: a dedup hit serves KV computed
    under a DIFFERENT prompt length, and the hitter's greedy tokens still
    match a dedup-free engine bit for bit. (Prefills bucket to one
    compiled shape, so a prefix's KV bits no longer depend on the length
    of the prompt that computed them.)"""
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, 128, 4 * PAGE).astype(np.int32)   # 4 full pages
    tails = [rng.integers(0, 128, n).astype(np.int32) for n in (2, 12)]

    def reqs():
        return [Request(rid=i, prompt=np.concatenate([prefix, t]),
                        max_new_tokens=8, ttft_slo_s=5.0, tpot_slo_s=1.0)
                for i, t in enumerate(tails)]

    tokens = {}
    for dedup in (True, False):
        eng, _ = mk_reduced_engine(
            name=f"dedup_{dedup}", max_batch=2, max_seq=MAX_SEQ,
            page_size=PAGE, extra_device_pages=16, host_pages=8,
            prefix_dedup=dedup)
        eng.run(reqs(), max_iters=2_000, submit_all=True)
        assert len(eng.finished) == 2
        tokens[dedup] = _gen_tokens([eng])
    assert tokens[True] == tokens[False]


def _manual_park(eng, req):
    """Park an ACTIVE request exactly the way _apply_preemptions does (the
    test drives the park directly so the migration moment is deterministic
    rather than load-dependent)."""
    slot = req.slot
    moves = eng.kv.park(req.rid)
    assert moves is not None
    ops.copy_pages_to_host(eng.pool, [m.src_page for m in moves],
                           eng.host_pool, [m.dst_page for m in moves])
    req.state = State.PREEMPTED
    req.preempt_count += 1
    req.parked_at_s = eng.clock_s
    eng.trace.event("park", req.rid, eng.clock_s, slot=slot)
    req.next_token = int(eng.tokens[slot])
    req.resume_pos = int(eng.pos[slot])
    req.slot = -1
    eng.active[slot] = False
    eng.slot_req[slot] = None
    eng.scheduler.preempted.append(req)


def test_cross_instance_migration_resumes_bitwise():
    """A parked request migrates to the less-loaded peer mid-decode and
    finishes there with exactly the tokens a never-migrated engine
    produces; the ticket's bytes conserve fleet-wide and both sides'
    audits stay clean."""
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, 128, 40).astype(np.int32)

    ref_eng, _ = mk_reduced_engine(name="ref", max_seq=MAX_SEQ,
                                   page_size=PAGE, extra_device_pages=12,
                                   host_pages=20, preemption=True)
    ref = Request(rid=0, prompt=prompt.copy(), max_new_tokens=12,
                  ttft_slo_s=5.0, tpot_slo_s=1.0)
    ref_eng.run([ref], max_iters=200)
    assert len(ref.generated) == 12

    e0 = _mk_instance("m0")
    e1 = _mk_instance("m1")
    fleet = Fleet([e0, e1], policy="affinity")
    victim = Request(rid=0, prompt=prompt.copy(), max_new_tokens=12,
                     ttft_slo_s=5.0, tpot_slo_s=1.0)
    e0.submit(victim)
    for _ in range(5):            # prefill + a few decode steps on e0
        e0.step()
    assert victim.state == State.DECODING and len(victim.generated) >= 3
    _manual_park(e0, victim)
    # a waiter keeps e0 "overloaded" (parked AND queued) so the fleet's
    # migration policy fires; e1 is idle and has host room
    waiter = Request(rid=1, prompt=rng.integers(0, 128, 16).astype(np.int32),
                     max_new_tokens=4, ttft_slo_s=5.0, tpot_slo_s=1.0,
                     arrival_s=e0.clock_s)
    e0.submit(waiter)
    fleet._maybe_migrate(e0)
    assert len(fleet.migrations) == 1
    assert fleet.migrations[0]["src"] == "m0"
    assert fleet.migrations[0]["dst"] == "m1"
    assert e0.n_migrated_out == 1 and e1.n_migrated_in == 1
    assert e0.mig_out_bytes_total == e1.mig_in_bytes_total > 0

    fleet.run([], max_iters=5_000)
    assert {r.rid for r in e1.finished} == {0}     # resumed on the peer
    assert {r.rid for r in e0.finished} == {1}
    migrated = e1.finished[0]
    assert tuple(migrated.generated) == tuple(ref.generated)
    ok, violations = fleet.audit()
    assert ok, violations


def test_queued_request_reroutes_to_drained_peer():
    """Satellite regression: routes bind per iteration boundary, not once
    at arrival. A request stuck QUEUED behind a long-runner re-scores after
    every fleet step and moves to the peer that has since drained — the
    withdraw/re-place path, not a migration (no KV ever moved)."""
    rng = np.random.default_rng(9)

    def mk(name):
        eng, _ = mk_reduced_engine(name=name, max_batch=1, max_seq=MAX_SEQ,
                                   page_size=PAGE, extra_device_pages=8,
                                   host_pages=20, preemption=True)
        return eng

    e0, e1 = mk("q0"), mk("q1")
    fleet = Fleet([e0, e1], policy="affinity")
    # long-runner occupies e0's single slot; a short request drains e1
    # quickly; the third arrival queues behind the long-runner and must
    # re-bind to e1 once it empties
    long_r = Request(rid=0, prompt=rng.integers(0, 128, 24).astype(np.int32),
                     max_new_tokens=24, ttft_slo_s=5.0, tpot_slo_s=1.0)
    short = Request(rid=1, prompt=rng.integers(0, 128, 16).astype(np.int32),
                    max_new_tokens=2, ttft_slo_s=5.0, tpot_slo_s=1.0)
    waiter = Request(rid=2, prompt=rng.integers(0, 128, 16).astype(np.int32),
                     max_new_tokens=4, ttft_slo_s=5.0, tpot_slo_s=1.0)
    fleet.run([long_r, short, waiter], max_iters=5_000, submit_all=True)

    assert len(_gen_tokens(fleet.engines)) == 3
    moved = [m for m in fleet.reroutes if m["rid"] == 2]
    assert moved and moved[-1]["dst"] == "q1"
    assert any(r.rid == 2 for r in e1.finished)
    ok, violations = fleet.audit()
    assert ok, violations


def test_migration_rollback_when_peer_full():
    """A peer without host room refuses the ticket; the source re-adopts
    the request into the frames the export freed and finishes it locally,
    books conserved."""
    rng = np.random.default_rng(5)
    e0 = _mk_instance("r0")
    # peer with NO host pool: never a migration target
    e1, _ = mk_reduced_engine(name="r1", max_seq=MAX_SEQ, page_size=PAGE,
                              extra_device_pages=12, host_pages=0,
                              preemption=True)
    fleet = Fleet([e0, e1], policy="affinity")
    victim = Request(rid=0, prompt=rng.integers(0, 128, 40).astype(np.int32),
                     max_new_tokens=10, ttft_slo_s=5.0, tpot_slo_s=1.0)
    e0.submit(victim)
    for _ in range(4):
        e0.step()
    _manual_park(e0, victim)
    e0.submit(Request(rid=1,
                      prompt=rng.integers(0, 128, 16).astype(np.int32),
                      max_new_tokens=4, ttft_slo_s=5.0, tpot_slo_s=1.0,
                      arrival_s=e0.clock_s))
    fleet._maybe_migrate(e0)
    assert not fleet.migrations          # nowhere to go: stays parked here
    assert e0.n_migrated_out == 0
    fleet.run([], max_iters=5_000)
    assert {r.rid for r in e0.finished} == {0, 1}
    ok, violations = fleet.audit()
    assert ok, violations
