"""While-aware HLO cost extraction: scan bodies must be trip-count weighted
(flops equal to the unrolled program), slice fusions must not charge whole
buffers, collectives inside loops must scale."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_costs


def _mm_body(x, w):
    return jnp.tanh(x @ w), None


def _costs(fn, *args, donate=()):
    c = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
    return hlo_costs.analyze(c.as_text())


def test_scan_flops_match_unrolled():
    x = jnp.zeros((128, 256), jnp.float32)
    ws = jnp.zeros((8, 256, 256), jnp.float32)

    def scanned(x, ws):
        out, _ = jax.lax.scan(_mm_body, x, ws)
        return out

    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x, _ = _mm_body(x, ws[i])
        return x

    fs = _costs(scanned, x, ws).flops
    fu = _costs(unrolled, x, ws).flops
    dot_flops = 2 * 8 * 128 * 256 * 256
    assert fs == pytest.approx(fu, rel=1e-6)
    assert fs == pytest.approx(dot_flops, rel=0.01)  # + tanh elementwise


def test_scan_bytes_close_to_unrolled():
    x = jnp.zeros((128, 256), jnp.float32)
    ws = jnp.zeros((8, 256, 256), jnp.float32)

    def scanned(x, ws):
        out, _ = jax.lax.scan(_mm_body, x, ws)
        return out

    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x, _ = _mm_body(x, ws[i])
        return x

    bs = _costs(scanned, x, ws).hbm_bytes
    bu = _costs(unrolled, x, ws).hbm_bytes
    assert bs == pytest.approx(bu, rel=0.25)
    # weights must be read once per layer: >= 8 * 256*256*4 bytes
    assert bs >= 8 * 256 * 256 * 4


def test_dus_cache_update_counts_slice_not_buffer():
    cache = jnp.zeros((4, 32768, 128), jnp.bfloat16)
    tok = jnp.zeros((4, 1, 128), jnp.bfloat16)

    def upd(cache, tok, idx):
        return jax.lax.dynamic_update_slice(cache, tok, (0, idx, 0))

    b = _costs(upd, cache, tok, jnp.int32(5), donate=(0,)).hbm_bytes
    assert b < 100_000, f"cache update charged {b} bytes (full buffer leak)"


def test_full_cache_read_still_counted():
    cache = jnp.zeros((4, 8192, 128), jnp.bfloat16)
    q = jnp.zeros((4, 128), jnp.float32)

    def attn(cache, q):
        return jnp.einsum("bsd,bd->bs", cache.astype(jnp.float32), q)

    r = _costs(attn, cache, q)
    assert r.hbm_bytes >= cache.size * 2           # full cache read
    assert r.flops == pytest.approx(2 * 4 * 8192 * 128, rel=0.05)


def test_nested_scan_multiplies():
    x = jnp.zeros((16, 64), jnp.float32)
    ws = jnp.zeros((4, 3, 64, 64), jnp.float32)

    def inner(x, ws3):
        out, _ = jax.lax.scan(_mm_body, x, ws3)
        return out

    def outer(x, ws):
        out, _ = jax.lax.scan(lambda c, w3: (inner(c, w3), None), x, ws)
        return out

    f = _costs(outer, x, ws).flops
    assert f == pytest.approx(2 * 12 * 16 * 64 * 64, rel=0.01)


_COLL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch import hlo_costs
    mesh = jax.make_mesh((8,), ("m",))
    def f(xs):
        def body(c, x):
            s = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(None)))
            return c + s.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), xs)
        return out
    xs = jnp.zeros((6, 1024), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=NamedSharding(mesh, P(None, "m")),
                    out_shardings=NamedSharding(mesh, P())).lower(xs).compile()
    r = hlo_costs.analyze(c.as_text(), default_group=8)
    n = sum(r.collective_count.values())
    assert n >= 6, f"collectives not trip-weighted: {r.collective_count}"
    print("OK", r.collective_count)
""")


def test_collectives_trip_weighted():
    # JAX_PLATFORMS=cpu: the script forces 8 *host* devices; without the
    # pin, a stripped env lets jax probe accelerator plugins (libtpu init
    # can block for minutes waiting on the device lock).
    out = subprocess.run([sys.executable, "-c", _COLL_SCRIPT],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
