"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes, dtypes, GQA groupings, masks, and paged layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# compile-heavy (full JAX jit of models/kernels): excluded from the fast CI
# tier, run in the nightly full suite
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _mk_qkv(b, h, vh, sq, sk, d, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, vh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, vh, sk, d), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,vh,sq,sk,d", [
    (1, 4, 4, 64, 64, 64),       # MHA square
    (2, 8, 2, 128, 128, 64),     # GQA
    (1, 8, 1, 96, 96, 128),      # MQA, non-multiple seq (padding path)
    (1, 4, 4, 256, 256, 32),     # multi q/kv blocks
])
def test_flash_causal(dtype, b, h, vh, sq, sk, d):
    q, k, v = _mk_qkv(b, h, vh, sq, sk, d, dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    q, k, v = _mk_qkv(1, 4, 2, 128, 128, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_noncausal_cross():
    q, k, v = _mk_qkv(2, 4, 4, 32, 80, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                              interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_kv_len_mask():
    q, k, v = _mk_qkv(1, 4, 4, 64, 128, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, kv_len=100,
                              block_q=32, block_k=32, interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=False, kv_len=100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_style_offset():
    """sq < sk with causal: queries are the LAST sq positions (chunked
    prefill continuation)."""
    q, k, v = _mk_qkv(1, 4, 4, 32, 128, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Paged decode
# ---------------------------------------------------------------------------


def _mk_paged(b, h, vh, d, npages, page, nb, dtype, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (npages, page, vh, d), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (npages, page, vh, d), jnp.float32).astype(dtype)
    # distinct page assignment per request
    perm = jax.random.permutation(ks[3], npages)[: b * nb]
    bt = perm.reshape(b, nb).astype(jnp.int32)
    cl = jax.random.randint(ks[4], (b,), 1, nb * page + 1, jnp.int32)
    return q, kp, vp, bt, cl


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,vh,d,page,nb", [
    (2, 4, 4, 64, 16, 4),
    (3, 8, 2, 64, 32, 3),     # GQA
    (1, 8, 1, 128, 16, 8),    # MQA
])
def test_paged_decode(dtype, b, h, vh, d, page, nb):
    q, kp, vp, bt, cl = _mk_paged(b, h, vh, d, b * nb + 3, page, nb, dtype)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_paged_decode_sliding_window():
    q, kp, vp, bt, cl = _mk_paged(2, 4, 2, 64, 19, 8, 9, jnp.float32)
    cl = jnp.asarray([60, 33], jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, window=20,
                                     interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl, window=20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_single_token_context():
    q, kp, vp, bt, _ = _mk_paged(2, 4, 4, 64, 16, 2, 7, jnp.float32)
    cl = jnp.asarray([1, 5], jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_padded_table_with_out_of_range_entries():
    """Block-table slots beyond the live context may hold garbage ids (the
    engine pads with a null frame; a buggy caller could pad with anything):
    the kernel clamps them into the pool and the context mask hides them."""
    q, kp, vp, bt, _ = _mk_paged(2, 4, 2, 64, 13, 16, 4, jnp.float32)
    cl = jnp.asarray([18, 33], jnp.int32)        # 2 resp. 3 live pages of 4
    bt = np.array(bt)
    bt[0, 2:] = 99_999
    bt[1, 3:] = -3
    got = ops.paged_decode_attention(q, kp, vp, jnp.asarray(bt), cl,
                                     interpret=True)
    want = ref.ref_paged_decode_attention(
        q, kp, vp, jnp.clip(jnp.asarray(bt), 0, 12), cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_context_not_page_multiple():
    q, kp, vp, bt, _ = _mk_paged(3, 8, 2, 64, 15, 16, 3, jnp.float32)
    cl = jnp.asarray([7, 17, 45], jnp.int32)     # none divisible by 16
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_batch_one():
    q, kp, vp, bt, _ = _mk_paged(1, 4, 4, 64, 9, 16, 4, jnp.float32)
    for c in (1, 15, 16, 17, 64):                # page-boundary straddles
        cl = jnp.asarray([c], jnp.int32)
        got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
        want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_flash_matches_model_chunked_attention():
    """Kernel and the jnp chunked implementation used at dry-run scale must
    agree (they are the same algorithm at different layers)."""
    from repro.configs import get_config
    from repro.configs.reduced import reduce_config
    from repro.models import layers as L

    cfg = reduce_config(get_config("deepseek-7b"))
    b, s, h, d = 2, 64, cfg.num_heads, cfg.resolved_head_dim
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    chunked = L.attn_chunked(cfg, q, k, v, pos, pos, chunk=16)
    kern = ops.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(chunked),
                               np.asarray(kern.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)
