"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes, dtypes, GQA groupings, masks, and paged layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# compile-heavy (full JAX jit of models/kernels): excluded from the fast CI
# tier, run in the nightly full suite
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _mk_qkv(b, h, vh, sq, sk, d, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, vh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, vh, sk, d), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,vh,sq,sk,d", [
    (1, 4, 4, 64, 64, 64),       # MHA square
    (2, 8, 2, 128, 128, 64),     # GQA
    (1, 8, 1, 96, 96, 128),      # MQA, non-multiple seq (padding path)
    (1, 4, 4, 256, 256, 32),     # multi q/kv blocks
])
def test_flash_causal(dtype, b, h, vh, sq, sk, d):
    q, k, v = _mk_qkv(b, h, vh, sq, sk, d, dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    q, k, v = _mk_qkv(1, 4, 2, 128, 128, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_noncausal_cross():
    q, k, v = _mk_qkv(2, 4, 4, 32, 80, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                              interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_kv_len_mask():
    q, k, v = _mk_qkv(1, 4, 4, 64, 128, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, kv_len=100,
                              block_q=32, block_k=32, interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=False, kv_len=100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_style_offset():
    """sq < sk with causal: queries are the LAST sq positions (chunked
    prefill continuation)."""
    q, k, v = _mk_qkv(1, 4, 4, 32, 128, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Paged decode
# ---------------------------------------------------------------------------


def _mk_paged(b, h, vh, d, npages, page, nb, dtype, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (npages, page, vh, d), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (npages, page, vh, d), jnp.float32).astype(dtype)
    # distinct page assignment per request
    perm = jax.random.permutation(ks[3], npages)[: b * nb]
    bt = perm.reshape(b, nb).astype(jnp.int32)
    cl = jax.random.randint(ks[4], (b,), 1, nb * page + 1, jnp.int32)
    return q, kp, vp, bt, cl


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,vh,d,page,nb", [
    (2, 4, 4, 64, 16, 4),
    (3, 8, 2, 64, 32, 3),     # GQA
    (1, 8, 1, 128, 16, 8),    # MQA
])
def test_paged_decode(dtype, b, h, vh, d, page, nb):
    q, kp, vp, bt, cl = _mk_paged(b, h, vh, d, b * nb + 3, page, nb, dtype)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_paged_decode_sliding_window():
    q, kp, vp, bt, cl = _mk_paged(2, 4, 2, 64, 19, 8, 9, jnp.float32)
    cl = jnp.asarray([60, 33], jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, window=20,
                                     interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl, window=20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_single_token_context():
    q, kp, vp, bt, _ = _mk_paged(2, 4, 4, 64, 16, 2, 7, jnp.float32)
    cl = jnp.asarray([1, 5], jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_padded_table_with_out_of_range_entries():
    """Block-table slots beyond the live context may hold garbage ids (the
    engine pads with a null frame; a buggy caller could pad with anything):
    the kernel clamps them into the pool and the context mask hides them."""
    q, kp, vp, bt, _ = _mk_paged(2, 4, 2, 64, 13, 16, 4, jnp.float32)
    cl = jnp.asarray([18, 33], jnp.int32)        # 2 resp. 3 live pages of 4
    bt = np.array(bt)
    bt[0, 2:] = 99_999
    bt[1, 3:] = -3
    got = ops.paged_decode_attention(q, kp, vp, jnp.asarray(bt), cl,
                                     interpret=True)
    want = ref.ref_paged_decode_attention(
        q, kp, vp, jnp.clip(jnp.asarray(bt), 0, 12), cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_context_not_page_multiple():
    q, kp, vp, bt, _ = _mk_paged(3, 8, 2, 64, 15, 16, 3, jnp.float32)
    cl = jnp.asarray([7, 17, 45], jnp.int32)     # none divisible by 16
    got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_batch_one():
    q, kp, vp, bt, _ = _mk_paged(1, 4, 4, 64, 9, 16, 4, jnp.float32)
    for c in (1, 15, 16, 17, 64):                # page-boundary straddles
        cl = jnp.asarray([c], jnp.int32)
        got = ops.paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
        want = ref.ref_paged_decode_attention(q, kp, vp, bt, cl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Copy-on-write through the engine path (prefix dedup)
# ---------------------------------------------------------------------------


def _mk_cow_engine(extra_device_pages: float, host_pages: int):
    """Dedup engine sized so two identical 10-token prompts (page 4: two
    full pages + a 2-token partial page) share all three prompt pages."""
    from _engine_builders import mk_reduced_engine

    eng, _ = mk_reduced_engine(name="cow", max_batch=2, max_seq=24,
                               page_size=4,
                               extra_device_pages=extra_device_pages,
                               host_pages=host_pages, prefix_dedup=True,
                               batches=(1, 2), seqs=(16, 32))
    return eng


def _submit_twins(eng, new=6):
    from repro.serving.request import Request

    prompt = (np.arange(10) * 7 % 97).astype(np.int32)
    for rid in range(2):
        eng.submit(Request(rid=rid, prompt=prompt.copy(),
                           max_new_tokens=new,
                           ttft_slo_s=10.0, tpot_slo_s=10.0))
    eng._admit()                 # prefill both; rid 1 dedups all 3 pages
    assert eng.kv.dedup_hit_pages(1) == [0, 1, 2]
    shared = eng.kv.refs(0)[2]
    assert eng.kv.refs(1)[2] == shared
    return shared


def test_cow_write_leaves_sibling_device_page_bitwise_unchanged():
    """Engine-path COW: both twins decode into the shared partial page in
    the same iteration — the later-admitted one must move onto its reserve
    and the sibling-visible bytes of every shared page (the prompt token
    slots) must be bitwise identical before and after, in the shared frame
    AND in the private copy."""
    eng = _mk_cow_engine(extra_device_pages=14, host_pages=0)
    shared = _submit_twins(eng)
    assert shared.tier == "device"
    full_frames = [eng.kv.refs(0)[0].page, eng.kv.refs(0)[1].page]
    ids = jnp.asarray([shared.page] + full_frames, jnp.int32)
    before = np.asarray(ops.gather_kv_pages(eng.pool, ids))

    eng.step()                   # first decode write for both twins
    assert eng.cow_events == 1   # rid 1 moved off; rid 0 appends in place
    new1 = eng.kv.refs(1)[2]
    assert new1 != shared and eng.kv.refs(0)[2] == shared
    after = np.asarray(ops.gather_kv_pages(eng.pool, ids))
    # full shared pages: bitwise untouched entirely
    assert np.array_equal(before[1:], after[1:])
    # shared partial page: the 2 prompt-token slots (all a sibling's
    # attention can see) bitwise untouched; offsets >= 2 hold rid 0's new
    # token, which rid 1's context length masks
    assert np.array_equal(before[0][:2], after[0][:2])
    # rid 1's private copy preserved the prompt bytes too
    got1 = np.asarray(ops.gather_kv_pages(
        eng.pool, jnp.asarray([new1.page], jnp.int32)))[0]
    assert np.array_equal(before[0][:2], got1[:2])
    # ... and the twins keep generating identical tokens
    for _ in range(5):
        eng.step()
    gens = [r.generated for r in sorted(eng.finished, key=lambda r: r.rid)]
    assert len(gens) == 2 and gens[0] == gens[1]
    eng.kv.check_invariants()


def test_cow_write_on_host_resident_streamed_shared_page():
    """Same protocol with ZERO device pages: the shared pages live on host,
    stream through the slab every iteration, and the decode write lands on
    a streamed page (dirty write-back). The write-back must not leak the
    writer's token into the sibling-visible bytes of the shared host slot,
    and the COW copy must land in the writer's host reserve."""
    eng = _mk_cow_engine(extra_device_pages=0.25, host_pages=16)
    assert eng.kv.device.total_pages == 0
    shared = _submit_twins(eng)
    assert shared.tier == "host"
    full_slots = [eng.kv.refs(0)[0].page, eng.kv.refs(0)[1].page]
    before_partial = eng.host_pool[shared.page].copy()
    before_full = eng.host_pool[np.asarray(full_slots)].copy()

    eng.step()
    assert eng.cow_events == 1
    new1 = eng.kv.refs(1)[2]
    assert new1.tier == "host" and new1 != shared
    assert np.array_equal(before_full,
                          eng.host_pool[np.asarray(full_slots)])
    # rid 0's write came back through the slab into the shared slot, but
    # only at offsets a sibling never reads
    assert np.array_equal(before_partial[:2],
                          eng.host_pool[shared.page][:2])
    assert not np.array_equal(before_partial[2],
                              eng.host_pool[shared.page][2])
    assert np.array_equal(before_partial[:2],
                          eng.host_pool[new1.page][:2])
    for _ in range(5):
        eng.step()
    gens = [r.generated for r in sorted(eng.finished, key=lambda r: r.rid)]
    assert len(gens) == 2 and gens[0] == gens[1]
    assert eng.kv.host.used_pages == 0
    eng.kv.check_invariants()


def test_cow_cross_tier_copy_charged_to_link_budget():
    """A COW whose reserve sits on the other tier moves a real page over
    the host link — the modeled iteration must charge both the d2h copy
    and the post-COW streaming, exactly once (regression: the pre-pass
    originally moved the bytes without billing them)."""
    import pytest as _pytest

    from repro.core.interval import iter_time_with_interval_kv

    eng = _mk_cow_engine(extra_device_pages=4, host_pages=16)
    shared = _submit_twins(eng)
    assert shared.tier == "device"           # twin 0 owns all 4 dev pages
    assert eng.kv.reserve_of(1).tier == "host"   # dev pool full: host spare
    t0 = eng.clock_s
    pb = eng.kv.page_bytes
    times = eng.times_fn(2, eng.ecfg.max_seq, "decode")
    eng.step()                               # twin 1's write COWs dev->host
    assert eng.cow_events == 1
    assert eng.kv.refs(1)[2].tier == "host"
    # link charge: the post-COW streamed pages (twin 1's tail + its new
    # private write page) gate compute; the COW page itself writes back
    streamed_after = eng.swap.streamed_bytes([0, 1])
    assert streamed_after == 2 * pb
    predicted = iter_time_with_interval_kv(times, eng.interval,
                                           streamed_after, pb)
    assert eng.clock_s - t0 == _pytest.approx(predicted, rel=1e-9)
    eng.kv.check_invariants()


def test_flash_matches_model_chunked_attention():
    """Kernel and the jnp chunked implementation used at dry-run scale must
    agree (they are the same algorithm at different layers)."""
    from repro.configs import get_config
    from repro.configs.reduced import reduce_config
    from repro.models import layers as L

    cfg = reduce_config(get_config("deepseek-7b"))
    b, s, h, d = 2, 64, cfg.num_heads, cfg.resolved_head_dim
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    chunked = L.attn_chunked(cfg, q, k, v, pos, pos, chunk=16)
    kern = ops.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(chunked),
                               np.asarray(kern.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)
