"""Two-tier KV offloading: allocator edge cases, page migration round trips,
combined weight+KV link algebra, coordinator arbitration, and the engine
serving beyond-HBM workloads without TPOT violations."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.core.coordinator import InstanceState, coordinate
from repro.core.interval import (LayerTimes, NO_OFFLOAD,
                                 iter_time_with_interval,
                                 iter_time_with_interval_kv, link_bandwidth)
from repro.core.simulator import schedule_for_interval, simulate_iteration
from repro.kernels import ops
from repro.serving.kv_cache import PageConfig, PagedKVAllocator
from repro.serving.kv_offload import (DEVICE, DISK, HOST, DiskKVPool,
                                      LinkSpec, PageRef, SwapScheduler,
                                      TieredKVAllocator)


def _pcfg(page_size=4, bpt=4):
    return PageConfig(page_size=page_size, bytes_per_token=bpt)


# ---------------------------------------------------------------------------
# PagedKVAllocator edge cases
# ---------------------------------------------------------------------------

def test_allocator_double_free_is_noop():
    a = PagedKVAllocator(16 * 16, _pcfg())
    a.alloc(1, 10)
    a.free(1)
    a.free(1)                       # second free must not corrupt the pool
    a.check_invariants()
    assert a.used_pages == 0


def test_allocator_extend_after_free():
    a = PagedKVAllocator(16 * 16, _pcfg())
    a.alloc(1, 10)
    a.free(1)
    assert a.extend(1, 8)           # rid was forgotten: extend re-allocates
    assert a.used_pages == a.pages_for(8)
    a.check_invariants()


def test_allocator_zero_page_alloc():
    a = PagedKVAllocator(16 * 16, _pcfg())
    pages = a.alloc(1, 0)
    assert pages == []
    assert a.used_pages == 0
    a.check_invariants()


def test_allocator_exhaustion_and_refill():
    a = PagedKVAllocator(8 * 16, _pcfg())   # 8 pages
    total = a.total_pages
    rids = []
    for rid in range(total):
        assert a.alloc(rid, a.pcfg.page_size) is not None
        rids.append(rid)
    assert a.free_pages == 0
    assert a.alloc(99, 1) is None
    a.check_invariants()
    for rid in rids:
        a.free(rid)
    a.check_invariants()
    assert a.free_pages == total
    assert len(set(a._free)) == total        # free list holds no duplicates
    assert a.alloc(100, total * a.pcfg.page_size) is not None
    a.check_invariants()


def test_allocator_release_foreign_page_raises():
    a = PagedKVAllocator(16 * 16, _pcfg())
    a.alloc(1, 4)
    with pytest.raises(ValueError):
        a.release_pages(1, [123])
    a.check_invariants()


# ---------------------------------------------------------------------------
# Tiered allocation + migration
# ---------------------------------------------------------------------------

def test_tiered_spill_layout_host_holds_cold_prefix():
    kv = TieredKVAllocator(4 * 16, 8 * 16, _pcfg())   # 4 device, 8 host pages
    refs = kv.alloc(1, 7 * 4)                          # 7 pages: 3 spill
    assert refs is not None and len(refs) == 7
    assert [r.tier for r in refs] == [HOST] * 3 + [DEVICE] * 4
    kv.check_invariants()
    assert kv.alloc(2, 5 * 4, allow_host=False) is None  # device exhausted


def test_tiered_migration_round_trip_accounting():
    kv = TieredKVAllocator(6 * 16, 6 * 16, _pcfg())
    kv.alloc(1, 6 * 4)                                 # fully device
    out = kv.swap_out(1, 2)
    assert len(out) == 2
    assert len(kv.host_pages_of(1)) == 2
    assert len(kv.device_pages_of(1)) == 4
    kv.check_invariants()
    back = kv.swap_in(1, 99)                           # promote everything
    assert len(back) == 2
    assert kv.host_pages_of(1) == []
    kv.check_invariants()
    kv.free(1)
    kv.check_invariants()
    assert kv.device.used_pages == 0 and kv.host.used_pages == 0


def test_tiered_extend_self_evicts_cold_page():
    kv = TieredKVAllocator(3 * 16, 8 * 16, _pcfg())    # 3 device pages
    kv.alloc(1, 3 * 4)                                 # device full
    moves = kv.extend(1, 4 * 4)                        # tail growth
    assert moves is not None and len(moves) == 1       # one demotion
    assert moves[0].src_tier == DEVICE
    # the tail (newest) page stays on device, the cold prefix went host-ward
    assert kv.refs(1)[0].tier == HOST
    assert kv.refs(1)[-1].tier == DEVICE
    kv.check_invariants()


def test_tiered_extend_on_demote_fires_before_frame_reuse():
    """The vacated device frame may be recycled as the new tail page within
    the same extend() call, so the data-plane copy hook must run while the
    frame is still free — this is the contract a real page buffer needs."""
    kv = TieredKVAllocator(2 * 16, 8 * 16, _pcfg())    # 2 device pages
    kv.alloc(1, 2 * 4)                                 # device full
    seen = []

    def on_demote(m):
        # at hook time the demoted frame is free, not yet reused
        assert m.src_page in kv.device._free
        seen.append(m.src_page)

    moves = kv.extend(1, 3 * 4, on_demote=on_demote)
    assert len(moves) == 1 and seen == [moves[0].src_page]
    # ...and afterwards that same frame IS the new tail (LIFO free list),
    # which is exactly why the hook has to be synchronous
    assert kv.refs(1)[-1].page == moves[0].src_page
    kv.check_invariants()


def test_tiered_extend_failure_rolls_back_tail_pages():
    """A mid-loop failure must not leave stray tail pages: the refs list has
    to keep matching the request's token count (demotions may remain — the
    data plane can already have copied them)."""
    kv = TieredKVAllocator(3 * 16, 1 * 16, _pcfg())    # 1 host page only
    kv.alloc(1, 3 * 4)
    out = kv.extend(1, 6 * 4)                          # needs 3, host fits 1
    assert out is None
    assert len(kv.refs(1)) == 3                        # token count preserved
    kv.check_invariants()


def test_tiered_resize_device_overflow_raises_before_mutation():
    kv = TieredKVAllocator(8 * 16, 2 * 16, _pcfg())
    kv.alloc(1, 5 * 4)
    kv.alloc(2, 3 * 4)
    with pytest.raises(RuntimeError):
        kv.resize_device(2 * 16)                       # overflow 6 > host 2
    kv.check_invariants()
    # nothing moved: the failure happened before any mutation
    assert len(kv.device_pages_of(1)) == 5
    assert kv.host.used_pages == 0
    kv.check_invariants()


def test_tiered_resize_device_demotes_then_reassigns():
    kv = TieredKVAllocator(8 * 16, 8 * 16, _pcfg())
    kv.alloc(1, 5 * 4)
    kv.alloc(2, 3 * 4)
    res = kv.resize_device(4 * 16)                     # shrink 8 -> 4 pages
    assert res.num_demoted == 4
    # demotions name real old device frames / host slots for the data plane
    assert all(m.src_tier == "device" for m in res.demotions)
    assert sorted(m.dst_page for m in res.demotions) == \
        sorted(p for rid in (1, 2) for p in kv.host_pages_of(rid))
    # surviving pages got a frame remap usable for a physical permute
    assert sorted(n for _, n in res.remap) == \
        sorted(p for rid in (1, 2) for p in kv.device_pages_of(rid))
    assert len(kv.device_pages_of(1)) + len(kv.device_pages_of(2)) == 4
    assert len(kv.host_pages_of(1)) + len(kv.host_pages_of(2)) == 4
    kv.check_invariants()
    grown = kv.resize_device(16 * 16)                  # grow back
    assert grown.num_demoted == 0
    sched = SwapScheduler(kv)
    plan = sched.plan_iteration([1, 2])                # promotions backfill
    assert len(plan.promotions) == 4
    assert kv.host_pages_of(1) == [] and kv.host_pages_of(2) == []
    kv.check_invariants()


def test_page_copy_round_trip_bitwise():
    """device -> host -> device through the real data plane, bitwise equal."""
    page, vh, d = 8, 2, 16
    pcfg = PageConfig(page_size=page, bytes_per_token=1)
    kv = TieredKVAllocator(6 * page, 8 * page, pcfg)
    kv.alloc(0, 3 * page)
    rng = np.random.default_rng(0)
    k_pool = jnp.asarray(rng.normal(size=(6, page, vh, d)).astype(np.float32))
    v_pool = jnp.asarray(rng.normal(size=(6, page, vh, d)).astype(np.float32))
    k_host = kv.host.make_pool_buffer((page, vh, d))
    v_host = kv.host.make_pool_buffer((page, vh, d))

    q = jnp.asarray(rng.normal(size=(1, 4, d)).astype(np.float32))
    cl = jnp.asarray([3 * page - 2], jnp.int32)
    bt0 = kv.device_block_table(0, 3)[None]
    out0 = ops.paged_decode_attention(q, k_pool, v_pool,
                                      jnp.asarray(bt0), cl, interpret=True)
    k_orig = np.asarray(k_pool)

    # migrations batch into one copy per direction per buffer (the intended
    # data-plane usage: one scatter/gather per iteration, not per page)
    moves = kv.swap_out(0, 2)
    src = [m.src_page for m in moves]
    dst = [m.dst_page for m in moves]
    ops.copy_pages_to_host(k_pool, src, k_host, dst)
    ops.copy_pages_to_host(v_pool, src, v_host, dst)
    # clobber the vacated device frames: the copy path must restore content
    k_pool = k_pool.at[jnp.asarray(src)].set(0.0)
    v_pool = v_pool.at[jnp.asarray(src)].set(0.0)

    back = kv.swap_in(0, 2)
    bsrc = [m.src_page for m in back]
    bdst = [m.dst_page for m in back]
    k_pool = ops.copy_pages_from_host(k_host, bsrc, k_pool, bdst)
    v_pool = ops.copy_pages_from_host(v_host, bsrc, v_pool, bdst)
    # bitwise round trip of the migrated page contents
    dev_now = kv.device_block_table(0, 3)
    for before, after in zip(bt0[0], dev_now):
        assert np.array_equal(k_orig[before], np.asarray(k_pool)[after])
    out1 = ops.paged_decode_attention(q, k_pool, v_pool,
                                      jnp.asarray(dev_now[None]), cl,
                                      interpret=True)
    assert np.array_equal(np.asarray(out0), np.asarray(out1))


def test_streamed_and_writeback_bytes_count_shared_pages_once():
    """Regression for a latent PR-1 double-count that sharing exposes: a
    host page referenced by several active requests streams over the link
    ONCE per iteration and a shared demotion writes back ONCE — but the
    per-request accounting (`sum(host_bytes_of(r))`) bills it per owner.
    The SLO math consumes these numbers directly, so the double-count would
    inflate the modeled iteration time and make admission refuse requests
    the link can actually serve."""
    pcfg = _pcfg()
    pb = pcfg.page_size * pcfg.bytes_per_token
    kv = TieredKVAllocator(0, 8 * pb, pcfg, scope="m", enable_dedup=True)
    prompt = np.arange(2 * pcfg.page_size, dtype=np.int64)
    kv.alloc(1, 2 * pcfg.page_size, prompt=prompt)   # 2 host pages
    kv.alloc(2, 2 * pcfg.page_size, prompt=prompt)   # same 2 frames shared
    assert kv.host.used_pages == 2
    sched = SwapScheduler(kv)
    # frame-wise: 2 unique pages, not 4 owner references
    assert sched.streamed_bytes([1, 2]) == 2 * pb
    assert sum(kv.host_bytes_of(r) for r in (1, 2)) == 4 * pb  # the trap
    # tie to the SLO math: the modeled iteration charges the deduped
    # stream; per-owner billing would claim a strictly slower iteration
    times = LayerTimes(2e-3, 5e-3, 8, 1 << 20, 0.0)
    bw = link_bandwidth(times)
    t = iter_time_with_interval_kv(times, NO_OFFLOAD,
                                   sched.streamed_bytes([1, 2]))
    assert t == pytest.approx(times.t_iter_no_offload_s + 2 * pb / bw)
    t_wrong = iter_time_with_interval_kv(
        times, NO_OFFLOAD, sum(kv.host_bytes_of(r) for r in (1, 2)))
    assert t_wrong > t
    # write-back side: demoting a shared frame is ONE migration -> one
    # pending-out page, charged once
    kv2 = TieredKVAllocator(4 * pb, 8 * pb, pcfg, scope="m",
                            enable_dedup=True)
    p2 = np.arange(2 * pcfg.page_size, dtype=np.int64) + 7
    kv2.alloc(1, 2 * pcfg.page_size, prompt=p2)
    kv2.alloc(2, 2 * pcfg.page_size, prompt=p2)
    res = kv2.resize_device(0)
    assert res.num_demoted == 2                      # unique frames moved
    sched2 = SwapScheduler(kv2)
    sched2.note_demotions(res.num_demoted)
    assert sched2.pending_out_bytes() == 2 * pb
    # and promotion back in bills each shared frame once as kv_in
    kv2.resize_device(4 * pb)
    plan = sched2.plan_iteration([1, 2])
    assert len(plan.promotions) == 2
    assert plan.kv_in_bytes == 2 * pb + plan.streamed_bytes
    assert plan.streamed_bytes == 0.0
    kv2.check_invariants()


def test_swap_out_spills_unshared_before_shared_hot_frames():
    """Regression (spill path of the park-target rule): ``swap_out`` used to
    demote the OLDEST device frames even when an active sibling still
    referenced them — moving a hot shared frame frees no lasting capacity
    (the sibling must stream it back every iteration). With ``active_rids``
    given, unshared frames spill first and the shared hot frame stays on
    device until nothing else remains."""
    pcfg = _pcfg()
    kv = TieredKVAllocator(8 * 16, 8 * 16, pcfg, scope="m", enable_dedup=True)
    shared_prompt = np.arange(2 * pcfg.page_size, dtype=np.int64)
    kv.alloc(1, 2 * pcfg.page_size, prompt=shared_prompt)   # origin
    long_prompt = np.concatenate(
        [shared_prompt, np.arange(100, 100 + 2 * pcfg.page_size)])
    kv.alloc(2, 4 * pcfg.page_size, prompt=long_prompt)     # shares pages 0-1
    shared = {r.page for r in kv.refs(1)}
    assert shared and shared == {r.page for r in kv.refs(2)[:2]}

    # oldest-first would take refs[0] (shared, hot); the fix takes the first
    # frame no active sibling references
    moves = kv.swap_out(2, 1, active_rids=[1])
    assert len(moves) == 1
    assert moves[0].src_page not in shared, "shared hot frame spilled"
    assert all(r.tier == DEVICE for r in kv.refs(1)), "sibling was disturbed"
    kv.check_invariants()

    # fall back only when nothing unshared remains: demanding 3 more frames
    # spills the last private one first, then the shared ones move too
    moves2 = kv.swap_out(2, 3, active_rids=[1])
    assert len(moves2) == 3
    assert moves2[0].src_page not in shared
    assert {m.src_page for m in moves2[1:]} == shared
    assert all(r.tier == HOST for r in kv.refs(1))          # moved once, both
    kv.check_invariants()


def test_park_preview_nets_out_reclaimable_cache():
    """Regression (preview/park parity): ``park`` reclaims keep-alive
    prefix-cache frames before giving up, but the preview used to report
    the raw target count — a precheck against ``host.free_pages`` refused
    parks the real call absorbs. The netted preview certifies a park that
    succeeds ONLY through cache reclaim."""
    kv = TieredKVAllocator(2 * 16, 2 * 16, _pcfg(), scope="pp",
                           enable_dedup=True, host_prefix_cache_pages=4)
    p = np.arange(16, dtype=np.int64)
    kv.alloc(0, 16, prompt=p)                  # 2 host (cold) + 2 device
    kv.free(0)                                 # host frames adopted as cache
    assert kv.host.free_pages == 0
    assert kv.reclaimable_host_pages() == 2
    kv.alloc(1, 8)                             # 2 device pages
    n_free, n_need = kv.park_preview(1)
    assert n_free == 2
    assert n_need == 0, "preview must credit reclaimable cache frames"
    moves = kv.park(1)                         # succeeds only via reclaim
    assert moves is not None and len(moves) == 2
    assert len(kv.host_pages_of(1)) == 2
    kv.check_invariants()


def test_plan_iteration_reselects_cheapest_after_shared_promotion():
    """Regression (stale promotion order): a shared-frame ``swap_in``
    rewrites SIBLING host-page counts mid-loop, so the one-shot up-front
    sort by "fewest host pages" goes stale. A(2 pages, one shared with C),
    B(3), C(3): promoting A drops C to 2, so the remaining free frames
    belong to C — the stale order would hand them to B."""
    pcfg = _pcfg()
    pb = pcfg.page_size * pcfg.bytes_per_token
    kv = TieredKVAllocator(4 * pb, 16 * pb, pcfg, scope="m",
                           enable_dedup=True)
    kv.alloc(9, 4 * pcfg.page_size)            # fill the device pool
    pa = np.arange(2 * pcfg.page_size, dtype=np.int64)
    kv.alloc(1, 2 * pcfg.page_size, prompt=pa)                   # A: 2 host
    kv.alloc(2, 3 * pcfg.page_size)                              # B: 3 host
    pc = np.concatenate([pa[:pcfg.page_size],
                         np.arange(900, 900 + 2 * pcfg.page_size)])
    kv.alloc(3, 3 * pcfg.page_size, prompt=pc)   # C: 3 host, page 0 shared w/ A
    assert kv.refs(3)[0] in kv.refs(1), "A and C must share page 0"
    kv.free(9)                                 # 4 device frames free up
    sched = SwapScheduler(kv)
    plan = sched.plan_iteration([1, 2, 3])
    # A promotes first (cheapest: 2). Its shared frame moves C to 2 host
    # pages — so the remaining 2 free frames go to C, not B.
    assert [m.rid for m in plan.promotions] == [1, 1, 3, 3]
    assert kv.host_pages_of(1) == [] and kv.host_pages_of(3) == []
    assert len(kv.host_pages_of(2)) == 3
    kv.check_invariants()


# ---------------------------------------------------------------------------
# Disk (NVMe) tier: three-tier migration, staging, cache retirement
# ---------------------------------------------------------------------------

def _mk_3tier(dev=4, host=4, disk=8, **kw):
    return TieredKVAllocator(dev * 16, host * 16, _pcfg(),
                             disk_bytes=disk * 16,
                             disk_link=LinkSpec(bw_bytes_s=1e9,
                                                latency_s=1e-6), **kw)


def test_disk_tier_park_demote_resume_round_trip_accounting():
    kv = _mk_3tier()
    kv.alloc(1, 16)                            # 4 device pages
    assert kv.park(1) is not None              # -> 4 host pages
    moves = kv.demote_to_disk(1, 99)
    assert len(moves) == 4
    assert all(m.src_tier == HOST and m.dst_tier == DISK for m in moves)
    assert len(kv.disk_pages_of(1)) == 4
    assert kv.host.used_pages == 0
    assert kv.pending_disk_out_pages == 4      # NVMe writes, not PCIe
    kv.check_invariants()
    back = kv.resume(1)
    assert back is not None
    # staged disk->host (4 NVMe reads), then promoted host->device
    assert kv.pending_disk_in_pages == 4
    assert len(back) == 4
    assert kv.disk_pages_of(1) == []
    assert all(r.tier == DEVICE for r in kv.refs(1))
    kv.check_invariants()
    kv.free(1)
    assert all(p.used_pages == 0 for p in kv.pools.values())


def test_demote_to_disk_skips_frames_active_sibling_streams():
    """An active request streams its host pages every iteration and the
    engine never reads the disk pool: frames shared with an active sibling
    must not retire to disk at all."""
    kv = TieredKVAllocator(0, 8 * 16, _pcfg(), scope="m", enable_dedup=True,
                           disk_bytes=8 * 16)
    p = np.arange(8, dtype=np.int64)
    kv.alloc(1, 8, prompt=p)                   # 2 host pages (parked)
    kv.alloc(2, 8, prompt=p)                   # active sibling shares both
    assert kv.demote_to_disk(1, 99, active_rids=[2]) == []
    kv.alloc(3, 8)                             # 2 private host pages
    moves = kv.demote_to_disk(3, 99, active_rids=[2])
    assert len(moves) == 2
    kv.check_invariants()


def test_unspill_from_disk_reverses_a_demotion_in_place():
    """The park-fell-through defensive path: every disk page returns to a
    host frame (NVMe reads charged), leaving no disk residency behind —
    the guarantee that an active request never keeps disk pages."""
    kv = _mk_3tier(dev=0, host=4, disk=8)
    kv.alloc(1, 16)                            # 4 host pages
    assert len(kv.demote_to_disk(1, 99)) == 4
    assert kv.host.used_pages == 0
    kv.pending_disk_out_pages = 0
    assert kv.unspill_from_disk(1) == 4
    assert kv.disk_pages_of(1) == []
    assert len(kv.host_pages_of(1)) == 4
    assert kv.pending_disk_in_pages == 4       # the reads are not free
    kv.check_invariants()


def test_resume_returns_none_when_host_cannot_stage():
    kv = _mk_3tier(dev=4, host=2, disk=8)
    kv.alloc(1, 8)                             # 2 device
    assert kv.park(1) is not None              # 2 host
    assert len(kv.demote_to_disk(1, 99)) == 2  # 2 disk
    kv.pending_disk_out_pages = 0
    kv.alloc(2, 24)                            # 4 device + 2 host: host full
    before = kv.refs(1)
    assert kv.resume(1) is None                # nothing staged, nothing moved
    assert kv.refs(1) == before
    assert kv.pending_disk_in_pages == 0
    kv.check_invariants()


def test_prefix_cache_demotes_to_disk_and_revives_on_hit():
    """Under host pressure, aged-out prefix-cache frames retire to the disk
    tier instead of being evicted — and a later dedup hit on a disk-resident
    entry revives it through a host frame (one NVMe read) and still counts
    as a cache hit."""
    kv = TieredKVAllocator(1 * 16, 4 * 16, _pcfg(), scope="dc",
                           enable_dedup=True, host_prefix_cache_pages=4,
                           disk_bytes=8 * 16,
                           disk_link=LinkSpec(bw_bytes_s=1e9))
    pa = (np.arange(12) * 7).astype(np.int64) % 97
    kv.alloc(0, 16, prompt=pa)                 # 3 host (indexed) + 1 device
    kv.free(0)
    assert len(kv.cached_pages()) == 3
    idx_before = len(kv.index)
    # a fresh 3-host-page allocation forces reclaim of 2 cache frames:
    # they must retire to disk, not die
    kv.alloc(1, 16, prompt=(np.arange(12) + 500).astype(np.int64))
    assert kv.reclaimable_disk_pages() == 2
    assert len(kv.index) == idx_before + 3     # nothing evicted, 3 added
    assert kv.pending_disk_out_pages == 2
    kv.check_invariants()
    kv.free(1)
    # resubmit pa: pages 0-1 hit on disk (revived), page 2 hits on host
    refs = kv.alloc(2, 16, prompt=pa)
    assert refs is not None
    assert kv.dedup_hit_pages(2) == [0, 1, 2]
    assert kv.cache_hits >= 3
    assert all(r.tier == HOST for r in refs[:3])
    assert kv.pending_disk_in_pages == 2       # two revival reads
    kv.check_invariants()


def test_disk_pool_backing_and_copy_hook_round_trip_bitwise(tmp_path):
    """Data-plane gate: page bytes survive host -> disk -> host bitwise,
    through both a RAM-backed buffer and a file-backed (np.memmap) pool,
    driven by the allocator's synchronous ``disk_copy`` hook exactly as the
    engine wires it."""
    for path in (None, str(tmp_path / "kv_disk.bin")):
        kv = TieredKVAllocator(2 * 16, 2 * 16, _pcfg(), disk_bytes=4 * 16,
                               disk_backing_path=path)
        page_shape = (4, 3)
        dev_buf = np.zeros((2, *page_shape), np.float32)
        host_buf = kv.host.make_pool_buffer(page_shape, np.float32)
        disk_buf = kv.disk.make_pool_buffer(page_shape, np.float32)
        if path is not None:
            assert isinstance(disk_buf, np.memmap)

        def copy(src_tier, src_page, dst_tier, dst_page,
                 host_buf=host_buf, disk_buf=disk_buf):
            if src_tier == HOST and dst_tier == DISK:
                disk_buf[dst_page] = host_buf[src_page]
            else:
                host_buf[dst_page] = disk_buf[src_page]

        kv.disk_copy = copy
        # resume's h2d legs run through promote_copy in planning order so
        # host transit frames can be reused by later stagings (the engine
        # differential test drives the actual frame-reuse chain)
        kv.promote_copy = (
            lambda src, dst, host_buf=host_buf, dev_buf=dev_buf:
            dev_buf.__setitem__(dst, host_buf[src]))
        kv.alloc(1, 8)                         # 2 device pages
        rng = np.random.default_rng(0)
        want = []
        for i, r in enumerate(kv.refs(1)):
            dev_buf[r.page] = rng.normal(size=page_shape).astype(np.float32)
            want.append(dev_buf[r.page].copy())
        moves = kv.park(1)
        assert moves is not None
        for m in moves:                        # park's d2h legs (engine job)
            host_buf[m.dst_page] = dev_buf[m.src_page]
        assert len(kv.demote_to_disk(1, 99)) == 2
        host_buf[:] = -1.0                     # clobber the host pool
        dev_buf[:] = -2.0                      # and the device pool
        back = kv.resume(1)                    # stages + promotes via hooks
        assert back is not None and len(back) == 2
        assert all(r.tier == DEVICE for r in kv.refs(1))
        for i, r in enumerate(kv.refs(1)):
            np.testing.assert_array_equal(dev_buf[r.page], want[i])
        kv.check_invariants()


def test_disk_traffic_has_own_latency_term():
    """NVMe traffic never rides the PCIe copy stream: small disk queues
    hide under the iteration, large ones bound it (max of the two
    channels), zero reduces exactly to the two-tier model, and unmodeled
    disk traffic (no bandwidth) is an error, not a free ride."""
    times = LayerTimes(2e-3, 5e-3, 8, 1 << 20, 0.0)
    base = iter_time_with_interval_kv(times, NO_OFFLOAD)
    assert iter_time_with_interval_kv(times, NO_OFFLOAD, disk_in_bytes=1e3,
                                      disk_bw=1e9) == base
    big = iter_time_with_interval_kv(times, NO_OFFLOAD, disk_in_bytes=5e8,
                                     disk_out_bytes=5e8, disk_bw=1e9,
                                     disk_latency_s=1e-3)
    assert big == pytest.approx(1e-3 + 1.0)
    with pytest.raises(ValueError):
        iter_time_with_interval_kv(times, NO_OFFLOAD, disk_out_bytes=1.0)
    for i in (1, 2, 7, NO_OFFLOAD):
        assert iter_time_with_interval_kv(times, i, 1e5, 2e5) == \
            iter_time_with_interval_kv(times, i, 1e5, 2e5, disk_bw=5e9)


def test_disk_pool_zero_is_two_tier():
    """Disk disabled: the three-tier allocator is the two-tier allocator —
    no disk pool pages, no NVMe counters, reclaim evicts like before."""
    kv = TieredKVAllocator(2 * 16, 2 * 16, _pcfg(), scope="z",
                           enable_dedup=True, host_prefix_cache_pages=4)
    assert kv.disk.total_pages == 0
    p = np.arange(16, dtype=np.int64)
    kv.alloc(0, 16, prompt=p)
    kv.free(0)
    kv.alloc(1, 16, prompt=np.arange(100, 116, dtype=np.int64))
    assert kv.reclaimable_disk_pages() == 0
    assert kv.pending_disk_out_pages == 0      # evicted, nothing retired
    assert kv.demote_to_disk(1, 99) == []
    kv.check_invariants()


# ---------------------------------------------------------------------------
# Combined weight+KV link algebra (acceptance: SLO-exact under swap traffic)
# ---------------------------------------------------------------------------

@given(tc=st.floats(1e-4, 1e-1), tt=st.floats(1e-4, 1e-1),
       n=st.integers(2, 64), i=st.integers(1, 64),
       kin=st.floats(0.0, 5e-2), kout=st.floats(0.0, 5e-2))
@settings(max_examples=200, deadline=None)
def test_analytic_matches_simulator_with_kv_traffic(tc, tt, n, i, kin, kout):
    """iter_time_with_interval_kv must equal the event simulation when KV
    swap traffic shares the copy stream with weight prefetch — every byte
    charged exactly once."""
    i = min(i, n)
    times = LayerTimes(tc, tt, n, 1 << 20, t_rest_s=0.0)
    bw = link_bandwidth(times)
    analytic = iter_time_with_interval_kv(times, i, kin * bw, kout * bw)
    sched = schedule_for_interval([tc] * n, i, tt, kv_in_s=kin, kv_out_s=kout)
    sim = simulate_iteration(sched)["latency_s"]
    assert sim == pytest.approx(analytic, rel=1e-9, abs=1e-12)


def test_kv_traffic_reduces_to_plain_interval_time():
    times = LayerTimes(2e-3, 5e-3, 32, 400 << 20, 1e-3)
    for i in (1, 2, 7, 32, NO_OFFLOAD):
        assert iter_time_with_interval_kv(times, i) == \
            iter_time_with_interval(times, i)


def test_kv_write_back_overlaps_when_no_offload():
    """With no weight transfers, write-back (d2h) rides a free copy stream:
    only swap-in, which gates layer 0, shows up in latency."""
    times = LayerTimes(2e-3, 5e-3, 8, 1 << 20, 0.0)
    bw = link_bandwidth(times)
    t = iter_time_with_interval_kv(times, NO_OFFLOAD, 0.0, 10 * (1 << 20))
    assert t == pytest.approx(times.t_iter_no_offload_s)
    t_in = iter_time_with_interval_kv(times, NO_OFFLOAD, 2 * (1 << 20), 0.0)
    assert t_in == pytest.approx(times.t_iter_no_offload_s
                                 + 2 * (1 << 20) / bw)


def test_coordinator_arbitrates_combined_weight_kv_rate():
    """KV swap traffic rides the same per-bus budget as weight prefetch: an
    instance streaming KV forces its neighbour to a larger interval on a
    link that weights-only traffic would have fit."""
    def inst(name, kv_bytes):
        return InstanceState(name=name, num_units=32, unit_bytes=400 << 20,
                             t_iter_s=0.050, min_interval=2,
                             max_interval=NO_OFFLOAD,
                             kv_bytes_per_iter=kv_bytes)

    a, b = inst("a", 0.0), inst("b", 0.0)
    base = coordinate([a, b], link_bw=1e14)
    link = base.total_link_rate * 1.05          # slack without KV traffic
    assert coordinate([a, b], link_bw=link).intervals == {"a": 2, "b": 2}

    kv_bytes = 0.050 * 0.2 * link               # b streams 20% of the link
    bk = inst("b", kv_bytes)
    res = coordinate([a, bk], link_bw=link)
    assert res.ok
    assert res.total_link_rate <= link * (1 + 1e-9)
    # combined rate is accounted: someone had to back off
    assert res.intervals["a"] > 2 or res.intervals["b"] > 2
    # and the KV rate is charged exactly once
    got_b = bk.link_rate(res.intervals["b"])
    from repro.core.interval import OffloadPlan
    want_b = OffloadPlan(32, res.intervals["b"]).link_rate(400 << 20, 0.050) \
        + kv_bytes / 0.050
    assert got_b == pytest.approx(want_b)


# ---------------------------------------------------------------------------
# Engine acceptance: serving beyond the HBM budget via host KV tiering
# ---------------------------------------------------------------------------

def _mk_tiered_engine(host_pages: int, extra_device_pages: float = 0.4,
                      max_batch: int = 4, max_seq: int = 48):
    """Engine whose HBM fits the resident weights but (essentially) no KV:
    every request's cache must spill to the host tier."""
    from _engine_builders import mk_reduced_engine

    eng, _ = mk_reduced_engine(name="tiered", max_batch=max_batch,
                               max_seq=max_seq,
                               extra_device_pages=extra_device_pages,
                               host_pages=host_pages)
    return eng


def _reqs(n, prompt_len=8, new=6, ttft=1.0, tpot=1.0):
    from repro.serving.request import Request
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, 100, prompt_len).astype(np.int32),
                    max_new_tokens=new, ttft_slo_s=ttft, tpot_slo_s=tpot)
            for i in range(n)]


def test_engine_serves_beyond_hbm_via_host_tier():
    """Acceptance: an HBM budget too small for the target (batch, context)
    under weights-only offloading is served through host KV tiering with no
    TTFT/TPOT violation in the modeled clock — and the engine's clock
    advance matches the combined-traffic prediction exactly."""
    eng = _mk_tiered_engine(host_pages=16)
    assert eng.kv.device.total_pages == 0       # weights-only: no KV fits
    reqs = _reqs(3)
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng._active_batch() > 0              # admitted via host spill
    assert eng.kv.host.used_pages > 0

    # predicted vs simulated clock under combined weight+KV traffic
    streamed = eng.swap.streamed_bytes(eng._active_rids())
    assert streamed > 0
    times = eng.times_fn(eng._active_batch(), eng.ecfg.max_seq, "decode")
    predicted = iter_time_with_interval_kv(times, eng.interval, streamed, 0.0)
    t0 = eng.clock_s
    was_queued = len(eng.queue)
    eng.step()
    if len(eng.queue) == was_queued:            # no admission: pure decode
        assert eng.clock_s - t0 == pytest.approx(predicted, rel=1e-9)

    it = 0
    while (eng.queue or eng._active_batch() > 0) and it < 300:
        eng.step()
        it += 1
    assert len(eng.finished) == 3
    for r in eng.finished:
        m = r.metrics()
        assert m["ttft_ok"] and m["tpot_ok"]
    assert eng.kv.host.used_pages == 0          # all pages returned
    eng.kv.check_invariants()


def test_engine_without_host_tier_cannot_serve_it():
    """Control: with host_kv_bytes=0 the same workload is unservable —
    the device pool never has a page, so requests wait forever."""
    eng = _mk_tiered_engine(host_pages=0)
    out = eng.run(_reqs(2), max_iters=50)
    assert out["finished"] == 0
    assert len(eng.queue) == 2                  # waiting, not rejected


def test_engine_spill_admission_respects_tpot():
    """If streaming the spilled KV would push the iteration past the TPOT
    SLO, the request is NOT admitted (it waits) — no modeled violation."""
    eng = _mk_tiered_engine(host_pages=16)
    times = eng.times_fn(1, eng.ecfg.max_seq, "decode")
    pages = eng.kv.device.pages_for(8 + 6)
    stream_bytes = pages * eng.kv.page_bytes
    dt0 = iter_time_with_interval_kv(times, eng.interval)
    dt_stream = iter_time_with_interval_kv(times, eng.interval, stream_bytes)
    assert dt_stream > dt0
    tight = (dt0 + dt_stream) / 2               # feasible w/o KV, not with
    reqs = _reqs(1, tpot=tight)
    out = eng.run(reqs, max_iters=20)
    assert out["finished"] == 0
    assert len(eng.queue) == 1                  # waiting on device pages
    assert eng.kv.host.used_pages == 0


# ---------------------------------------------------------------------------
# PrefixIndex keep-alive (host-tier prefix cache, LRU-bounded)
# ---------------------------------------------------------------------------

def _mk_cached_kv(device_pages=1, host_pages=8, cache_pages=4):
    return TieredKVAllocator(device_pages * 16, host_pages * 16,
                             _pcfg(page_size=4, bpt=4), scope="cache-test",
                             enable_dedup=True,
                             host_prefix_cache_pages=cache_pages)


def _prompt(seed, n=12):
    return (np.arange(n) * 7 + seed).astype(np.int64) % 97


def test_prefix_cache_keeps_host_entries_after_last_owner_frees():
    """A re-submitted shared prefix dedups even when no live request holds
    the pages anymore: the last owner's indexed host frames survive under
    the cache owner instead of dying with the request."""
    kv = _mk_cached_kv()
    p = _prompt(0)
    kv.alloc(0, 16, prompt=p)              # 3 prompt pages on host, tail dev
    assert len(kv.host_pages_of(0)) == 3
    idx_before = len(kv.index)
    kv.free(0)
    kv.check_invariants()
    assert len(kv.cached_pages()) == 3     # frames survived their owner
    assert len(kv.index) == idx_before     # content still addressable
    assert kv.host.used_pages == 3

    refs = kv.alloc(1, 16, prompt=p)       # same prefix re-submitted
    assert refs is not None
    assert kv.dedup_hit_pages(1) == [0, 1, 2]
    assert kv.cache_hits == 3
    kv.free(1)
    kv.check_invariants()
    assert len(kv.cached_pages()) == 3     # re-entered the cache


def test_prefix_cache_lru_capacity_evicts_oldest():
    kv = _mk_cached_kv(host_pages=16, cache_pages=3)
    kv.alloc(0, 16, prompt=_prompt(0))     # 3 host prompt pages
    kv.free(0)
    first_gen = set(kv.cached_pages())
    kv.alloc(1, 16, prompt=_prompt(1))     # different content: 3 more
    kv.free(1)
    kv.check_invariants()
    cached = kv.cached_pages()
    assert len(cached) == 3                # capacity bound holds
    # the survivors are the newest entries (rid 1's), oldest evicted first
    assert not (set(cached) & first_gen)
    assert kv.host.used_pages == 3


def test_prefix_cache_reclaimed_under_host_pressure():
    """Cache frames are capacity, not a leak: an allocation that needs host
    pages evicts LRU entries instead of failing."""
    kv = _mk_cached_kv(host_pages=4, cache_pages=4)
    kv.alloc(0, 16, prompt=_prompt(0))
    kv.free(0)
    assert len(kv.cached_pages()) == 3 and kv.host.free_pages == 1
    # a fresh prompt needs 3 host pages: 2 cache entries must be reclaimed
    refs = kv.alloc(1, 16, prompt=_prompt(5))
    assert refs is not None
    assert len(kv.host_pages_of(1)) == 3
    assert len(kv.cached_pages()) <= 1
    kv.check_invariants()


def test_prefix_cache_hit_frames_not_reclaimed_for_same_alloc():
    """Reclaim under pressure must spare the frames the very same
    allocation is about to share: the OTHER prompt's entries evict, the hit
    prompt's entries survive and dedup."""
    kv = _mk_cached_kv(host_pages=8, cache_pages=6)
    pa, pb = _prompt(0), _prompt(50)
    kv.alloc(0, 16, prompt=pa)
    kv.free(0)                             # pa cached (older)
    kv.alloc(1, 16, prompt=pb)
    kv.free(1)                             # pb cached (newer)
    assert len(kv.cached_pages()) == 6 and kv.host.free_pages == 2
    # pb resubmitted with a longer tail: hits pb's 3 cached pages, needs 3
    # fresh host pages (free 2) -> reclaim must evict pa's LRU entries, not
    # the pb frames this allocation shares
    refs = kv.alloc(2, 28, prompt=np.concatenate([pb, _prompt(9, 8)]))
    assert refs is not None
    assert kv.dedup_hit_pages(2)[:3] == [0, 1, 2]
    assert kv.cache_hits == 3
    kv.check_invariants()


def test_prefix_cache_disabled_by_default_frames_die_with_owner():
    kv = TieredKVAllocator(16, 8 * 16, _pcfg(page_size=4, bpt=4),
                           scope="nocache", enable_dedup=True)
    kv.alloc(0, 16, prompt=_prompt(0))
    kv.free(0)
    assert kv.host.used_pages == 0 and len(kv.index) == 0
    assert kv.cached_pages() == []


def test_prefix_cache_single_owner_over_cap_trims_at_free():
    """Regression: the LRU bound must hold even when ONE owner frees more
    indexed host pages than the capacity — the trim runs after the owner's
    own claims are released, so the excess frames are evictable
    immediately, not only under later pressure."""
    kv = _mk_cached_kv(host_pages=8, cache_pages=2)
    kv.alloc(0, 16, prompt=_prompt(0))     # 3 indexed host pages
    kv.free(0)
    kv.check_invariants()
    assert len(kv.cached_pages()) == 2     # bound holds right away
    assert kv.host.used_pages == 2


# ---------------------------------------------------------------------------
# Forked beams: per-sharer COW reserves on arbitrary shared pages
# ---------------------------------------------------------------------------

def test_fork_shares_whole_block_table_refcounts():
    kv = TieredKVAllocator(8 * 16, 8 * 16, _pcfg())
    refs = kv.alloc(1, 3 * 4)
    assert refs is not None
    forked = kv.fork(1, 2)
    assert forked is not None and len(forked) == 3
    assert kv.refs(2) == kv.refs(1)              # same frames, position-wise
    for r in kv.refs(1):
        assert kv.refcount(r) == 2
    kv.check_invariants()
    # freeing one sharer leaves the other's table fully intact
    kv.free(1)
    assert len(kv.refs(2)) == 3
    for r in kv.refs(2):
        assert kv.refcount(r) == 1
    kv.free(2)
    assert kv.device.used_pages == 0 and kv.host.used_pages == 0


def test_fork_refuses_live_dst_and_dead_src():
    kv = TieredKVAllocator(8 * 16, 8 * 16, _pcfg())
    kv.alloc(1, 4)
    kv.alloc(2, 4)
    assert kv.fork(1, 2) is None                 # dst already live
    assert kv.fork(99, 3) is None                # src unknown
    kv.check_invariants()


def test_add_reserve_per_sharer_on_arbitrary_shared_page():
    """Each sharer of each shared page gets its OWN private spare frame —
    N beams diverging at the same position must never race for one
    reserve, and a mid-table page is as reservable as the tail."""
    kv = TieredKVAllocator(8 * 16, 8 * 16, _pcfg())
    kv.alloc(1, 3 * 4)
    kv.fork(1, 2)
    r1 = kv.add_reserve(1, 1)                    # mid-table shared page
    r2 = kv.add_reserve(2, 1)
    assert r1 is not None and r2 is not None
    assert r1.page != r2.page                    # private per sharer
    assert kv.reserves_of(1) == {1: r1}
    assert kv.reserves_of(2) == {1: r2}
    assert kv.n_reserve_frames() == 2
    # idempotent: a covered page hands back the existing reserve
    assert kv.add_reserve(1, 1) == r1
    assert kv.n_reserve_frames() == 2
    kv.check_invariants()


def test_add_reserve_private_page_needs_none():
    kv = TieredKVAllocator(8 * 16, 8 * 16, _pcfg())
    kv.alloc(1, 3 * 4)
    assert kv.add_reserve(1, 0) is None          # refcount 1: no COW risk
    assert kv.n_reserve_frames() == 0


def test_add_reserve_exhausted_pools_claims_nothing():
    kv = TieredKVAllocator(2 * 16, 1 * 16, _pcfg())   # 2 dev + 1 host pages
    kv.alloc(1, 2 * 4)
    kv.fork(1, 2)
    assert kv.add_reserve(1, 0) is not None      # host fallback frame
    assert kv.add_reserve(2, 0) is None          # both pools dry: no claim
    assert kv.n_reserve_frames() == 1
    kv.check_invariants()


# ---------------------------------------------------------------------------
# PEER tier accounting: handoff export/import conservation + refusal path
# ---------------------------------------------------------------------------

def test_peer_handoff_byte_conservation_across_allocators():
    """Exporter and importer book the same page count; both sides' pending
    counters drain into exactly one SwapPlan's peer terms and zero out —
    the per-instance halves of the I12 conservation invariant."""
    src = TieredKVAllocator(4 * 16, 8 * 16, _pcfg())
    dst = TieredKVAllocator(4 * 16, 8 * 16, _pcfg())
    src.alloc(1, 3 * 4)
    assert src.park(1) is not None               # whole table host-ward
    pages = src.export_parked(1)
    assert pages is not None and len(pages) == 3
    src.free(1)
    src.note_peer_export(len(pages))

    got = dst.import_parked(1, len(pages))
    assert got is not None and len(got) == 3
    dst.note_peer_import(len(pages))

    assert src.peer_out_pages_total == dst.peer_in_pages_total == 3
    s_src, s_dst = SwapScheduler(src), SwapScheduler(dst)
    p_out, p_in = s_src.plan_iteration([]), s_dst.plan_iteration([])
    assert p_out.peer_out_bytes == p_in.peer_in_bytes == 3 * src.page_bytes
    assert src.pending_peer_out_pages == dst.pending_peer_in_pages == 0
    # drained once: the next plan charges nothing
    assert s_src.plan_iteration([]).peer_out_bytes == 0
    assert s_dst.plan_iteration([]).peer_in_bytes == 0
    src.check_invariants()
    dst.check_invariants()


def test_peer_export_refuses_partial_or_reserved_parks():
    kv = TieredKVAllocator(4 * 16, 8 * 16, _pcfg())
    kv.alloc(1, 2 * 4)
    assert kv.export_parked(1) is None           # device-resident: not parked
    kv.park(1)
    kv.fork(1, 2)
    assert kv.add_reserve(1, 0) is not None
    assert kv.export_parked(1) is None           # reserve held: stays put
    assert kv.export_parked(2) is not None       # reserve-free sharer exports
    kv.check_invariants()


def test_peer_import_refusal_claims_nothing_and_rollback_reclaims():
    """A too-small host tier refuses the import with ZERO frames claimed;
    the exporter can then re-import into the frames its own export just
    freed — the allocator-level contract the engine's rollback leans on."""
    src = TieredKVAllocator(4 * 16, 8 * 16, _pcfg())
    dst = TieredKVAllocator(4 * 16, 2 * 16, _pcfg())   # 2 host pages only
    src.alloc(1, 3 * 4)
    src.park(1)
    assert src.export_parked(1) is not None
    src.free(1)
    used_before = dst.host.used_pages
    assert dst.import_parked(1, 3) is None       # cannot absorb: refuse
    assert dst.host.used_pages == used_before    # nothing claimed
    back = src.import_parked(1, 3)               # rollback re-claim
    assert back is not None and len(back) == 3
    assert src.refs(1) == [PageRef(HOST, p) for p in back]
    src.check_invariants()
    dst.check_invariants()
