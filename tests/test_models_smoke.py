"""Per-architecture smoke tests: reduced config, one forward/train step and a
prefill→decode step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.reduced import reduce_config
from repro.models.frontends import stub_embeddings
from repro.models.model import build_model

# compile-heavy (full JAX jit of models/kernels): excluded from the fast CI
# tier, run in the nightly full suite
pytestmark = pytest.mark.slow

B, S = 2, 16


def make_inputs(cfg, model, key, seq=S, with_labels=False):
    ks = jax.random.split(key, 3)
    n_front = 0
    inputs = {}
    if cfg.encoder_layers > 0:
        inputs["enc_embeds"] = stub_embeddings(cfg, B, seq, ks[0])
    elif cfg.frontend is not None:
        n_front = cfg.frontend.num_positions
        inputs["frontend_embeds"] = stub_embeddings(cfg, B, n_front, ks[0])
    s_tok = seq - n_front
    inputs["tokens"] = jax.random.randint(ks[1], (B, s_tok), 0,
                                          cfg.vocab_size, jnp.int32)
    if with_labels:
        inputs["labels"] = jax.random.randint(ks[2], (B, s_tok), 0,
                                              cfg.vocab_size, jnp.int32)
    return inputs


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_loss(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_inputs(cfg, model, key, with_labels=True)
    loss, metrics = jax.jit(lambda p, b: model.loss_fn(p, b, remat=False))(
        params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nans(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = make_inputs(cfg, model, key, with_labels=True)

    def step(p, b):
        (loss, _), grads = jax.value_and_grad(
            lambda q: model.loss_fn(q, b, remat=True), has_aux=True)(p)
        return loss, grads

    loss, grads = jax.jit(step)(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    inputs = make_inputs(cfg, model, key)
    cache_len = S + 4

    logits, caches, enc_pos = jax.jit(
        lambda p, i: model.prefill(p, i, cache_len=cache_len))(params, inputs)
    assert logits.shape == (B, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, caches2 = jax.jit(model.decode_step)(params, tok, pos, caches,
                                                  enc_pos)
    assert logits2.shape == (B, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    # caches must be structurally stable across steps (scan invariant)
    jax.tree.map(lambda a, b: None if a.shape == b.shape else pytest.fail(
        f"cache shape changed {a.shape} vs {b.shape}"), caches, caches2)
