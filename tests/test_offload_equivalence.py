"""The offload-grouped step must compute exactly what the plain step computes
— offloading is a *placement*, never a math change. Checked per interval and
per architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core.interval import NO_OFFLOAD, OffloadPlan
from repro.core.memory_manager import (OffloadRuntime, merge_model_params,
                                       split_model_params, split_stacked)
from repro.models.frontends import stub_embeddings
from repro.models.model import build_model
from repro.models.transformer import pattern_info

# compile-heavy (full JAX jit of models/kernels): excluded from the fast CI
# tier, run in the nightly full suite
pytestmark = pytest.mark.slow

B, S = 2, 12


def _mk(arch, layers=None):
    cfg = reduce_config(get_config(arch))
    if layers:
        import dataclasses
        cfg = dataclasses.replace(cfg, num_layers=layers)
    return cfg, build_model(cfg)


def _inputs(cfg, key):
    inputs = {}
    if cfg.encoder_layers > 0:
        inputs["enc_embeds"] = stub_embeddings(cfg, B, S, key)
    elif cfg.frontend is not None:
        inputs["frontend_embeds"] = stub_embeddings(
            cfg, B, cfg.frontend.num_positions, key)
    n_front = (cfg.frontend.num_positions
               if cfg.frontend is not None and cfg.family != "audio" else 0)
    inputs["tokens"] = jax.random.randint(key, (B, S - n_front), 0,
                                          cfg.vocab_size, jnp.int32)
    return inputs


@pytest.mark.parametrize("arch,layers,interval", [
    ("deepseek-7b", 6, 1),       # DeepSpeed degenerate case
    ("deepseek-7b", 6, 2),
    ("deepseek-7b", 6, 3),
    ("deepseek-7b", 7, 3),       # remainder tail
    ("deepseek-7b", 6, NO_OFFLOAD),
    ("qwen2.5-3b", 4, 2),
    ("h2o-danube-3-4b", 4, 2),   # SWA
    ("grok-1-314b", 4, 2),       # MoE
    ("jamba-1.5-large-398b", None, 2),  # hybrid: 2 periods, interval in units
    ("xlstm-125m", 4, 2),
    ("seamless-m4t-medium", 4, 2),      # enc-dec w/ cross caches
    ("paligemma-3b", 4, 2),
])
def test_decode_equivalence(arch, layers, interval):
    cfg, model = _mk(arch, layers)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    inputs = _inputs(cfg, key)
    cache_len = S + 4

    logits_p, caches, enc_pos = jax.jit(
        lambda p, i: model.prefill(p, i, cache_len=cache_len))(params, inputs)
    tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)

    # Plain path
    ref_logits, ref_caches = jax.jit(model.decode_step)(params, tok, pos,
                                                        caches, enc_pos)

    # Offload path
    _, r = pattern_info(cfg)
    plan = OffloadPlan(num_units=r, interval=interval)
    rt = OffloadRuntime(model=model, plan=plan)
    psplit = split_model_params(params, plan)
    csplit = split_stacked(caches, plan)
    off_logits, new_csplit = jax.jit(rt.decode_step)(psplit, tok, pos, csplit,
                                                     enc_pos)

    # bf16 tolerance: the grouped path slices params/caches differently
    # (direct [g, j] dynamic slices vs scan xs), which changes XLA fusion
    # boundaries and thus bf16 rounding. Exactness is asserted in f32 below.
    np.testing.assert_allclose(np.asarray(off_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=3e-2, atol=6e-2)


def test_decode_equivalence_exact_f32():
    """In f32 the grouped decode is bit-exact vs the plain step — offloading
    is a placement, never a math change."""
    cfg, model = _mk("deepseek-7b", 6)
    key = jax.random.PRNGKey(0)
    params = jax.tree.map(
        lambda t: t.astype(jnp.float32) if t.dtype == jnp.bfloat16 else t,
        model.init(key))
    inputs = _inputs(cfg, key)
    cache_len = S + 4
    logits_p, caches, enc_pos = jax.jit(
        lambda p, i: model.prefill(p, i, cache_len=cache_len))(params, inputs)
    tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    ref_logits, _ = jax.jit(model.decode_step)(params, tok, pos, caches,
                                               enc_pos)
    for interval in (1, 2, 3):
        plan = OffloadPlan(num_units=6, interval=interval)
        rt = OffloadRuntime(model=model, plan=plan)
        off_logits, _ = jax.jit(rt.decode_step)(
            split_model_params(params, plan), tok, pos,
            split_stacked(caches, plan), enc_pos)
        np.testing.assert_array_equal(np.asarray(off_logits),
                                      np.asarray(ref_logits))


def test_offload_prefill_equivalence():
    cfg, model = _mk("deepseek-7b", 6)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    inputs = _inputs(cfg, key)
    ref_logits, _, _ = jax.jit(
        lambda p, i: model.prefill(p, i, cache_len=S))(params, inputs)

    plan = OffloadPlan(num_units=6, interval=3)
    rt = OffloadRuntime(model=model, plan=plan)
    psplit = split_model_params(params, plan)
    off_logits, caches, _ = jax.jit(
        lambda p, i: rt.prefill(p, i, cache_len=S))(psplit, inputs)
    np.testing.assert_allclose(np.asarray(off_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
    # prefill caches feed the offloaded decode directly
    tok = jnp.argmax(off_logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, _ = jax.jit(rt.decode_step)(psplit, tok, pos, caches, None)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_split_merge_roundtrip():
    cfg, model = _mk("qwen2.5-3b", 6)
    params = model.init(jax.random.PRNGKey(1))
    plan = OffloadPlan(num_units=6, interval=4)  # G=1, tail=2
    split = split_model_params(params, plan)
    merged = merge_model_params(split, plan)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, merged)
